"""End-to-end smart-grid deployment (paper §4): a full site with topology,
IoT ingestion, a data-transformation model (Fig. 4), all four AI models
deployed against the substation (Figs. 5/6), programmatic fleet deployment
to every prosumer, rolling-horizon scoring over several cycles (Fig. 7),
and the model-ranking retrieval.

    PYTHONPATH=src python examples/smartgrid_forecasting.py \
        [--executor fleet|serverless|local]

``--executor serverless`` routes the cycles through the serverless
invocation pipeline (stateless payloads, aggregated actions, warm
sticky workers — repro/serverless/) and prints its invocation telemetry.
"""
import argparse
import time

import numpy as np

from repro.core import Castor, ModelDeployment, Schedule, DAY, HOUR
from repro.forecast import (PAPER_MODELS, EnergyFromCurrentModel)
from repro.timeseries.ingest import SiteSpec, build_site, ingest_current_feed
from repro.timeseries.transforms import mape


def main(executor: str = "fleet"):
    castor = Castor()
    t_end = 50 * DAY
    site = build_site(castor, SiteSpec("CY", n_prosumers=8, n_feeders=2,
                                       n_substations=1, seed=5),
                      t0=0.0, t1=t_end)
    print(f"[site] {castor.stats()} ({site['readings']:,} readings)")

    # ---- data-transformation model (Fig. 4): current -> 15-min energy ----
    ingest_current_feed(castor, "CY_SUB_0", t0=40 * DAY, t1=45 * DAY)
    castor.publish("castor-xform", "1.0", EnergyFromCurrentModel)
    castor.add_signal("ENERGY_LOAD_15MIN", unit="kWh")
    castor.deploy(ModelDeployment(
        name="xform-sub", package="castor-xform",
        signal="ENERGY_LOAD_15MIN", entity="CY_SUB_0",
        train=Schedule(45 * DAY, 1e12), score=Schedule(45 * DAY, DAY),
        user_params={"window_days": 5}))

    # ---- the paper's four AI models on the substation (Figs. 5/6) ----
    hp = {"ANN": {"epochs": 150, "hidden": 32},
          "LSTM": {"epochs": 150, "hidden": 16}}
    for rank, (kind, cls) in enumerate(PAPER_MODELS.items()):
        castor.publish(f"castor-{kind.lower()}", "1.0", cls)
        castor.deploy(ModelDeployment(
            name=f"{kind}-sub", package=f"castor-{kind.lower()}",
            signal="ENERGY_LOAD", entity="CY_SUB_0",
            train=Schedule(45 * DAY, 7 * DAY), score=Schedule(45 * DAY, HOUR),
            user_params={"train_window_days": 28, **hp.get(kind, {})},
            rank=rank))

    # ---- programmatic fleet: LR for every prosumer with the signal ----
    fleet = castor.deploy_for_all(
        package="castor-lr", signal="ENERGY_LOAD", name_prefix="fleet-lr",
        kind="PROSUMER", train=Schedule(45 * DAY, 7 * DAY),
        score=Schedule(45 * DAY, HOUR),
        user_params={"train_window_days": 21})
    print(f"[deploy] {len(castor.deployments)} deployments "
          f"({len(fleet)} from one semantic rule)")

    # ---- run 3 hourly scheduler cycles (rolling horizons, Fig. 7) ----
    t0 = time.time()
    for i in range(3):
        res = castor.tick(45 * DAY + i * HOUR, executor=executor)
        ok = sum(r.ok for r in res)
        print(f"[tick {i}] {ok}/{len(res)} jobs ok")
        bad = [r for r in res if not r.ok]
        for r in bad[:3]:
            print("   FAIL", r.job.deployment_name, r.error[:100])
    print(f"[exec] 3 cycles in {time.time()-t0:.1f}s wall "
          f"(executor={executor})")
    if executor == "serverless":
        s = castor.stats()["serverless"]
        print(f"[serverless] {s['invocations']} invocations "
              f"({s['cold_starts']} cold / {s['warm_starts']} warm), "
              f"mean aggregation {s['mean_aggregation']:.1f} jobs/action, "
              f"p50 exec {s['exec_s_p50'] * 1e3:.0f}ms")

    # ---- Fig. 6: compare the four substation models against actuals ----
    print("\nvalidation MAPE over the first scored day (paper: LR 3.92, "
          "GAM 2.86, ANN 2.76, LSTM 6.37):")
    for kind in PAPER_MODELS:
        fc = castor.predictions.history(f"{kind}-sub")[0]
        t, actual = castor.read("ENERGY_LOAD", "CY_SUB_0",
                                fc.times[0] - 1, fc.times[-1] + 1)
        n = min(len(actual), len(fc.values))
        print(f"  {kind:5s} MAPE = {mape(actual[:n], fc.values[:n]):5.2f}%")

    # ---- Fig. 7: one target hour seen from multiple forecast horizons ----
    first = castor.predictions.history("GAM-sub")[0]
    target = float(first.times[4])
    hz = castor.predictions.horizons("GAM-sub", target)
    print(f"\nFig.7 view — target hour t={target/3600:.0f}h predicted from "
          f"{len(hz)} horizons: {[(round(c/3600., 1), round(v, 2)) for c, v in hz]}")

    # ---- ranking: consumers just ask for the context ----
    best = castor.best_forecast("ENERGY_LOAD", "CY_SUB_0")
    print(f"\nranked retrieval serves: {best.deployment_name}")
    print(f"[lineage] {castor.versions.count()} model versions, "
          f"{castor.predictions.count()} persisted forecasts")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--executor", default="fleet",
                    choices=("fleet", "serverless", "local"))
    main(ap.parse_args().executor)
