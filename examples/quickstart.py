"""Quickstart: the paper's full workflow in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Ingest a sensor -> attach semantics -> publish a model implementation ->
deploy it against the semantic context -> let the scheduler execute it ->
retrieve the forecast by semantics.
"""
import numpy as np

from repro.core import Castor, ModelDeployment, Schedule, DAY, HOUR
from repro.forecast import LinearForecaster
from repro.timeseries.transforms import mape


def main():
    castor = Castor()

    # (1) ingest an irregular energy time-series for 35 days
    rng = np.random.default_rng(0)
    t = np.arange(0, 35 * DAY, HOUR) + rng.uniform(-60, 60, 35 * 24)
    hod = (t % DAY) / HOUR
    load = 3 + 2 * np.exp(-0.5 * ((hod - 19) / 2.5) ** 2) \
        + rng.normal(0, 0.08, t.size)
    castor.ingest("sensor-001", t, load)

    # (2) contextualise: what quantity, where
    castor.add_signal("ENERGY_LOAD", unit="kWh")
    castor.add_entity("SUBSTATION_S1", kind="SUBSTATION", lat=35.1, lon=33.4)
    castor.link("sensor-001", "ENERGY_LOAD", "SUBSTATION_S1")

    # (3)/(4) publish a model implementation (the paper's PyPI step)
    castor.publish("energy-lr", "1.0", LinearForecaster)

    # (5)/(6) deploy it against the context with train/score schedules
    castor.deploy(ModelDeployment(
        name="lr-s1", package="energy-lr",
        signal="ENERGY_LOAD", entity="SUBSTATION_S1",
        train=Schedule(start=30 * DAY, every=7 * DAY),     # weekly training
        score=Schedule(start=30 * DAY, every=HOUR),        # hourly scoring
        user_params={"train_window_days": 21, "horizon": 24}))

    # (7)-(10) one scheduler tick trains + scores; forecasts are persisted
    results = castor.tick(now=30 * DAY)
    print(f"executed {len(results)} jobs: "
          f"{[f'{r.job.task}:{r.ok}' for r in results]}")

    # retrieval is semantic: consumers never know which model served it
    fc = castor.best_forecast("ENERGY_LOAD", "SUBSTATION_S1")
    print(f"forecast by {fc.deployment_name} (model v{fc.model_version}): "
          f"{fc.values[:6].round(2)} ...")

    tt, actual = castor.read("ENERGY_LOAD", "SUBSTATION_S1",
                             fc.times[0] - 1, fc.times[-1] + 1)
    n = min(len(actual), len(fc.values))
    print(f"24h MAPE vs actuals: {mape(actual[:n], fc.values[:n]):.2f}%")
    print("system stats:", castor.stats())


if __name__ == "__main__":
    main()
