"""Serve a small LM with continuously-batched requests (the serving path of
the assigned architectures; the production-mesh variant is exercised by the
decode/prefill dry-run cells).

    PYTHONPATH=src python examples/serve_lm.py [--arch llama3-8b]
"""
import argparse
import time

import jax
import numpy as np

from repro.arch import model as M
from repro.configs import get_config
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-smoke")      # CPU-scale same-family config
    print(f"[serve] {cfg.name}: {M.param_count(cfg)/1e6:.2f}M params, "
          f"{args.slots} cache slots")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_slots=args.slots, max_seq=128)

    rng = np.random.default_rng(1)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 16))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(8, 24))))
        engine.submit(reqs[-1])

    t0 = time.perf_counter()
    total = engine.run_until_idle()
    dt = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    print(f"[serve] {done}/{len(reqs)} requests finished, {total} tokens "
          f"in {dt:.1f}s  ({total/dt:.1f} tok/s, {engine.steps} engine steps, "
          f"mean batch occupancy "
          f"{total/max(engine.steps,1):.2f}/{args.slots})")
    for r in reqs[:3]:
        print(f"  req{r.rid}: prompt[{len(r.prompt)}] -> {r.tokens[:8]}...")


if __name__ == "__main__":
    main()
