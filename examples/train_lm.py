"""Train a small LM for a few hundred steps with the full production loop:
async sharded checkpoints, an injected node failure, restore-and-continue.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()
    losses = train_main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", "--lr", "1e-3",
        "--checkpoint-every", "50",
        "--inject-failure-at", str(args.steps // 2),   # prove the fault path
        "--checkpoint-dir", "artifacts/example_ckpt",
    ])
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} effective steps (incl. one failure+restore)")


if __name__ == "__main__":
    main()
