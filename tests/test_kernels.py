"""Per-kernel validation: Pallas (interpret=True) and the XLA paths swept
over shapes/dtypes against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.flash_attention.xla import attention_xla
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_reference
from repro.kernels.mamba2_scan.kernel import ssd_scan_pallas
from repro.kernels.mamba2_scan.ref import ssd_chunked, ssd_sequential
from repro.kernels.rwkv6_scan.kernel import wkv6_scan_pallas
from repro.kernels.rwkv6_scan.ref import wkv6_chunked, wkv6_sequential
from repro.kernels.fleet_mlp.kernel import fleet_mlp_pallas
from repro.kernels.fleet_mlp.ref import fleet_mlp_reference

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _mk(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,D,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),       # MHA
    (2, 256, 4, 2, 32, 128, 64),      # GQA 2:1
    (1, 128, 8, 2, 64, 64, 128),      # GQA 4:1, wide head
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(rng, dtype, B, S, H, KV, D, bq, bk, causal):
    q, k, v = (_mk(rng, (B, S, n, D), dtype) for n in (H, KV, KV))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    want = attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("causal", [True, False])
def test_attention_xla_matches_ref(rng, causal):
    q, k, v = (_mk(rng, (2, 256, 4, 32), jnp.float32) for _ in range(3))
    k = k[:, :, :2]
    v = v[:, :, :2]
    got = attention_xla(q, k, v, causal=causal, q_chunk=64)
    want = attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_attention_cross_q_kv_lengths(rng):
    """Chunked prefill continuation: Sq < Skv with aligned ends."""
    q = _mk(rng, (1, 64, 4, 32), jnp.float32)
    k = _mk(rng, (1, 256, 4, 32), jnp.float32)
    v = _mk(rng, (1, 256, 4, 32), jnp.float32)
    got = attention_xla(q, k, v, causal=True, q_chunk=32)
    want = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,D,bk", [
    (3, 256, 4, 2, 32, 64),
    (2, 128, 8, 8, 64, 128),
])
def test_decode_attention(rng, dtype, B, S, H, KV, D, bk):
    q = _mk(rng, (B, H, D), dtype)
    kc = _mk(rng, (B, S, KV, D), dtype)
    vc = _mk(rng, (B, S, KV, D), dtype)
    lens = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)
    got = decode_attention_pallas(q, kc, vc, lens, block_k=bk, interpret=True)
    want = decode_attention_reference(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 3, 16, 16, 32),
    (1, 64, 2, 8, 32, 16),
    (1, 96, 1, 32, 16, 32),
])
def test_mamba2_kernel_vs_sequential(rng, B, S, H, P, N, chunk):
    x = _mk(rng, (B, S, H, P), jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = _mk(rng, (B, S, 1, N), jnp.float32)
    Cm = _mk(rng, (B, S, 1, N), jnp.float32)
    D = _mk(rng, (H,), jnp.float32)
    got_y, got_s = ssd_scan_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                                   interpret=True)
    want_y, want_s = ssd_sequential(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(got_y, want_y, atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(got_s, want_s, atol=3e-5, rtol=3e-5)


def test_mamba2_chunked_xla_init_state(rng):
    """XLA chunked path: continuation with init_state == longer sequential."""
    B, S, H, P, N = 1, 128, 2, 8, 8
    x = _mk(rng, (B, S, H, P), jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = _mk(rng, (B, S, 1, N), jnp.float32)
    Cm = _mk(rng, (B, S, 1, N), jnp.float32)
    D = _mk(rng, (H,), jnp.float32)
    y_full, s_full = ssd_sequential(x, dt, A, Bm, Cm, D)
    half = S // 2
    _, s1 = ssd_chunked(x[:, :half], dt[:, :half], A, Bm[:, :half],
                        Cm[:, :half], D, chunk=32)
    y2, s2 = ssd_chunked(x[:, half:], dt[:, half:], A, Bm[:, half:],
                         Cm[:, half:], D, init_state=s1, chunk=32)
    np.testing.assert_allclose(y2, y_full[:, half:], atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(s2, s_full, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("wmin", [0.4, 0.001])   # mild + aggressive decay
@pytest.mark.parametrize("B,S,H,K,chunk", [
    (2, 128, 3, 16, 32),
    (1, 64, 2, 32, 16),
])
def test_rwkv6_kernel_vs_sequential(rng, wmin, B, S, H, K, chunk):
    r = _mk(rng, (B, S, H, K), jnp.float32)
    k = _mk(rng, (B, S, H, K), jnp.float32)
    v = _mk(rng, (B, S, H, K), jnp.float32)
    w = jnp.asarray(rng.uniform(wmin, 0.999, (B, S, H, K)), jnp.float32)
    u = _mk(rng, (H, K), jnp.float32)
    got_y, got_s = wkv6_scan_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    want_y, want_s = wkv6_sequential(r, k, v, w, u)
    np.testing.assert_allclose(got_y, want_y, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(got_s, want_s, atol=2e-4, rtol=2e-4)


def test_rwkv6_chunked_xla_moderate_decay(rng):
    B, S, H, K = 2, 96, 2, 16
    r = _mk(rng, (B, S, H, K), jnp.float32)
    k = _mk(rng, (B, S, H, K), jnp.float32)
    v = _mk(rng, (B, S, H, K), jnp.float32)
    w = jnp.asarray(rng.uniform(0.37, 0.999, (B, S, H, K)), jnp.float32)
    u = _mk(rng, (H, K), jnp.float32)
    got_y, got_s = wkv6_chunked(r, k, v, w, u, chunk=32)
    want_y, want_s = wkv6_sequential(r, k, v, w, u)
    np.testing.assert_allclose(got_y, want_y, atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(got_s, want_s, atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,b,F,Hd,depth,block_n", [
    (16, 4, 8, 32, 3, 4),
    (8, 1, 54, 64, 5, 8),      # ANN shape (4 hidden + out)
    (4, 2, 16, 16, 1, 2),      # single layer
])
def test_fleet_mlp(rng, dtype, N, b, F, Hd, depth, block_n):
    x = _mk(rng, (N, b, F), dtype)
    sizes = [F] + [Hd] * (depth - 1) + [1]
    ws = [_mk(rng, (N, sizes[i], sizes[i + 1]), dtype) for i in range(depth)]
    bs = [_mk(rng, (N, sizes[i + 1]), dtype) for i in range(depth)]
    got = fleet_mlp_pallas(x, ws, bs, block_n=block_n, interpret=True)
    want = fleet_mlp_reference(x, ws, bs)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype] * 10, rtol=TOL[dtype] * 10)
