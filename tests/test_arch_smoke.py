"""Per-architecture smoke tests (assignment requirement): reduced same-family
config, one forward/train step on CPU, asserting output shapes + no NaNs;
plus decode-vs-forward consistency for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.arch import model as M
from repro.configs import get_config, list_archs
from repro.configs.base import ShapeSpec
from repro.data.synthetic import synthetic_batch_for
from repro.train import AdamWConfig, init_state, make_train_step

SMOKE = ShapeSpec("smoke", seq_len=32, global_batch=2, kind="train")
ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch + "-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = synthetic_batch_for(cfg, SMOKE)

    logits, aux = M.forward(cfg, params, batch, mode="train", remat=False)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    step = make_train_step(cfg, opt=AdamWConfig(lr=1e-3))
    opt = init_state(params)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    # parameters actually changed
    before = jax.tree_util.tree_leaves(params)[0]
    after = jax.tree_util.tree_leaves(p2)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).is_decoder])
def test_prefill_decode_consistency(arch):
    """decode(prefill(x[:-1]), x[-1]) == forward(x)[-1] — the serving path is
    numerically consistent with training."""
    cfg = get_config(arch + "-smoke").replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    full, _ = M.forward(cfg, params, {"tokens": toks}, mode="train",
                        remat=False)
    pf_logits, state = M.forward(cfg, params, {"tokens": toks[:, :S - 1]},
                                 mode="prefill", remat=False)

    def pad(x):
        if x.ndim >= 3 and x.shape[2] == S - 1:        # grow KV capacity by 1
            w = [(0, 0)] * x.ndim
            w[2] = (0, 1)
            return jnp.pad(x, w)
        return x

    state = {"caches": jax.tree_util.tree_map(pad, state["caches"]),
             "lengths": state["lengths"]}
    got, _ = M.decode_step(cfg, params, state, {"tokens": toks[:, S - 1:]})
    rel = float(jnp.abs(got - full[:, -1]).max()
                / (jnp.abs(full[:, -1]).max() + 1e-9))
    assert rel < 2e-3, f"{arch}: prefill+decode rel err {rel}"


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).is_decoder])
def test_decode_steps_finite(arch):
    cfg = get_config(arch + "-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = M.init_decode_state(cfg, 2, 16)
    tok = {"tokens": jnp.ones((2, 1), jnp.int32)}
    for _ in range(3):
        logits, state = M.decode_step(cfg, params, state, tok)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), arch
    assert int(state["lengths"][0]) == 3


def test_encoder_has_no_decode():
    cfg = get_config("hubert-xlarge-smoke")
    with pytest.raises(AssertionError):
        M.decode_step(cfg, {}, {"lengths": jnp.zeros(2, jnp.int32)}, {})


def test_chunked_ce_matches_full():
    cfg = get_config("llama3-8b-smoke").replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = synthetic_batch_for(cfg, SMOKE)
    l1, _ = M.train_loss(cfg, params, batch, remat=False, loss_chunks=1)
    l4, _ = M.train_loss(cfg, params, batch, remat=False, loss_chunks=4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)


def test_microbatched_step_matches_single():
    cfg = get_config("qwen3-1.7b-smoke").replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = synthetic_batch_for(
        cfg, ShapeSpec("smoke4", seq_len=32, global_batch=4, kind="train"))
    opt = AdamWConfig(lr=1e-3)
    s1 = make_train_step(cfg, opt=opt, microbatches=1)
    s2 = make_train_step(cfg, opt=opt, microbatches=2)
    st = init_state(params, opt)
    p1, _, m1 = jax.jit(s1)(params, st, batch)
    p2, _, m2 = jax.jit(s2)(params, st, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    a = jax.tree_util.tree_leaves(p1)[0]
    b = jax.tree_util.tree_leaves(p2)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_param_counts_match_published():
    expect = {"llama3-8b": 8.0e9, "dbrx-132b": 132e9,
              "llama4-maverick-400b-a17b": 400e9, "qwen3-1.7b": 1.7e9,
              "internlm2-20b": 20e9, "zamba2-2.7b": 2.7e9,
              "rwkv6-7b": 7.6e9, "starcoder2-7b": 7.2e9,
              "qwen2-vl-7b": 7.6e9, "hubert-xlarge": 1.0e9}
    for arch, n in expect.items():
        got = M.param_count(get_config(arch))
        assert abs(got - n) / n < 0.12, (arch, got, n)
