"""MoE routing/dispatch semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.arch import moe
from repro.configs import get_config


def _setup(E=4, k=2, d=32, ff=64, B=2, S=16, seed=0):
    cfg = get_config("dbrx-132b-smoke").replace(
        d_model=d, d_ff=ff, num_experts=E, num_experts_per_tok=k,
        dtype="float32")
    key = jax.random.PRNGKey(seed)
    from repro.arch.params import init_tree
    p = init_tree(moe.moe_specs(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, d), jnp.float32)
    return cfg, p, x


def test_dispatch_matches_dense_with_ample_capacity():
    cfg, p, x = _setup()
    y_dense, aux_d = moe.moe_block_dense(cfg, p, x)
    y_disp, aux_s = moe.moe_block_dispatch(cfg, p, x, capacity_factor=8.0,
                                           groups=4)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_disp),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux_d["moe_lb_loss"]),
                               float(aux_s["moe_lb_loss"]), rtol=1e-6)


def test_dispatch_drops_over_capacity():
    cfg, p, x = _setup(B=1, S=32)
    y_tight, _ = moe.moe_block_dispatch(cfg, p, x, capacity_factor=0.25,
                                        groups=1)
    y_ample, _ = moe.moe_block_dispatch(cfg, p, x, capacity_factor=8.0,
                                        groups=1)
    # dropping must change some outputs (tokens fell back to residual 0)
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_ample))
    # dropped-token outputs are exactly zero contribution
    norms = np.linalg.norm(np.asarray(y_tight), axis=-1)
    assert (norms < 1e-6).any()


def test_router_weights_normalised():
    cfg, p, x = _setup()
    w, idx, aux = moe._router(cfg, p, x)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-6)
    assert int(idx.max()) < cfg.num_experts
    assert aux["moe_lb_loss"] >= 1.0 - 1e-3          # >= 1 by Cauchy-Schwarz


def test_shared_expert_always_on():
    cfg, p, x = _setup()
    cfg2 = cfg.replace(n_shared_experts=1)
    from repro.arch.params import init_tree
    p2 = init_tree(moe.moe_specs(cfg2), jax.random.PRNGKey(3))
    y, _ = moe.moe_block_dispatch(cfg2, p2, x, capacity_factor=0.01, groups=1)
    # even with ~all tokens dropped, shared expert contributes
    assert float(np.abs(np.asarray(y)).max()) > 0
