"""Flow-typed deployments: prediction intervals end-to-end + the
minutely anomaly-detection flow (repro.flows, forecast/anomaly.py).

Contracts pinned here:

* every forecaster's q10-q90 band has sane empirical coverage on
  synthetic data (property test over seeds, all four model kinds);
* ``Castor.best_forecast(return_bands=True)`` honors ``at=`` replay;
* detection is replay-faithful: catch-up occurrences score bitwise equal
  to live minutely polling;
* the fleet-vectorized detection path (one read_many + one batched
  band-compare per bin) is bitwise equal to the per-sensor local path;
* detection runs over serverless — inline, chaos-injected, and real
  spawned process containers — with the same exactly-once guarantees as
  forecasting (store snapshots bitwise equal to the fleet run);
* per-flow deployment counts + detection telemetry in ``Castor.stats``.
"""
import functools

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import Schedule
from repro.forecast import (ANNForecaster, GAMForecaster, LSTMForecaster,
                            LinearForecaster)
from repro.forecast.anomaly import BandAnomalyDetector
from repro.serverless import ChaosPolicy, ProcessBackend, ServerlessExecutor
from repro.serverless.payload import (DetectionBlob, ForecastBlob,
                                      InvocationPayload, InvocationResult,
                                      JobRef)
from repro.testing import (FLEET_NOW as NOW, HOUR, MINUTE,
                           assert_stores_bitwise_equal,
                           build_detection_castor, build_steady_castor,
                           snapshot_stores)

FORECASTERS = {
    "lr": (LinearForecaster, {}),
    "gam": (GAMForecaster, {}),
    "ann": (ANNForecaster, {"hidden": 8, "epochs": 10}),
    "lstm": (LSTMForecaster, {"hidden": 4, "epochs": 10}),
}
TICKS = 45          # minutely detect polls driven per equivalence run
N = 3


def _detect_ticks(c, k, executor="fleet"):
    for i in range(1, k + 1):
        res = c.tick(NOW + i * MINUTE, executor=executor)
        assert all(r.ok for r in res), [r.error for r in res if not r.ok]


# ------------------------------------------------- prediction intervals
@pytest.mark.parametrize("kind", list(FORECASTERS))
def test_band_coverage_property(kind):
    """Property: for every forecaster, over drawn data seeds, the q10-q90
    band's empirical coverage of the ACTUAL future readings is within
    tolerance — neither degenerate (<50%) nor meaningless (band must
    have positive width)."""
    cls, hp = FORECASTERS[kind]

    @settings(max_examples=3, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def prop(seed):
        c = build_steady_castor(kind, cls, hp, n=2, seed=seed, site="C")
        res = c.tick(NOW, executor="fleet")
        assert all(r.ok for r in res), [r.error for r in res if not r.ok]
        for i in range(2):
            fc = c.best_forecast("ENERGY_LOAD", f"C_PRO_0_{i}")
            assert fc.lower is not None and fc.upper is not None
            assert fc.lower.shape == fc.values.shape == fc.upper.shape
            width = fc.upper - fc.lower
            assert np.all(width > 0), "degenerate band"
            at, av = c.read("ENERGY_LOAD", f"C_PRO_0_{i}",
                            fc.times[0] - HOUR, fc.times[-1] + HOUR)
            actual = np.interp(fc.times, at, av)
            cov = float(np.mean((actual >= fc.lower)
                                & (actual <= fc.upper)))
            assert cov >= 0.5, f"{kind} seed={seed}: coverage {cov:.2f}"

    prop()


def test_fleet_bands_match_local_bands():
    """The fleet scoring path derives the SAME residual-quantile bands as
    per-instance score() — bands ride the local==fleet equivalence."""
    ca = build_steady_castor("lr", LinearForecaster, {}, n=N)
    cb = build_steady_castor("lr", LinearForecaster, {}, n=N)
    assert all(r.ok for r in ca.tick(NOW, executor="fleet"))
    assert all(r.ok for r in cb.tick(NOW, executor="local"))
    for i in range(N):
        fa = ca.predictions.history(f"s-Z_PRO_0_{i}")[-1]
        fb = cb.predictions.history(f"s-Z_PRO_0_{i}")[-1]
        np.testing.assert_allclose(fa.lower, fb.lower, rtol=2e-3, atol=1e-3)
        np.testing.assert_allclose(fa.upper, fb.upper, rtol=2e-3, atol=1e-3)


def test_best_forecast_return_bands_with_at_replay():
    """Satellite regression: ``return_bands=True`` returns (times, values,
    lower, upper) and honors the existing ``at=`` replay semantics — the
    band at a past instant is the band a live consumer had then."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=2)
    assert all(r.ok for r in c.tick(NOW))
    assert all(r.ok for r in c.tick(NOW + HOUR))
    ent = "Z_PRO_0_0"
    assert len(c.predictions.history("s-" + ent)) == 2
    t, v, lo, hi = c.best_forecast("ENERGY_LOAD", ent, return_bands=True)
    latest = c.predictions.latest("ENERGY_LOAD", ent)
    assert latest.created_at == NOW + HOUR
    np.testing.assert_array_equal(v, latest.values)
    np.testing.assert_array_equal(lo, latest.lower)
    np.testing.assert_array_equal(hi, latest.upper)
    assert np.all(lo < hi)
    # at= replays the EARLIER forecast's band, not the latest
    t0, v0, lo0, hi0 = c.best_forecast("ENERGY_LOAD", ent,
                                       at=NOW + 30 * MINUTE,
                                       return_bands=True)
    first = c.predictions.history("s-" + ent)[0]
    assert first.created_at == NOW
    np.testing.assert_array_equal(v0, first.values)
    np.testing.assert_array_equal(lo0, first.lower)
    np.testing.assert_array_equal(hi0, first.upper)
    assert not np.array_equal(lo0, lo)
    assert c.best_forecast("ENERGY_LOAD", ent, at=NOW - HOUR,
                           return_bands=True) is None


# ------------------------------------------------- detection semantics
@pytest.fixture(scope="module")
def detected():
    """One detection castor driven through TICKS minutely fleet polls —
    shared by the semantics/stats assertions below (read-only)."""
    c = build_detection_castor(n=N)
    _detect_ticks(c, TICKS)
    return c


def test_detection_flags_the_anomalous_sensor(detected):
    """The spiked sensor's derived anomaly series goes large after the
    spike; in-band sensors stay at ~0 throughout."""
    c = detected
    for i in range(N):
        recs = c.detections.history(f"d-D_PRO_0_{i}")
        assert len(recs) == TICKS
        assert [r.scheduled_at for r in recs] == \
            [NOW + k * MINUTE for k in range(1, TICKS + 1)]
    # builder spikes from reading 75//2 (time NOW+38min); each occurrence
    # scores the half-open window [now-60s, now), so the first spiked
    # reading lands in the occurrence at NOW+39min
    spike_from = NOW + (75 // 2 + 2) * MINUTE
    bad = [r for r in c.detections.history("d-D_PRO_0_0")
           if r.scheduled_at >= spike_from]
    assert bad and all(r.score > 1.0 for r in bad), \
        [(r.scheduled_at, r.score) for r in bad]
    assert all(r.n_anomalies >= 1 for r in bad)
    for i in range(1, N):
        scores = [r.score for r in c.detections.history(f"d-D_PRO_0_{i}")]
        assert max(scores) < 1.0, max(scores)


def test_detection_derived_signal_readable_through_graph(detected):
    """The anomaly score is a first-class derived signal on the semantic
    graph: registered once, one point per occurrence, queryable via
    ``Castor.read`` like any ingested series."""
    c = detected
    assert "ENERGY_LOAD.anomaly" in c.graph.signals
    for i in range(N):
        t, v = c.read("ENERGY_LOAD.anomaly", f"D_PRO_0_{i}")
        assert t.size == TICKS
        recs = c.detections.history(f"d-D_PRO_0_{i}")
        np.testing.assert_array_equal(t, [r.scheduled_at for r in recs])
        np.testing.assert_array_equal(v, [r.score for r in recs])


def test_detection_telemetry_in_stats(detected):
    """Satellite: per-flow deployment counts + detection telemetry
    surface through ``Castor.stats``."""
    s = detected.stats()
    assert s["deployments_by_flow"] == {"detection": N, "forecast": N}
    d = s["detection"]
    assert d["records"] == N * TICKS
    assert d["scored_readings"] >= N * (TICKS - 1)
    assert d["anomalies_flagged"] >= 1
    # every reading here sits inside the fresh band's horizon
    assert d["band_misses"] == 0 and d["band_miss_rate"] == 0.0


def test_stale_band_counts_misses():
    """A detection firing past the resolved band's horizon counts its
    readings as band MISSES (telemetry, not anomalies) — and the miss
    rate surfaces through stats."""
    c = build_detection_castor(n=2)
    # freeze the forecast flow so the NOW band (24h horizon) goes stale
    for i in range(2):
        c.undeploy(f"s-D_PRO_0_{i}")
        c.ingest(c.graph.context("ENERGY_LOAD", f"D_PRO_0_{i}").ts_id,
                 [NOW + 25 * HOUR + 90.0], [3.0])
    res = c.tick(NOW + 25 * HOUR + 2 * MINUTE, executor="fleet")
    detects = [r for r in res if r.job.task == "detect"]
    assert len(detects) == 2 and all(r.ok for r in detects)
    d = c.detections.stats()
    assert d["band_misses"] == 2
    assert d["anomalies_flagged"] == 0
    assert 0.0 < d["band_miss_rate"] <= 1.0
    for i in range(2):
        rec = c.detections.history(f"d-D_PRO_0_{i}")[-1]
        assert rec.band_misses == 1 and rec.score == 0.0


def test_detection_store_idempotent_and_derived_append_once(detected):
    """Exactly-once surface: re-saving an already-seen occurrence must
    neither duplicate the record nor double-append the derived series."""
    c = detected
    rec = c.detections.history("d-D_PRO_0_0")[-1]
    before = snapshot_stores(c)
    c.detections.save(rec)
    c.detections.save_many([rec, rec])
    assert_stores_bitwise_equal(before, c, context="duplicate save")


def test_fleet_detection_bitwise_equals_local(detected):
    """Tentpole acceptance: the fleet-vectorized bin path (one read_many,
    one batched band-compare) persists detections + derived series
    bitwise identical to the per-sensor local-pool path."""
    cb = build_detection_castor(n=N)
    _detect_ticks(cb, TICKS, executor="local")
    assert_stores_bitwise_equal(detected, cb, context="fleet vs local")


def test_catchup_detection_bitwise_equals_live(detected):
    """Replay-faithfulness: ONE catch-up poll at the end of the window
    (scheduler re-fires every missed minutely boundary, each resolving
    the band via at=scheduled_at) scores bitwise equal to minute-by-
    minute live polling."""
    cb = build_detection_castor(n=N)
    # first poll establishes the watermark (a never-polled deployment
    # fires once); the second poll catches up every missed boundary
    assert all(r.ok for r in cb.tick(NOW + MINUTE, executor="fleet"))
    res = cb.tick(NOW + TICKS * MINUTE, executor="fleet")
    assert len([r for r in res if r.job.task == "detect"]) \
        == N * (TICKS - 1)
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    assert_stores_bitwise_equal(detected, cb, context="live vs catchup")


def test_detection_before_any_banded_forecast_fails_alone():
    """A detect job whose context has no banded forecast yet fails ALONE
    (at-least-once re-fire), without poisoning sibling detections."""
    c = build_detection_castor(n=N)
    # a fresh context with a detection deployment but no forecast flow
    c.add_entity("D_PRO_9_9", "PROSUMER")
    ts = "ts::cold"
    c.ingest(ts, [NOW + MINUTE / 2], [1.0])
    c.link(ts, "ENERGY_LOAD", "D_PRO_9_9")
    c.deploy_detections(package="anom", signal="ENERGY_LOAD",
                        name_prefix="x", kind="PROSUMER",
                        detect=Schedule(NOW + MINUTE, MINUTE))
    res = c.tick(NOW + MINUTE, executor="fleet")
    bad = [r for r in res if not r.ok]
    assert len(bad) == 1 and "no banded forecast" in bad[0].error
    assert bad[0].job.deployment_name == "x-D_PRO_9_9"
    # the d-* fleet AND the banded x-* siblings all detected fine
    assert sum(r.ok for r in res if r.job.task == "detect") == 2 * N


# ------------------------------------------------- serverless parity
def test_serverless_detection_bitwise_equals_fleet(detected):
    """Detection bins dispatch over the serverless pipeline (warm
    workers, action aggregation) with effects bitwise equal to fleet."""
    cb = build_detection_castor(n=N)
    _detect_ticks(cb, TICKS, executor="serverless")
    assert_stores_bitwise_equal(detected, cb, context="fleet vs serverless")
    cb.close()


@pytest.mark.parametrize("fault", ["kill", "duplicate"])
def test_serverless_detection_chaos_exactly_once(detected, fault):
    """Exactly-once under chaos, detection flow included: kill-mid-action
    (partial persisted bins + retry) and duplicate delivery leave the
    detection store AND the derived anomaly series bitwise identical to
    the fault-free fleet run — idempotence gates the derived append."""
    chaos = ChaosPolicy(seed=17, **{"kill_mid_action" if fault == "kill"
                                    else "duplicate": 1.0})
    cb = build_detection_castor(n=N)
    ex = ServerlessExecutor(cb, n_workers=2, chaos=chaos, max_retries=3,
                            backoff_base_s=0.01, speculative=False)
    cb._serverless_ex = ex
    try:
        _detect_ticks(cb, TICKS, executor="serverless")
        assert chaos.summary().get(fault, 0) >= 1, chaos.summary()
        assert_stores_bitwise_equal(detected, cb,
                                    context=f"chaos {fault}")
    finally:
        cb.close()


def test_process_backend_detection_matches_fleet(detected):
    """Real spawned containers: detect jobs ship with their banded
    forecasts in the payload, workers ship DetectionBlobs back, and the
    invoker's stores converge bitwise with the fleet run."""
    factory = functools.partial(build_detection_castor, n=N)
    c = factory()
    ex = ServerlessExecutor(c, backend=ProcessBackend(factory, n_workers=1),
                            speculative=False)
    c._serverless_ex = ex
    try:
        _detect_ticks(c, 3, executor="serverless")
        ref = build_detection_castor(n=N)
        _detect_ticks(ref, 3)
        assert_stores_bitwise_equal(ref, c, context="process vs fleet")
        assert c.detections.count() == 3 * N
    finally:
        c.close()


def test_payload_roundtrips_bands_and_detections_bitwise():
    """JSON wire format: banded-forecast payloads and detection results
    survive the serialization boundary bitwise."""
    job = JobRef("d0", "anom", "1.0", "detect", NOW, "ENERGY_LOAD", "E0")
    fb = ForecastBlob("s0", "ENERGY_LOAD", "E0", NOW,
                      times=NOW + HOUR * np.arange(1.0, 4.0),
                      values=np.array([1.0, 2.0, 3.0]),
                      model_version=2, rank=1,
                      lower=np.array([0.5, 1.4, 2.2]),
                      upper=np.array([1.5, 2.6, 3.8]))
    p = InvocationPayload(invocation_id="inv-1", jobs=(job,), bands=(fb,))
    q = InvocationPayload.from_json(p.to_json())
    got = q.bands[0]
    for f in ("times", "values", "lower", "upper"):
        a, b = getattr(got, f), getattr(fb, f)
        assert a.dtype == b.dtype and np.array_equal(a, b)
    db = DetectionBlob("d0", "ENERGY_LOAD", "E0", NOW + MINUTE,
                       score=0.125, n_readings=7, n_anomalies=2,
                       band_misses=1, model_version=2,
                       derived_signal="ENERGY_LOAD.anomaly")
    r = InvocationResult(invocation_id="inv-1", worker_id="w0",
                         cold_start=False, started_at=1.0, finished_at=2.0,
                         outcomes=(), detections=(db,))
    assert InvocationResult.from_json(r.to_json()).detections == (db,)


def test_fleet_detect_classmethod_bitwise_equals_per_sensor(detected):
    """Unit-level pin of the vectorized kernel itself: fleet_detect over
    a bin == N per-sensor detect() calls, field for field."""
    c = detected
    at = NOW + 40 * MINUTE
    insts, bands = [], []
    for i in range(N):
        ent = f"D_PRO_0_{i}"
        fc = c.predictions.latest("ENERGY_LOAD", ent, at=at)
        bands.append(fc)
        insts.append(BandAnomalyDetector(
            context=c.graph.context("ENERGY_LOAD", ent), task="detect",
            model_id=f"d-{ent}", model_version=None,
            user_params={"now": at}, system=c))
    fleet = BandAnomalyDetector.fleet_detect(insts, bands)
    for inst, fc, fr in zip(insts, bands, fleet):
        assert inst.detect(fc) == fr
