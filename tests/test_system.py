"""End-to-end behaviour of the full Castor workflow (paper Fig. 1):
ingest -> semantics -> publish -> programmatic deploy -> schedule ->
fleet-execute -> lineage -> semantic retrieval with ranking."""
import numpy as np

from repro.core import Castor, ModelDeployment, Schedule, DAY, HOUR
from repro.forecast import GAMForecaster, LinearForecaster
from repro.timeseries.ingest import SiteSpec, build_site
from repro.timeseries.transforms import mape


def test_full_workflow():
    c = Castor()
    site = build_site(c, SiteSpec("CY", n_prosumers=4, n_feeders=2,
                                  n_substations=1, seed=5),
                      t0=0.0, t1=50 * DAY)
    assert site["readings"] > 0
    now = 45 * DAY

    c.publish("castor-lr", "1.0", LinearForecaster)
    c.publish("castor-gam", "1.0", GAMForecaster)

    # programmatic fleet deployment from a semantic rule
    deps = c.deploy_for_all(package="castor-lr", signal="ENERGY_LOAD",
                            name_prefix="lr", kind="PROSUMER",
                            train=Schedule(now, 7 * DAY),
                            score=Schedule(now, HOUR),
                            user_params={"train_window_days": 14})
    assert len(deps) == 4

    # two ranked models on the substation
    for rank, pkg in [(0, "castor-gam"), (1, "castor-lr")]:
        c.deploy(ModelDeployment(
            name=f"{pkg}-sub", package=pkg, signal="ENERGY_LOAD",
            entity="CY_SUB_0", train=Schedule(now, 7 * DAY),
            score=Schedule(now, HOUR),
            user_params={"train_window_days": 14}, rank=rank))

    r1 = c.tick(now, executor="fleet")
    assert len(r1) == 12 and all(r.ok for r in r1)   # 6 trains + 6 scores
    r2 = c.tick(now + HOUR, executor="fleet")
    assert len(r2) == 6 and all(r.ok for r in r2)    # scores only

    # rolling-horizon lineage: two forecasts per deployment, none overwritten
    assert len(c.predictions.history("castor-gam-sub")) == 2

    # ranked retrieval by semantics only
    best = c.best_forecast("ENERGY_LOAD", "CY_SUB_0")
    assert best.deployment_name == "castor-gam-sub"

    # forecasts are usable: MAPE sane vs actuals
    t, actual = c.read("ENERGY_LOAD", "CY_SUB_0", best.times[0] - 1,
                       best.times[-1] + 1)
    n = min(len(actual), len(best.values))
    assert mape(actual[:n], best.values[:n]) < 25.0

    # model versions persisted with metadata
    mv = c.versions.get("castor-gam-sub")
    assert mv is not None and mv.version == 1

    # Fig. 7 multi-horizon view exists for an overlapping target hour
    target = float(best.times[0])
    hz = c.predictions.horizons("castor-gam-sub", target)
    assert len(hz) >= 2


def test_growth_auto_deploy():
    """The application grows as sensors are added (paper §3.2)."""
    c = Castor()
    build_site(c, SiteSpec("G", n_prosumers=2, n_feeders=1,
                           n_substations=1, seed=1), t0=0.0, t1=30 * DAY)
    c.publish("lr", "1.0", LinearForecaster)
    first = c.deploy_for_all(package="lr", signal="ENERGY_LOAD",
                             name_prefix="a", kind="PROSUMER",
                             score=Schedule(0.0, HOUR))
    # new sensor arrives later
    c.add_entity("G_PRO_NEW", "PROSUMER", parent="G_FD_0_0")
    c.ingest("raw::new", np.arange(0, 10) * 3600.0, np.ones(10))
    c.link("raw::new", "ENERGY_LOAD", "G_PRO_NEW")
    second = c.deploy_for_all(package="lr", signal="ENERGY_LOAD",
                              name_prefix="b", kind="PROSUMER",
                              score=Schedule(0.0, HOUR))
    assert len(second) == len(first) + 1
