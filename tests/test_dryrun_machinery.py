"""Integration test of the dry-run cell machinery on 8 placeholder devices
(subprocess: the device-count override must precede jax init, and the main
test process must keep its single real device)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.launch.cells import build_cell, lower_cell
    from repro.launch.mesh import make_mesh
    from repro.launch import hlo_cost
    from repro.distributed.sharding import serve_rules

    mesh = make_mesh((2, 4), ("data", "model"))
    out = {}
    for tag, kw in [("baseline", {}),
                    ("optimized", dict(rules=serve_rules(False),
                                       dist_decode=True))]:
        cell = build_cell("qwen3-1.7b", "decode_32k", mesh, **kw)
        compiled = lower_cell(cell).compile()
        cost = hlo_cost.analyze(compiled.as_text(), 8)
        mem = compiled.memory_analysis()
        out[tag] = {"flops": cost.flops, "bytes": cost.bytes,
                    "wire": cost.collective_wire_bytes,
                    "temp": mem.temp_size_in_bytes}
    # train cell lowers too (microbatching + FSDP path)
    cell = build_cell("qwen3-1.7b", "train_4k", mesh)
    compiled = lower_cell(cell).compile()
    cost = hlo_cost.analyze(compiled.as_text(), 8)
    out["train"] = {"flops": cost.flops, "wire": cost.collective_wire_bytes}
    print(json.dumps(out))
""")


def test_cells_compile_and_analyze_on_8_devices():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=520,
        env={"PYTHONPATH": str(Path(__file__).parent.parent / "src"),
             "PATH": "/usr/bin:/bin",
             # without this, jax probes for accelerator plugins and hangs
             # on hosts with a baked-in (but absent) TPU toolchain
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    # decode cells: optimized layout must slash collective wire bytes
    assert out["optimized"]["wire"] < out["baseline"]["wire"] * 0.5, out
    # train flops per device at 8 devices: 6*N*D/8 within remat factor bounds
    n, d = 1.72e9, 256 * 4096
    model = 6 * n * d / 8
    assert 0.8 * model < out["train"]["flops"] < 2.0 * model, out["train"]
