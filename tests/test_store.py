"""Columnar compacting TimeSeriesStore: compaction invariants, batched
reads, and the FleetExecutor one-read_many-per-bin contract."""
import numpy as np
import pytest

from repro.core import Castor, ModelDeployment, Schedule
from repro.core.executor import FleetExecutor, LocalPoolExecutor
from repro.forecast import LinearForecaster
from repro.timeseries.store import TimeSeriesStore
from repro.timeseries.transforms import DAY, HOUR


def _reference(batches, start=None, end=None):
    """The seed store's semantics: concat everything, stable sort, slice."""
    t = np.concatenate([np.asarray(b[0], np.float64).ravel() for b in batches])
    v = np.concatenate([np.asarray(b[1], np.float64).ravel() for b in batches])
    order = np.argsort(t, kind="stable")
    t, v = t[order], v[order]
    lo = np.searchsorted(t, start) if start is not None else 0
    hi = np.searchsorted(t, end) if end is not None else t.size
    return t[lo:hi], v[lo:hi]


def _check_invariants(store, ts_id):
    s = store._data[ts_id]
    n_seg = sum(seg.n for seg in s.segments)
    assert n_seg + s.tail_n == s.count          # nothing lost or duplicated
    for seg in s.segments:
        assert np.all(np.diff(seg.times) >= 0)  # each segment sorted
        assert not seg.times.flags.writeable    # immutable columnar runs
        assert not seg.values.flags.writeable


# ---------------- ordering semantics ----------------
def test_out_of_order_appends_sorted_reads():
    st = TimeSeriesStore(tail_max=8)
    batches = [([5.0, 1.0, 9.0], [50, 10, 90]),
               ([3.0, 7.0], [30, 70]),
               ([0.5, 6.5, 2.5, 8.5], [5, 65, 25, 85])]
    for t, v in batches:
        st.append("x", t, v)
    rt, rv = st.read("x")
    et, ev = _reference(batches)
    np.testing.assert_array_equal(rt, et)
    np.testing.assert_array_equal(rv, ev)
    _check_invariants(st, "x")


def test_duplicate_timestamps_preserve_append_order():
    st = TimeSeriesStore(tail_max=2)   # force compactions between appends
    st.append("x", [5.0, 5.0], [1, 2])
    st.append("x", [5.0, 3.0], [3, 30])
    st.append("x", [5.0], [4])
    t, v = st.read("x")
    np.testing.assert_array_equal(t, [3.0, 5.0, 5.0, 5.0, 5.0])
    np.testing.assert_array_equal(v, [30, 1, 2, 3, 4])   # stable across merges


def test_range_read_half_open():
    st = TimeSeriesStore()
    st.append("x", [3.0, 1.0, 2.0], [30, 10, 20])
    t, v = st.read("x", 1.5, 3.0)                        # [start, end)
    assert list(t) == [2.0] and list(v) == [20]
    t, v = st.read("x", 1.0, 3.0)                        # start inclusive
    assert list(t) == [1.0, 2.0]


def test_read_straddles_compacted_and_tail():
    """Windows spanning sorted segments AND the unsorted tail are exact."""
    rng = np.random.default_rng(0)
    st = TimeSeriesStore(tail_max=16)
    batches = []
    for _ in range(20):                 # 200 points, many compactions
        t = rng.uniform(0, 1000, 10)
        v = rng.normal(size=10)
        batches.append((t, v))
        st.append("x", t, v)
    assert st._data["x"].segments       # some data compacted
    # last small batch stays in the tail
    t = rng.uniform(0, 1000, 3)
    v = rng.normal(size=3)
    batches.append((t, v))
    st.append("x", t, v)
    for start, end in [(None, None), (0.0, 500.0), (250.0, 750.0),
                       (999.0, 1001.0), (-5.0, 0.0)]:
        rt, rv = st.read("x", start, end)
        et, ev = _reference(batches, start, end)
        np.testing.assert_array_equal(rt, et)
        np.testing.assert_array_equal(rv, ev)
    _check_invariants(st, "x")


def test_randomized_interleaved_append_read_matches_reference():
    rng = np.random.default_rng(7)
    st = TimeSeriesStore(tail_max=32)
    batches = []
    for i in range(60):
        n = int(rng.integers(1, 40))
        t = rng.uniform(0, 1e4, n)
        dup = rng.random(n) < 0.2
        t[dup] = np.round(t[dup])               # inject duplicate timestamps
        v = rng.normal(size=n)
        batches.append((t, v))
        st.append("x", t, v)
        if i % 7 == 0:
            lo = float(rng.uniform(0, 1e4))
            hi = lo + float(rng.uniform(0, 5e3))
            rt, rv = st.read("x", lo, hi)
            et, ev = _reference(batches, lo, hi)
            np.testing.assert_array_equal(rt, et)
            np.testing.assert_array_equal(rv, ev)
    assert st.length("x") == sum(len(b[0]) for b in batches)


# ---------------- O(1) metadata ----------------
def test_last_first_time_without_consolidation():
    st = TimeSeriesStore(tail_max=1 << 30)   # nothing ever compacts
    st.append("x", [5.0, 2.0], [1, 1])
    st.append("x", [9.0, 0.5], [1, 1])
    assert st.last_time("x") == 9.0
    assert st.first_time("x") == 0.5
    assert st._data["x"].segments == []      # answered from metadata alone
    assert st.last_time("missing") is None


# ---------------- batched reads ----------------
def test_read_many_matches_individual_reads_and_counts_one_call():
    rng = np.random.default_rng(1)
    st = TimeSeriesStore(tail_max=64)
    ids = [f"s{i}" for i in range(8)]
    for i, ts in enumerate(ids):
        n = 50 + 10 * i
        st.append(ts, rng.uniform(0, 100, n), rng.normal(size=n))
    singles = [st.read(ts, 10.0, 90.0) for ts in ids]
    before_rm, before_r = st.read_many_count, st.read_count
    batch = st.read_many(ids + ["unknown"], 10.0, 90.0)
    assert st.read_many_count == before_rm + 1
    assert st.read_count == before_r            # no hidden per-series reads
    for (et, ev), (bt, bv) in zip(singles, batch[:-1]):
        np.testing.assert_array_equal(et, bt)
        np.testing.assert_array_equal(ev, bv)
    assert batch[-1][0].size == 0                # unknown id -> empty


def test_read_window_batch_shapes_and_mask():
    st = TimeSeriesStore()
    st.append("a", [1.0, 2.0, 3.0], [10, 20, 30])
    st.append("b", [2.5], [25])
    times, values, mask = st.read_window_batch(["a", "b", "c"], 0.0, 10.0)
    assert times.shape == values.shape == mask.shape == (3, 3)
    np.testing.assert_array_equal(mask, [[True, True, True],
                                         [True, False, False],
                                         [False, False, False]])
    np.testing.assert_array_equal(values[0], [10, 20, 30])
    assert values[1, 0] == 25 and values[1, 1] == 0.0    # zero padding
    # all-empty window
    t2, v2, m2 = st.read_window_batch(["c"], 0.0, 10.0)
    assert t2.shape == (1, 0) and not m2.any()


# ---------------- persistence ----------------
def test_save_load_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    st = TimeSeriesStore(tail_max=16)
    st.append("a", rng.uniform(0, 100, 50), rng.normal(size=50))
    st.append("a", rng.uniform(0, 100, 7), rng.normal(size=7))  # tail data
    st.append("b::x", [0.5], [9])
    st.save(str(tmp_path))
    st2 = TimeSeriesStore.load(str(tmp_path))
    assert set(st2.ids()) == {"a", "b::x"}
    for ts in ("a", "b::x"):
        t1, v1 = st.read(ts)
        t2, v2 = st2.read(ts)
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_array_equal(v1, v2)
        _check_invariants(st2, ts)


# ---------------- compaction machinery ----------------
def test_compaction_bounds_segments_and_conserves_points():
    rng = np.random.default_rng(3)
    st = TimeSeriesStore(tail_max=64)
    total = 0
    for _ in range(200):
        n = int(rng.integers(1, 50))
        st.append("x", rng.uniform(0, 1e6, n), rng.normal(size=n))
        total += n
        _check_invariants(st, "x")
    s = st._data["x"]
    assert len(s.segments) <= int(np.log2(max(total, 2))) + 2   # tiered bound
    assert st.compaction_count > 0 and st.merge_count > 0
    st.compact("x")
    assert len(s.segments) == 1 and s.tail_n == 0
    assert s.segments[0].n == total == st.length("x")
    assert np.all(np.diff(s.segments[0].times) >= 0)


def test_small_appends_between_reads_do_not_rewrite_history():
    """Steady interleaved append/read must NOT consolidate the full series
    on every read — dirty data below 1/8 of the series is served via an
    ephemeral window merge (amortized O(log n + k) reads)."""
    rng = np.random.default_rng(8)
    st = TimeSeriesStore(tail_max=1024)
    st.append("x", rng.uniform(0, 1e6, 20_000), rng.normal(size=20_000))
    st.read("x")                        # consolidates once
    merged0 = st.merged_points
    ref = [(st.read("x")[0].copy(), st.read("x")[1].copy())]
    for _ in range(50):
        t = rng.uniform(0, 1e6, 5)
        v = rng.normal(size=5)
        ref.append((t, v))
        st.append("x", t, v)
        rt, rv = st.read("x", 2e5, 3e5)
        et, ev = _reference(ref, 2e5, 3e5)
        np.testing.assert_array_equal(rt, et)   # exact despite no rewrite
        np.testing.assert_array_equal(rv, ev)
    assert st.merged_points == merged0          # 20k history never re-merged


def test_repeated_reads_do_not_recompact():
    st = TimeSeriesStore(tail_max=8)
    rng = np.random.default_rng(4)
    st.append("x", rng.uniform(0, 10, 100), rng.normal(size=100))
    st.read("x")
    merges = st.merge_count
    compactions = st.compaction_count
    for _ in range(10):
        st.read("x", 2.0, 8.0)
    assert st.merge_count == merges             # later reads are pure slices
    assert st.compaction_count == compactions


# ---------------- fleet executor contract ----------------
def _small_castor(n_entities=4):
    c = Castor()
    c.add_signal("ENERGY_LOAD", "kWh")
    rng = np.random.default_rng(5)
    t = np.arange(0.0, 30 * DAY, HOUR)
    for i in range(n_entities):
        c.add_entity(f"P{i}", "PROSUMER", lat=35.0, lon=33.0 + 0.01 * i)
        hod = (t % DAY) / HOUR
        load = 2 + np.sin(2 * np.pi * hod / 24) + rng.normal(0, 0.05, t.size)
        c.ingest(f"ts{i}", t, load)
        c.link(f"ts{i}", "ENERGY_LOAD", f"P{i}")
    return c


def test_fleet_executor_issues_one_read_many_per_bin():
    """Acceptance criterion: a FleetExecutor score bin fetches all its
    series with ONE store.read_many call and ZERO single read()s."""
    c = _small_castor(4)
    now = 28 * DAY
    c.publish("lr", "1.0", LinearForecaster)
    c.deploy_for_all(package="lr", signal="ENERGY_LOAD", name_prefix="m",
                     kind="PROSUMER", train=Schedule(now, 1e12),
                     score=Schedule(now, HOUR),
                     user_params={"train_window_days": 7})
    res = c.tick(now, executor="fleet")          # train + first score
    assert all(r.ok for r in res), [r.error for r in res]

    jobs = c.scheduler.poll(now + HOUR)          # one score bin of 4 jobs
    assert len(jobs) == 4 and len({j.bin_key for j in jobs}) == 1
    fx = FleetExecutor(c)
    rm0, r0 = c.store.read_many_count, c.store.read_count
    res = fx.run(jobs)
    assert all(r.ok for r in res), [r.error for r in res]
    assert c.store.read_many_count - rm0 == 1    # ONE batched fetch per bin
    assert c.store.read_count - r0 == 0          # no per-instance reads
    assert len(fx.last_bin_stats) == 1
    assert fx.last_bin_stats[0]["read_many_calls"] == 1
    assert fx.last_bin_stats[0]["single_reads"] == 0


def test_fleet_and_local_predictions_identical():
    """Observational equivalence: scoring the same trained version through
    either executor yields identical forecasts."""
    def run(executor):
        c = _small_castor(3)
        now = 28 * DAY
        c.publish("lr", "1.0", LinearForecaster)
        c.deploy_for_all(package="lr", signal="ENERGY_LOAD", name_prefix="m",
                         kind="PROSUMER", train=Schedule(now, 1e12),
                         score=Schedule(now, HOUR),
                         user_params={"train_window_days": 7})
        assert all(r.ok for r in c.tick(now, executor="fleet"))  # same train
        jobs = c.scheduler.poll(now + HOUR)
        ex = FleetExecutor(c) if executor == "fleet" \
            else LocalPoolExecutor(c, max_parallel=4)
        assert all(r.ok for r in ex.run(jobs))
        return {f"m-P{i}": c.predictions.history(f"m-P{i}")[-1]
                for i in range(3)}

    fleet = run("fleet")
    local = run("local")
    assert fleet.keys() == local.keys()
    for k in fleet:
        np.testing.assert_array_equal(fleet[k].times, local[k].times)
        np.testing.assert_allclose(fleet[k].values, local[k].values,
                                   rtol=1e-5, atol=1e-6)


def test_empty_window_equivalent_across_executors():
    """An entity with no data in the train window gets the same outcome
    (zero-filled history, job ok) through both executors — one dead sensor
    must not poison a fleet bin nor diverge from the pool path."""
    def run(executor):
        c = _small_castor(2)
        now = 28 * DAY
        # dead sensor: linked series with data only far before the window
        c.add_entity("P_dead", "PROSUMER", lat=35.0, lon=34.0)
        c.ingest("ts_dead", [1.0, 2.0], [5.0, 5.0])
        c.link("ts_dead", "ENERGY_LOAD", "P_dead")
        c.publish("lr", "1.0", LinearForecaster)
        c.deploy_for_all(package="lr", signal="ENERGY_LOAD", name_prefix="m",
                         kind="PROSUMER", train=Schedule(now, 1e12),
                         score=Schedule(now, HOUR),
                         user_params={"train_window_days": 7})
        res = c.tick(now, executor=executor)
        return c, {(r.job.deployment_name, r.job.task): r.ok for r in res}

    cf, fleet = run("fleet")
    cl, local = run("local")
    assert fleet == local                       # identical per-job outcomes
    assert all(fleet.values()), fleet           # zero-fill semantics: jobs ok
    f = cf.predictions.history("m-P_dead")[-1]
    l = cl.predictions.history("m-P_dead")[-1]
    np.testing.assert_array_equal(f.times, l.times)
    np.testing.assert_allclose(f.values, l.values, rtol=1e-5, atol=1e-6)


def test_fleet_bins_split_by_execution_time():
    """Jobs from different polls carry different scheduled_at and a fleet
    score bin shares ONE execution time axis — scheduled_at is part of the
    bin key, so mixed-poll jobs execute as separate bins, each stamped at
    its own time (batching them would silently skew calendar features)."""
    c = _small_castor(2)
    now = 28 * DAY
    c.publish("lr", "1.0", LinearForecaster)
    c.deploy_for_all(package="lr", signal="ENERGY_LOAD", name_prefix="m",
                     kind="PROSUMER", train=Schedule(now, 1e12),
                     score=Schedule(now, HOUR),
                     user_params={"train_window_days": 7})
    assert all(r.ok for r in c.tick(now, executor="fleet"))
    mixed = c.scheduler.poll(now + HOUR) + c.scheduler.poll(now + 2 * HOUR)
    assert len({j.scheduled_at for j in mixed}) == 2
    fx = FleetExecutor(c)
    res = fx.run(mixed)
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    assert len(fx.last_bin_stats) == 2          # one bin per poll time
    for i in range(2):
        created = [f.created_at for f in c.predictions.history(f"m-P{i}")]
        assert created == [now, now + HOUR, now + 2 * HOUR]


def test_fleet_score_mixed_now_instances_fail_loudly():
    """Model-layer backstop behind the bin split: calling fleet_score
    directly on instances with mixed execution times must refuse rather
    than silently compute wrong calendar features."""
    c = _small_castor(2)
    now = 28 * DAY
    up = {"train_window_days": 7, "now": now}
    insts = [LinearForecaster(
        context=c.graph.context("ENERGY_LOAD", f"P{i}"), task="score",
        model_id=f"x{i}", model_version=None,
        user_params={**up, "now": now + i * HOUR}, system=c)
        for i in range(2)]
    trained = LinearForecaster.fleet_train(insts)
    with pytest.raises(RuntimeError, match="mixes execution times"):
        LinearForecaster.fleet_score(insts, trained)


def test_castor_semantic_read_many():
    c = _small_castor(3)
    pairs = [("ENERGY_LOAD", f"P{i}") for i in range(3)]
    batch = c.read_many(pairs, 0.0, DAY)
    assert len(batch) == 3
    for i, (t, v) in enumerate(batch):
        et, ev = c.read("ENERGY_LOAD", f"P{i}", 0.0, DAY)
        np.testing.assert_array_equal(t, et)
        np.testing.assert_array_equal(v, ev)
