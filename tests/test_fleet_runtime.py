"""Steady-state FleetRuntime: warm polls are O(delta), retrace-free, and
observationally equivalent to the cold fleet path and to LocalPool.

Contracts pinned here:
  * repeated-poll forecasts: runtime(warm) == cold fleet == LocalPool
    (LocalPool for the deterministic closed-form models; ANN/LSTM fleet
    training seeds differ from per-instance training by design)
  * zero retraces after warmup, INCLUDING across two different bin sizes
    that land in the same shape bucket
  * warm telemetry: cache_hit, delta_rows == steps since last poll, one
    watermark-delta store read, no single reads
  * invalidation: late (out-of-order) appends, now regression, and the
    runtime/rollout opt-outs all fall back to the cold path correctly
  * the batched weather service is bitwise the per-instance calls
  * the rollout compile cache is LRU-bounded with live hit/miss counters
"""
import numpy as np
import pytest

from repro.core.executor import FleetExecutor, LocalPoolExecutor
from repro.core.runtime import FleetRuntime
from repro.forecast import (ANNForecaster, GAMForecaster, LSTMForecaster,
                            LinearForecaster)
from repro.testing import (FLEET_ATOL, FLEET_NOW as NOW, FLEET_RTOL, HOUR,
                           build_steady_castor, run_polls)

MODELS = {
    "lr": (LinearForecaster, {}),
    "gam": (GAMForecaster, {}),
    "ann": (ANNForecaster, {"hidden": 8, "epochs": 20}),
    "lstm": (LSTMForecaster, {"hidden": 8, "epochs": 20}),
}
POLLS = 3


def _histories(c, n):
    return {i: c.predictions.history(f"s-Z_PRO_0_{i}") for i in range(n)}


@pytest.mark.parametrize("kind", list(MODELS))
def test_runtime_equals_cold_fleet_repeated_polls(kind):
    """Warm polls (device ring + on-device assembly + cached params) must
    persist the same forecasts as the cold fleet path — with training due
    EVERY poll, so the warm train path is exercised, not just scoring."""
    cls, hp = MODELS[kind]
    ca = build_steady_castor(kind, cls, hp, n=5, train_every=HOUR)
    ex = run_polls(ca, POLLS)
    assert all(b["runtime"] == "warm" for b in ex.last_bin_stats), \
        ex.last_bin_stats
    cb = build_steady_castor(kind, cls, hp, n=5, train_every=HOUR)
    run_polls(cb, POLLS, executor=FleetExecutor(cb, runtime="off"))
    ha, hb = _histories(ca, 5), _histories(cb, 5)
    for i in range(5):
        assert len(ha[i]) == len(hb[i]) == POLLS
        for a, b in zip(ha[i], hb[i]):
            np.testing.assert_array_equal(a.times, b.times)
            np.testing.assert_allclose(a.values, b.values, rtol=FLEET_RTOL,
                                       atol=FLEET_ATOL, err_msg=kind)


@pytest.mark.parametrize("kind", ["lr", "gam"])
def test_runtime_equals_local_pool_repeated_polls(kind):
    """The runtime path also matches LocalPool over a poll sequence for
    the deterministic (closed-form) models — the executor-equivalence
    contract extends through the incremental state."""
    cls, hp = MODELS[kind]
    ca = build_steady_castor(kind, cls, hp, n=4)
    run_polls(ca, POLLS)
    cb = build_steady_castor(kind, cls, hp, n=4)
    run_polls(cb, POLLS, executor=LocalPoolExecutor(cb, max_parallel=4))
    for i in range(4):
        fa = ca.predictions.history(f"s-Z_PRO_0_{i}")
        fb = cb.predictions.history(f"s-Z_PRO_0_{i}")
        assert len(fa) == len(fb) == POLLS
        for a, b in zip(fa, fb):
            np.testing.assert_array_equal(a.times, b.times)
            np.testing.assert_allclose(a.values, b.values, rtol=FLEET_RTOL,
                                       atol=FLEET_ATOL, err_msg=kind)


def test_warm_polls_zero_retraces_and_delta_telemetry():
    """After warmup, every score poll of a steady sequence reports
    cache_hit, delta_rows == steps since the last poll, ONE watermark-delta
    read, no single reads, and ZERO retraces (trace counters live in every
    jitted hot-path body, so this catches any shape instability)."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=5)
    ex = run_polls(c, 2)                         # warmup: cold + first delta
    for k in range(2, 5):
        res = ex.run(c.scheduler.poll(NOW + k * HOUR))
        assert all(r.ok for r in res)
        assert len(ex.last_bin_stats) == 1
        for b in ex.last_bin_stats:
            assert b["runtime"] == "warm" and b["cache_hit"], b
            assert b["delta_rows"] == 1, b
            assert b["retraces"] == 0, b
            assert b["read_many_calls"] == 1 and b["delta_reads"] == 1, b
            assert b["single_reads"] == 0, b
    # a poller stall: catch-up emits one bin per missed boundary and the
    # runtime advances through them chronologically, one delta each
    res = ex.run(c.scheduler.poll(NOW + 7 * HOUR))
    assert all(r.ok for r in res)
    assert [b["delta_rows"] for b in ex.last_bin_stats] == [1, 1, 1]
    assert all(b["runtime"] == "warm" for b in ex.last_bin_stats)
    # same-poll reuse: a train bin followed by a score bin at one `now`
    # advances once — the score bin runs with ZERO store reads
    c2 = build_steady_castor("lr", LinearForecaster, {}, n=5,
                             train_every=HOUR)
    ex2 = run_polls(c2, 3)
    by_task = {("train" if "'train'" in b["bin"] else "score"): b
               for b in ex2.last_bin_stats}
    assert by_task["train"]["delta_rows"] == 1
    assert by_task["score"]["delta_rows"] == 0
    assert by_task["score"]["read_many_calls"] == 0, by_task["score"]


def test_same_bucket_bin_sizes_share_all_compilations():
    """A fleet of 5 and a fleet of 6 land in the same power-of-two bucket
    (8): after the first fleet warms the caches, the second fleet's ENTIRE
    poll sequence — cold build, warm train, warm score — compiles
    nothing."""
    ca = build_steady_castor("lr", LinearForecaster, {}, n=5,
                             train_every=HOUR)
    run_polls(ca, POLLS)                         # warms every program
    cb = build_steady_castor("lr", LinearForecaster, {}, n=6,
                             train_every=HOUR)
    ex = FleetExecutor(cb)
    for k in range(POLLS):
        res = ex.run(cb.scheduler.poll(NOW + k * HOUR))
        assert all(r.ok for r in res)
        assert all(b["retraces"] == 0 for b in ex.last_bin_stats), \
            (k, ex.last_bin_stats)
    assert all(b["runtime"] == "warm" for b in ex.last_bin_stats)


def test_late_append_invalidates_and_result_matches_cold():
    """An out-of-order append landing BEHIND the watermark must cold-rebuild
    the bin (the prior_counts handshake) — and the rebuilt forecasts equal
    a runtime-off executor fed the same data."""
    def run(runtime):
        c = build_steady_castor("lr", LinearForecaster, {}, n=3)
        ex = FleetExecutor(c, runtime=runtime)
        run_polls(c, 2, executor=ex)
        # late data: one series gets a point 2 days inside the window
        ctx = c.graph.context("ENERGY_LOAD", "Z_PRO_0_1")
        c.ingest(ctx.ts_id, [NOW - 2 * 86400.0 + 7.0], [9.0])
        res = ex.run(c.scheduler.poll(NOW + 2 * HOUR))
        assert all(r.ok for r in res)
        return c, ex

    ca, exa = run("auto")
    assert all(b["runtime"] == "cold" for b in exa.last_bin_stats), \
        exa.last_bin_stats
    assert exa.runtime.invalidations == 1
    cb, _ = run("off")
    for i in range(3):
        fa = ca.predictions.history(f"s-Z_PRO_0_{i}")[-1]
        fb = cb.predictions.history(f"s-Z_PRO_0_{i}")[-1]
        np.testing.assert_allclose(fa.values, fb.values, rtol=FLEET_RTOL,
                                   atol=FLEET_ATOL)
    # and the poll AFTER the rebuild is warm again
    res = exa.runtime  # state survived the rebuild
    ex = run_polls(ca, 1, executor=exa, t0=NOW + 3 * HOUR)
    assert all(b["runtime"] == "warm" for b in ex.last_bin_stats)


def test_now_regression_and_gap_invalidate():
    """Direct runtime unit contract: a poll earlier than the watermark or
    further away than the whole window cold-rebuilds instead of deltaing."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=3)
    rt = FleetRuntime(c)

    def insts(now):
        up = {"train_window_days": 14, "now": now}
        return [LinearForecaster(
            context=c.graph.context("ENERGY_LOAD", f"Z_PRO_0_{i}"),
            task="train", model_id=f"u{i}", model_version=None,
            user_params=up, system=c) for i in range(3)]

    assert rt.fleet_xy(LinearForecaster, insts(NOW)) is not None
    assert rt.pop_stats()["runtime"] == "cold"
    rt.fleet_xy(LinearForecaster, insts(NOW + HOUR))
    assert rt.pop_stats()["delta_rows"] == 1
    rt.fleet_xy(LinearForecaster, insts(NOW + 4 * HOUR))   # 3-step stall
    assert rt.pop_stats()["delta_rows"] == 3
    rt.fleet_xy(LinearForecaster, insts(NOW))              # regression
    s = rt.pop_stats()
    assert s["runtime"] == "cold" and s["runtime_reason"] == "now regression"
    rt.fleet_xy(LinearForecaster, insts(NOW + 40 * 86400.0))   # full turnover
    s = rt.pop_stats()
    assert s["runtime"] == "cold" and s["runtime_reason"] == "delta spans window"
    rt.fleet_xy(LinearForecaster, insts(NOW + 40 * 86400.0 + HOUR / 3))
    assert rt.pop_stats()["runtime_reason"] == "misaligned now"


def test_runtime_opt_outs():
    """user_params['runtime']='off' and FleetExecutor(runtime='off') both
    keep the bin on the cold path; rollout='host' skips the runtime score
    path but still scores correctly."""
    c = build_steady_castor("lr", LinearForecaster, {"runtime": "off"}, n=3)
    ex = run_polls(c, 2)
    assert all(b["runtime"] == "off" for b in ex.last_bin_stats)
    c2 = build_steady_castor("lr", LinearForecaster, {}, n=3)
    ex2 = run_polls(c2, 2, executor=FleetExecutor(c2, runtime="off"))
    assert ex2.runtime is None
    assert all(b["runtime"] == "off" for b in ex2.last_bin_stats)
    c3 = build_steady_castor("lr", LinearForecaster, {"rollout": "host"}, n=3)
    ex3 = run_polls(c3, 2)
    assert all(not b["cache_hit"] for b in ex3.last_bin_stats
               if "'score'" in b["bin"])


def test_forecast_many_bitwise_matches_scalar_calls():
    from repro.timeseries.weather import WeatherService
    w = WeatherService(seed=11)
    lats = [35.0, 35.2, 36.1]
    lons = [33.0, 32.9, 33.3]
    t = NOW + 3600.0 * np.arange(48)
    many = w.forecast_many(lats, lons, NOW, t)
    temp = w.temperature_many(lats, lons, t)
    for i, (la, lo) in enumerate(zip(lats, lons)):
        np.testing.assert_array_equal(many[i], w.forecast(la, lo, NOW, t))
        np.testing.assert_array_equal(temp[i], w.temperature(la, lo, t))
    # draw_len: trailing-slice evaluation preserves the rng stream exactly
    tail = w.forecast_many(lats, lons, NOW, t[-7:], draw_len=t.size)
    np.testing.assert_array_equal(tail, many[:, -7:])


def test_rollout_cache_is_lru_bounded_with_counters():
    from repro.forecast import base
    from repro.forecast.features import FeatureSpec
    lru = base._LRUCache(cap=3)
    for k in range(5):
        lru.put(("k", k), object())
    assert len(lru) == 3                     # oldest evicted
    assert lru.get(("k", 0)) is None         # miss (evicted)
    assert lru.get(("k", 4)) is not None     # hit
    assert lru.stats()["hits"] == 1 and lru.stats()["misses"] == 1
    # the live rollout cache IS an _LRUCache and reports stats
    st = base.rollout_cache_stats()
    assert set(st) == {"size", "cap", "hits", "misses"}
    assert st["size"] <= st["cap"]


def test_store_delta_read_and_prior_counts():
    from repro.timeseries.store import TimeSeriesStore
    st = TimeSeriesStore(tail_max=8)
    st.append("a", [1.0, 2.0, 5.0], [1, 2, 5])
    st.append("b", [3.0], [3])
    pairs, prior = st.read_many(["a", "b"], since=2.0, prior_counts=True)
    assert st.delta_read_count == 1
    np.testing.assert_array_equal(prior, [1, 0])      # points strictly < 2.0
    np.testing.assert_array_equal(pairs[0][0], [2.0, 5.0])
    np.testing.assert_array_equal(pairs[1][0], [3.0])
    # a late append behind the watermark moves prior — the invalidation signal
    st.append("a", [0.5], [0])
    _, prior2 = st.read_many(["a", "b"], since=2.0, prior_counts=True)
    np.testing.assert_array_equal(prior2, [2, 0])
    # since= equals start= for the returned points
    plain = st.read_many(["a"], 2.0, None)
    np.testing.assert_array_equal(plain[0][0], [2.0, 5.0])
