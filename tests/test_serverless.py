"""Serverless invocation subsystem (repro/serverless/): stateless
payloads, action aggregation, warm-container affinity, retry/speculation
exactly-once effects, and the inline == fleet bitwise contract across all
four forecasters."""
import functools
import threading

import numpy as np
import pytest

from repro.core import Castor, ModelDeployment, Schedule
from repro.core.executor import FleetExecutor
from repro.forecast import (ANNForecaster, GAMForecaster, LSTMForecaster,
                            LinearForecaster)
from repro.serverless import (InlineBackend, InvocationPayload,
                              ProcessBackend, ServerlessExecutor)
from repro.serverless.backend import InvocationError
from repro.serverless.payload import JobRef, VersionRef
from repro.testing import FLEET_NOW as NOW, HOUR, build_steady_castor

DAY = 86400.0

MODELS = {
    "lr": (LinearForecaster, {}),
    "gam": (GAMForecaster, {}),
    "ann": (ANNForecaster, {"hidden": 16, "epochs": 30}),
    "lstm": (LSTMForecaster, {"hidden": 8, "epochs": 30}),
}


# ------------------------------------------------------------ equivalence
@pytest.mark.parametrize("kind", list(MODELS))
def test_inline_serverless_equals_fleet_bitwise(kind):
    """Acceptance: tick(executor="serverless") with the inline backend is
    BITWISE identical to the fleet executor for all four forecasters,
    over several polls (cold build + warm ring updates), because bins are
    never split across invocations and each worker runs the exact fleet
    code path."""
    cls, hp = MODELS[kind]
    polls = 3
    ca = build_steady_castor(kind, cls, hp, n=4)
    cb = build_steady_castor(kind, cls, hp, n=4)
    for k in range(polls):
        ra = ca.tick(NOW + k * HOUR, executor="fleet")
        rb = cb.tick(NOW + k * HOUR, executor="serverless")
        assert ra and all(r.ok for r in ra), \
            [r.error for r in ra if not r.ok]
        assert rb and all(r.ok for r in rb), \
            [r.error for r in rb if not r.ok]
    for i in range(4):
        fa = ca.predictions.history(f"s-Z_PRO_0_{i}")
        fb = cb.predictions.history(f"s-Z_PRO_0_{i}")
        assert len(fa) == len(fb) == polls
        for x, y in zip(fa, fb):
            assert np.array_equal(x.times, y.times)
            assert np.array_equal(x.values, y.values), \
                (i, float(np.max(np.abs(x.values - y.values))))
    # telemetry surfaced through Castor.stats()
    s = cb.stats()["serverless"]
    assert s["invocations"] >= polls
    assert s["cold_starts"] >= 1 and s["warm_starts"] >= polls - 1


def test_bins_stay_whole_across_invocations():
    """Aggregation packs WHOLE bins: a catch-up cycle with several bins
    and a small aggregation factor must never split one bin's jobs across
    two invocations (bitwise megabatch numerics depend on it)."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=6)
    ex = ServerlessExecutor(c, n_workers=2, aggregation=12,
                            speculative=False)
    c._serverless_ex = ex
    res = ex.run(c.scheduler.poll(NOW))
    assert all(r.ok for r in res)
    # 3h stall: 3 catch-up score bins of 6 jobs each; aggregation=12
    # packs two whole bins per action and the third alone — never a
    # partial bin
    res = ex.run(c.scheduler.poll(NOW + 3 * HOUR))
    assert len(res) == 18 and all(r.ok for r in res), \
        [r.error for r in res if not r.ok]
    recs = ex.monitor.records
    assert all(r["jobs"] % 6 == 0 for r in recs), recs   # whole bins only
    assert any(r["jobs"] == 12 and r["bins"] == 2 for r in recs), \
        recs                                             # aggregation real
    # catch-up forecasts persist at their own boundaries
    assert [f.created_at for f in c.predictions.history("s-Z_PRO_0_0")] \
        == [NOW + k * HOUR for k in range(4)]
    for f in c.predictions.history("s-Z_PRO_0_0"):
        assert f.times[0] == f.created_at


def test_sticky_affinity_keeps_bins_on_one_warm_worker():
    """Successive polls of one logical bin hit the same worker, whose
    FleetRuntime then advances O(delta) (warm loads) instead of cold
    rebuilding."""
    polls = 4
    c = build_steady_castor("lr", LinearForecaster, {}, n=4)
    ex = ServerlessExecutor(c, n_workers=3, speculative=False)
    c._serverless_ex = ex
    for k in range(polls):
        res = ex.run(c.scheduler.poll(NOW + k * HOUR))
        assert res and all(r.ok for r in res)
    workers = {r["worker"] for r in ex.monitor.records}
    assert len(workers) == 1            # one bin -> one sticky worker
    s = ex.stats()
    assert s["cold_starts"] == 1
    assert s["warm_starts"] == s["invocations"] - 1
    (w,) = [ex.backend._workers[w] for w in workers]
    assert w.executor.runtime.warm_loads >= polls - 2
    assert w.executor.runtime.cold_loads == 1


# ------------------------------------------------------------ resilience
class _FlakyBackend(InlineBackend):
    """Fails each invocation's first delivery at the backend level."""

    def __init__(self, system, *, n_workers=2, fail_first=1):
        super().__init__(system, n_workers=n_workers)
        self.fail_first = fail_first
        self.seen = {}
        self._seen_lock = threading.Lock()

    def invoke(self, payload, worker_id):
        with self._seen_lock:
            n = self.seen.get(payload.invocation_id, 0)
            self.seen[payload.invocation_id] = n + 1
        if n < self.fail_first:
            raise InvocationError("transient backend failure")
        return super().invoke(payload, worker_id)


def test_invoker_retries_with_backoff_exactly_once_effects():
    c = build_steady_castor("lr", LinearForecaster, {}, n=4)
    ex = ServerlessExecutor(c, backend=_FlakyBackend(c, n_workers=2),
                            max_retries=2, backoff_base_s=0.01,
                            speculative=False)
    res = ex.run(c.scheduler.poll(NOW))
    assert res and all(r.ok for r in res), \
        [r.error for r in res if not r.ok]
    s = ex.stats()
    assert s["retries"] >= 1 and s["failed_invocations"] >= 1
    # exactly-once effects despite at-least-once invocation
    for i in range(4):
        assert len(c.predictions.history(f"s-Z_PRO_0_{i}")) == 1
        assert len(c.versions.history(f"s-Z_PRO_0_{i}")) == 1
    # no spurious re-fire queued
    assert not c.scheduler.poll(NOW + 1.0)


def test_invoker_exhausted_retries_fail_and_requeue():
    c = build_steady_castor("lr", LinearForecaster, {}, n=2)
    ex = ServerlessExecutor(c, backend=_FlakyBackend(c, n_workers=2,
                                                     fail_first=99),
                            max_retries=1, backoff_base_s=0.01,
                            speculative=False)
    res = ex.run(c.scheduler.poll(NOW))
    assert res and not any(r.ok for r in res)
    # at-least-once: every occurrence re-fires at its own boundary
    refire = c.scheduler.poll(NOW + 1.0)
    assert sorted({j.task for j in refire}) == ["score", "train"]
    assert all(j.scheduled_at == NOW for j in refire)


def test_duplicate_invocation_is_idempotent():
    """A speculative backup / replayed action re-executing the same
    payload must not double-persist (the exactly-once argument)."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=3)
    ex = ServerlessExecutor(c, n_workers=2, speculative=False)
    res = ex.run(c.scheduler.poll(NOW))
    assert all(r.ok for r in res)
    backend = ex.backend
    jobs = c.scheduler.poll(NOW + HOUR)
    refs = tuple(JobRef.from_job(j) for j in jobs)
    payload = InvocationPayload(invocation_id="dup-1", jobs=refs)
    r1 = backend.invoke(payload, "w0")
    r2 = backend.invoke(payload, "w1")       # the duplicate delivery
    assert all(o.ok for o in r1.outcomes + r2.outcomes)
    for i in range(3):
        assert len(c.predictions.history(f"s-Z_PRO_0_{i}")) == 2


def test_missing_version_fails_alone():
    """Serverless mirrors FleetExecutor's partial-bin semantics: a
    never-trained deployment fails alone, the rest of its bin scores."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=4)
    c.deploy(ModelDeployment(
        name="cold", package="lr", signal="ENERGY_LOAD",
        entity="Z_PRO_0_0", train=None, score=Schedule(NOW, 1e12),
        user_params={"train_window_days": 14}))
    ex = ServerlessExecutor(c, n_workers=2, speculative=False)
    res = ex.run(c.scheduler.poll(NOW))
    by_name = {r.job.deployment_name: r for r in res
               if r.job.task == "score"}
    assert not by_name["cold"].ok
    assert "no trained version" in by_name["cold"].error
    assert all(r.ok for n, r in by_name.items() if n != "cold")
    refire = c.scheduler.poll(NOW + 1.0)
    assert [j.deployment_name for j in refire] == ["cold"]


# ------------------------------------------------------------ payloads
def test_payload_and_result_roundtrip_json_bitwise():
    job = JobRef("d0", "lr", "1.0", "score", NOW, "ENERGY_LOAD", "E0",
                 "params-key")
    arrs = {"w": np.linspace(-1, 1, 7).astype(np.float32),
            "b": np.arange(4, dtype=np.float64) * np.pi}
    vr = VersionRef("d0", 3, NOW - HOUR,
                    model_object={"kind": "lr", "params": arrs,
                                  "y_scale": 2.5})
    p = InvocationPayload(invocation_id="inv-1", jobs=(job,),
                          versions=(vr,), created_at=123.25, attempt=2)
    q = InvocationPayload.from_json(p.to_json())
    assert q.jobs == (job,)
    assert q.invocation_id == "inv-1" and q.attempt == 2
    mo = q.versions[0].model_object
    for k, v in arrs.items():
        got = mo["params"][k]
        assert got.dtype == v.dtype and np.array_equal(got, v)
    assert mo["y_scale"] == 2.5
    assert q.jobs[0].to_job().bin_key == job.to_job().bin_key


# ------------------------------------------------------------ process
def _mini_castor():
    """Cheapest possible picklable system factory: spawn-handshake tests
    only need the worker process to come up, not to model anything."""
    return Castor()


def test_process_backend_workers_reaped_on_gc():
    """Regression: a ProcessBackend leaked by a crashed invoker (or a
    test failing mid-run) used to orphan its spawned workers for the
    rest of the session. The weakref.finalize teardown must kill them
    when the backend object is collected — and at interpreter exit."""
    import gc
    be = ProcessBackend(_mini_castor, n_workers=1)
    (proc, _tq, _rq), _lock = be._worker("p0")     # force the spawn
    assert proc.is_alive()
    del be                                          # "crash": no close()
    gc.collect()
    proc.join(timeout=10.0)
    assert not proc.is_alive(), "orphaned worker survived backend GC"


def test_process_backend_context_manager_reaps_and_cleans_storage():
    import os
    with ProcessBackend(_mini_castor, n_workers=1) as be:
        (proc, _tq, _rq), _lock = be._worker("p0")
        root = be.storage.root                      # owned "auto" bucket
        assert proc.is_alive() and os.path.isdir(root)
    proc.join(timeout=10.0)
    assert not proc.is_alive()
    assert not os.path.exists(root)                 # owned bucket removed
    be.close()                                      # idempotent


def test_process_backend_smoke_matches_fleet():
    """Real spawned containers (JSON wire, artifact ship-back): forecasts
    equal the fleet executor's, versions persisted with the invoker's
    lineage numbering, cold/warm telemetry recorded."""
    factory = functools.partial(build_steady_castor, "lr",
                                LinearForecaster, {}, n=2)
    c = factory()
    cf = factory()
    ex = ServerlessExecutor(c, backend=ProcessBackend(factory, n_workers=1),
                            speculative=False)
    try:
        for k in range(2):
            rb = ex.run(c.scheduler.poll(NOW + k * HOUR))
            assert rb and all(r.ok for r in rb), \
                [r.error for r in rb if not r.ok]
            ra = cf.tick(NOW + k * HOUR, executor="fleet")
            assert all(r.ok for r in ra)
        for i in range(2):
            fa = cf.predictions.history(f"s-Z_PRO_0_{i}")
            fb = c.predictions.history(f"s-Z_PRO_0_{i}")
            assert len(fa) == len(fb) == 2
            for x, y in zip(fa, fb):
                np.testing.assert_allclose(y.values, x.values,
                                           rtol=1e-6, atol=1e-8)
                assert y.model_version == x.model_version
            assert len(c.versions.history(f"s-Z_PRO_0_{i}")) == 1
        s = ex.stats()
        assert s["cold_starts"] == 1 and s["warm_starts"] >= 1
        assert s["queue_s_p95"] >= 0.0
    finally:
        ex.close()
