"""Property tests (hypothesis) for the time-series substrate invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.timeseries.store import TimeSeriesStore
from repro.timeseries.transforms import (HOUR, align_resample,
                                         calendar_features,
                                         integrate_to_energy, lagged_features,
                                         mape)


# ---------------- store ----------------
@given(st.lists(st.lists(st.tuples(st.floats(0, 1e6), st.floats(-1e3, 1e3)),
                         min_size=1, max_size=20), min_size=1, max_size=5))
@settings(max_examples=50, deadline=None)
def test_store_append_only_sorted_reads(batches):
    s = TimeSeriesStore()
    total = 0
    for b in batches:
        t = [x[0] for x in b]
        v = [x[1] for x in b]
        total += s.append("x", t, v)
    rt, rv = s.read("x")
    assert rt.size == total == s.length("x")        # nothing lost/overwritten
    assert np.all(np.diff(rt) >= 0)                 # time-sorted view


def test_store_range_reads():
    s = TimeSeriesStore()
    s.append("x", [3.0, 1.0, 2.0], [30, 10, 20])
    t, v = s.read("x", 1.5, 3.0)                    # [start, end)
    assert list(t) == [2.0] and list(v) == [20]


def test_store_roundtrip(tmp_path):
    s = TimeSeriesStore()
    s.append("a", [1, 2], [3, 4])
    s.append("b::x", [0.5], [9])
    s.save(str(tmp_path))
    s2 = TimeSeriesStore.load(str(tmp_path))
    t, v = s2.read("a")
    assert list(v) == [3, 4] and set(s2.ids()) == {"a", "b::x"}


# ---------------- resample ----------------
@given(n=st.integers(2, 200), step=st.floats(1.0, 100.0))
@settings(max_examples=50, deadline=None)
def test_align_resample_sum_conserves_mass(n, step):
    rng = np.random.default_rng(n)
    t = np.sort(rng.uniform(0, 1000, n))
    v = rng.normal(size=n)
    grid, out = align_resample(t, v, step=step, how="sum")
    assert np.isclose(out.sum(), v.sum(), atol=1e-6 * max(1, abs(v).sum()))
    assert np.all(np.diff(grid) > 0)


def test_align_resample_mean_and_ffill():
    t = np.asarray([0.0, 1.0, 10.0])
    v = np.asarray([2.0, 4.0, 8.0])
    grid, out = align_resample(t, v, step=5.0, start=0.0, end=15.0)
    assert out[0] == 3.0                            # mean of bin
    assert out[1] == 3.0                            # forward-filled gap
    assert out[2] == 8.0


# ---------------- integration (Fig. 4) ----------------
def test_integrate_constant_current_exact():
    """Constant current I at voltage V for T hours = V*I*T/1000 kWh."""
    t = np.arange(0, 3600 * 4 + 1, 60.0)            # 4 hours at 1-min
    i = np.full_like(t, 10.0)                       # 10 A
    grid, e = integrate_to_energy(t, i, voltage=230.0, step=900.0)
    np.testing.assert_allclose(e.sum(), 230.0 * 10.0 * 4.0 / 1000.0, rtol=1e-6)
    # each 15-min bin carries V*I*0.25h/1000
    np.testing.assert_allclose(e[1:-1], 230 * 10 * 0.25 / 1000, rtol=1e-6)


@given(n=st.integers(10, 300))
@settings(max_examples=30, deadline=None)
def test_integration_invariant_total_energy(n):
    rng = np.random.default_rng(n)
    t = np.sort(rng.uniform(0, 36000, n))
    i = rng.uniform(0, 20, n)
    grid, e = integrate_to_energy(t, i, step=900.0)
    # total energy equals the full trapezoid integral
    p = 230.0 * i / 1000.0
    want = np.trapezoid(p, t / 3600.0)
    np.testing.assert_allclose(e.sum(), want, rtol=1e-6, atol=1e-9)


# ---------------- features ----------------
def test_lagged_features_alignment():
    s = np.arange(10.0)
    X = lagged_features(s, [1, 3])
    assert X[5, 0] == 4.0 and X[5, 1] == 2.0


def test_calendar_features_periodic():
    f1 = calendar_features(np.asarray([0.0]))
    f2 = calendar_features(np.asarray([7 * 24 * HOUR]))
    np.testing.assert_allclose(f1, f2, atol=1e-9)


def test_mape_basic():
    assert mape([100, 100], [90, 110]) == pytest.approx(10.0)
