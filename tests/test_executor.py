"""Executor semantics: retries, failure requeue, straggler speculation, and
fleet-vs-local observational equivalence."""
import threading
import time

import numpy as np
import pytest

from repro.core import Castor, ModelDeployment, Schedule
from repro.core.executor import FleetExecutor, LocalPoolExecutor
from repro.core.registry import ModelInterface
from repro.forecast import LinearForecaster


class _Flaky(ModelInterface):
    """Fails the first N attempts per deployment (class-level counter)."""
    FAILS = {}
    LOCK = threading.Lock()

    def load(self): pass
    def transform(self): pass

    def train(self):
        with _Flaky.LOCK:
            n = _Flaky.FAILS.get(self.model_id, 0)
            _Flaky.FAILS[self.model_id] = n + 1
        if n < 1:
            raise RuntimeError("transient backend error")
        return {"ok": True}

    def score(self, m):
        return np.arange(2.0), np.ones(2)


class _Slow(ModelInterface):
    """One deployment is a straggler (sleeps)."""
    def load(self): pass
    def transform(self): pass
    def train(self): return {}
    def score(self, m):
        if self.model_id.endswith("slow"):
            time.sleep(1.2)
        return np.arange(2.0), np.ones(2)


def _mk_castor(cls, n=4, slow=False):
    c = Castor()
    c.publish("pkg", "1.0", cls)
    c.add_signal("S")
    for i in range(n):
        name = f"d{i}" + ("slow" if slow and i == 0 else "")
        c.add_entity(f"E{i}")
        c.deploy(ModelDeployment(name=name, package="pkg", signal="S",
                                 entity=f"E{i}", train=Schedule(0.0, 1e9),
                                 score=Schedule(0.0, 1e9)))
    return c


def test_retry_on_transient_failure():
    _Flaky.FAILS = {}
    c = _mk_castor(_Flaky)
    res = c.tick(0.0, executor="local")
    trains = [r for r in res if r.job.task == "train"]
    assert all(r.ok for r in trains)
    assert all(r.attempts == 2 for r in trains)      # one retry each


def test_permanent_failure_requeues():
    class _Dead(ModelInterface):
        def load(self): pass
        def transform(self): pass
        def train(self): raise RuntimeError("permanently broken")
        def score(self, m): return np.arange(2.0), np.ones(2)

    c = _mk_castor(_Dead, n=1)
    res = c.tick(0.0, executor="local")
    assert any(not r.ok for r in res)
    # failed job re-fires next poll (at-least-once)
    jobs = c.scheduler.poll(1.0)
    assert any(j.task == "train" for j in jobs)


def test_straggler_speculation_does_not_duplicate_results():
    c = _mk_castor(_Slow, n=6, slow=True)
    c.tick(0.0, executor="local")                    # trains
    ex = LocalPoolExecutor(c, max_parallel=6, straggler_min_s=0.2,
                           straggler_factor=2.0)
    res = ex.run(c.scheduler.poll(1.0))
    assert all(r.ok for r in res)
    # exactly one persisted forecast per deployment despite backup copies
    for i in range(6):
        name = f"d{i}" + ("slow" if i == 0 else "")
        assert len(c.predictions.history(name)) == 1


class _SlowPrimaryDeadBackup(ModelInterface):
    """The straggler's FIRST score attempt is slow but succeeds; every
    later copy (speculative backup + its retries) dies instantly."""
    CALLS = {}
    LOCK = threading.Lock()

    def load(self): pass
    def transform(self): pass
    def train(self): return {}

    def score(self, m):
        with _SlowPrimaryDeadBackup.LOCK:
            n = _SlowPrimaryDeadBackup.CALLS.get(self.model_id, 0)
            _SlowPrimaryDeadBackup.CALLS[self.model_id] = n + 1
        if self.model_id.endswith("slow"):
            if n == 0:
                time.sleep(1.2)
                return np.arange(2.0), np.ones(2)
            raise RuntimeError("backup copy died")
        return np.arange(2.0), np.ones(2)


def test_backup_failure_does_not_discard_primary_success():
    """A speculative backup that exhausts its retries while the primary is
    still running must NOT record the job as failed — the late primary
    success wins, and the job must not re-fire next poll."""
    _SlowPrimaryDeadBackup.CALLS = {}
    c = _mk_castor(_SlowPrimaryDeadBackup, n=6, slow=True)
    c.tick(0.0, executor="local")                    # trains
    ex = LocalPoolExecutor(c, max_parallel=8, max_retries=1,
                           straggler_min_s=0.1, straggler_factor=2.0)
    res = ex.run(c.scheduler.poll(1.0))
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    # the backup really did fire and fail
    assert _SlowPrimaryDeadBackup.CALLS["d0slow"] >= 2
    assert len(c.predictions.history("d0slow")) == 1
    # no spurious requeue: the failure path must not have marked the job
    assert not c.scheduler.poll(2.0)


def test_scheduled_at_overrides_user_params_now():
    """A stray "now" inside a deployment's user_params must not pin jobs
    to a stale timestamp — job.scheduled_at always wins."""
    from repro.timeseries.ingest import SiteSpec, build_site
    c = Castor()
    build_site(c, SiteSpec("N", n_prosumers=1, n_feeders=1,
                           n_substations=1, seed=4),
               t0=0.0, t1=40 * 86400.0)
    now = 35 * 86400.0
    c.publish("lr", "1.0", LinearForecaster)
    c.deploy_for_all(package="lr", signal="ENERGY_LOAD", name_prefix="n",
                     kind="PROSUMER", train=Schedule(now, 1e9),
                     score=Schedule(now, 3600.0),
                     user_params={"train_window_days": 14,
                                  "now": 7 * 86400.0})   # stale!
    res = c.tick(now, executor="local")
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    assert c.predictions.history("n-N_PRO_0_0")[0].times[0] == now
    # and through the fleet path at the NEXT poll time
    res = c.tick(now + 3600.0, executor="fleet")
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    assert c.predictions.history("n-N_PRO_0_0")[1].times[0] == now + 3600.0


def _smartgrid(n=6):
    from repro.timeseries.ingest import SiteSpec, build_site
    c = Castor()
    build_site(c, SiteSpec("T", n_prosumers=n, n_feeders=2,
                           n_substations=1, seed=1),
               t0=0.0, t1=40 * 86400.0)
    c.publish("lr", "1.0", LinearForecaster)
    c.deploy_for_all(package="lr", signal="ENERGY_LOAD", name_prefix="m",
                     kind="PROSUMER", train=Schedule(35 * 86400.0, 1e9),
                     score=Schedule(35 * 86400.0, 1e9),
                     user_params={"train_window_days": 14})
    return c


def test_fleet_equals_local_for_linear():
    """Fleet megabatch and per-job local execution produce the same
    predictions (observational equivalence)."""
    ca = _smartgrid()
    cb = _smartgrid()
    ra = ca.tick(35 * 86400.0, executor="fleet")
    rb = cb.tick(35 * 86400.0, executor="local")
    assert all(r.ok for r in ra) and all(r.ok for r in rb)
    for i in range(6):
        fa = ca.predictions.history(f"m-T_PRO_0_{i}")
        fb = cb.predictions.history(f"m-T_PRO_0_{i}")
        assert len(fa) == len(fb) == 1
        np.testing.assert_allclose(fa[0].values, fb[0].values,
                                   rtol=1e-4, atol=1e-5)


def _mk_castor_late_score(cls, n=6, slow=True):
    """Like _mk_castor, but scoring first fires at t=1.0 so tick(0.0)
    trains WITHOUT scoring (keeps per-test score call counts clean)."""
    c = Castor()
    c.publish("pkg", "1.0", cls)
    c.add_signal("S")
    for i in range(n):
        name = f"d{i}" + ("slow" if slow and i == 0 else "")
        c.add_entity(f"E{i}")
        c.deploy(ModelDeployment(name=name, package="pkg", signal="S",
                                 entity=f"E{i}", train=Schedule(0.0, 1e9),
                                 score=Schedule(1.0, 1e9)))
    return c


class _DeadStraggler(ModelInterface):
    """The straggler's scoring always fails — and its FIRST attempt is slow
    enough to trigger a speculative backup. Everyone else succeeds fast."""
    CALLS = {}
    LOCK = threading.Lock()

    def load(self): pass
    def transform(self): pass
    def train(self): return {}

    def score(self, m):
        with _DeadStraggler.LOCK:
            n = _DeadStraggler.CALLS.get(self.model_id, 0)
            _DeadStraggler.CALLS[self.model_id] = n + 1
        if self.model_id.endswith("slow"):
            if n == 0:
                time.sleep(0.6)
            raise RuntimeError("permanently dead")
        return np.arange(2.0), np.ones(2)


def test_retry_budget_is_per_job_not_per_copy_chain():
    """Regression: a speculative backup was submitted with attempt n+1 and
    could itself be retried, so one job consumed max_retries twice. The
    budget is per job index: at most 1 + max_retries executions total,
    backups included."""
    _DeadStraggler.CALLS = {}
    c = _mk_castor_late_score(_DeadStraggler, n=6, slow=True)
    c.tick(0.0, executor="local")                    # trains only
    ex = LocalPoolExecutor(c, max_parallel=8, max_retries=2,
                           straggler_min_s=0.1, straggler_factor=2.0)
    res = ex.run(c.scheduler.poll(1.0))
    slow = [r for r in res if r.job.deployment_name == "d0slow"]
    assert len(slow) == 1 and not slow[0].ok
    assert _DeadStraggler.CALLS["d0slow"] == 3      # 1 + max_retries, EXACTLY
    assert slow[0].attempts == 3


class _SlowPrimaryFastBackup(ModelInterface):
    """The straggler's first score copy sleeps; every later copy returns
    instantly — the speculative backup should win."""
    CALLS = {}
    LOCK = threading.Lock()

    def load(self): pass
    def transform(self): pass
    def train(self): return {}

    def score(self, m):
        with _SlowPrimaryFastBackup.LOCK:
            n = _SlowPrimaryFastBackup.CALLS.get(self.model_id, 0)
            _SlowPrimaryFastBackup.CALLS[self.model_id] = n + 1
        if self.model_id.endswith("slow") and n == 0:
            time.sleep(1.2)
        return np.arange(2.0), np.ones(2)


def test_speculative_win_flag_set_only_for_winning_backup():
    _SlowPrimaryFastBackup.CALLS = {}
    c = _mk_castor_late_score(_SlowPrimaryFastBackup, n=6, slow=True)
    c.tick(0.0, executor="local")                    # trains only
    ex = LocalPoolExecutor(c, max_parallel=8, max_retries=1,
                           straggler_min_s=0.1, straggler_factor=2.0)
    res = ex.run(c.scheduler.poll(1.0))
    assert all(r.ok for r in res)
    by_name = {r.job.deployment_name: r for r in res}
    assert by_name["d0slow"].speculative_win        # the backup copy won
    assert not any(r.speculative_win for n, r in by_name.items()
                   if n != "d0slow")


def test_fleet_partial_bin_scores_trained_excludes_missing():
    """One deployment with no trained version must fail ALONE: the rest of
    the bin scores normally (regression: the whole bin used to fail)."""
    c = _smartgrid(6)
    from repro.core import ModelDeployment
    c.deploy(ModelDeployment(
        name="cold", package="lr", signal="ENERGY_LOAD", entity="T_PRO_0_0",
        train=None, score=Schedule(35 * 86400.0, 1e9),
        user_params={"train_window_days": 14}))
    fx = FleetExecutor(c)
    res = fx.run(c.scheduler.poll(35 * 86400.0))
    by_name = {r.job.deployment_name: r for r in res
               if r.job.task == "score"}
    assert not by_name["cold"].ok
    assert "no trained version" in by_name["cold"].error
    assert all(r.ok for n, r in by_name.items() if n != "cold")
    for i in range(6):
        assert len(c.predictions.history(f"m-T_PRO_0_{i}")) == 1
    # the scored bin ran as one megabatch of the 6 trained instances
    score_bins = [b for b in fx.last_bin_stats if "'score'" in b["bin"]]
    assert [b["jobs"] for b in score_bins] == [6]
    # only the truly-missing job re-fires (at-least-once per job)
    refire = c.scheduler.poll(35 * 86400.0 + 1.0)
    assert [j.deployment_name for j in refire] == ["cold"]


def test_fleet_run_phases_trains_before_scores():
    """FleetExecutor.run must phase train bins before score bins itself,
    not rely on callers passing pre-sorted jobs."""
    c = _smartgrid(4)
    jobs = list(reversed(c.scheduler.poll(35 * 86400.0)))   # scores FIRST
    assert jobs[0].task == "score"
    res = FleetExecutor(c).run(jobs)
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    for i in range(4):
        assert len(c.predictions.history(f"m-T_PRO_0_{i}")) == 1


def test_non_fleet_fallback_pools_across_staggered_bins():
    """Non-fleet jobs with distinct scheduled_at (staggered schedules or
    catch-up) fragment into separate bins — but the local-pool fallback
    must still receive them as ONE run per phase, not one sequential
    single-job run per bin."""
    class _Plain(ModelInterface):
        def load(self): pass
        def transform(self): pass
        def train(self): return {"ok": True}
        def score(self, m): return np.arange(2.0), np.ones(2)

    c = Castor()
    c.publish("plain", "1.0", _Plain)
    c.add_signal("S")
    for i in range(4):
        c.add_entity(f"E{i}")
        c.deploy(ModelDeployment(name=f"p{i}", package="plain", signal="S",
                                 entity=f"E{i}",
                                 train=Schedule(i * 10.0, 1e9),
                                 score=Schedule(i * 10.0, 1e9)))
    jobs = c.scheduler.poll(100.0)
    assert len({j.scheduled_at for j in jobs}) == 4   # staggered boundaries
    fx = FleetExecutor(c)
    calls = []
    orig = fx.fallback.run
    fx.fallback.run = lambda js: calls.append(len(js)) or orig(js)
    res = fx.run(jobs)
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    assert calls == [4, 4]        # one pooled run per phase, not 8 bins


def test_catchup_tick_persists_forecasts_at_boundaries():
    """End-to-end: a late tick covering K missed score occurrences persists
    K forecasts, each created_at its scheduled boundary (Castor lineage)."""
    HOUR = 3600.0
    from repro.timeseries.ingest import SiteSpec, build_site
    c = Castor()
    build_site(c, SiteSpec("C", n_prosumers=2, n_feeders=1,
                           n_substations=1, seed=2),
               t0=0.0, t1=40 * 86400.0)
    now = 35 * 86400.0
    c.publish("lr", "1.0", LinearForecaster)
    c.deploy_for_all(package="lr", signal="ENERGY_LOAD", name_prefix="c",
                     kind="PROSUMER", train=Schedule(now, 1e12),
                     score=Schedule(now, HOUR),
                     user_params={"train_window_days": 14})
    assert all(r.ok for r in c.tick(now, executor="fleet"))
    # the poller was down for 3 hours: one late tick catches up
    res = c.tick(now + 3 * HOUR, executor="fleet")
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    fc = c.predictions.history("c-C_PRO_0_0")
    assert [f.created_at for f in fc] == [now, now + HOUR, now + 2 * HOUR,
                                          now + 3 * HOUR]
    for f in fc:                      # horizons roll from the DUE time
        assert f.times[0] == f.created_at


def test_catchup_scoring_uses_contemporaneous_versions():
    """Replay fidelity: when BOTH train and score catch up, each forecast
    must record the model version a live poller would have had at its
    boundary — never a version trained on data observed later."""
    HOUR = 3600.0
    from repro.timeseries.ingest import SiteSpec, build_site
    c = Castor()
    build_site(c, SiteSpec("D", n_prosumers=2, n_feeders=1,
                           n_substations=1, seed=2),
               t0=0.0, t1=40 * 86400.0)
    now = 35 * 86400.0
    c.publish("lr", "1.0", LinearForecaster)
    c.deploy_for_all(package="lr", signal="ENERGY_LOAD", name_prefix="d",
                     kind="PROSUMER", train=Schedule(now, HOUR),
                     score=Schedule(now, HOUR),
                     user_params={"train_window_days": 14})
    assert all(r.ok for r in c.tick(now, executor="fleet"))
    res = c.tick(now + 3 * HOUR, executor="fleet")   # 3h poller stall
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    name = "d-D_PRO_0_0"
    versions = {v.version: v.trained_at for v in c.versions.history(name)}
    for f in c.predictions.history(name):
        # the forecast's model was trained AT its own boundary, not later
        assert versions[f.model_version] == f.created_at, \
            (f.created_at, f.model_version, versions)


def test_fleet_bins_execute_as_one(capsys):
    c = _smartgrid()
    ex = FleetExecutor(c)
    jobs = c.scheduler.poll(35 * 86400.0)
    res = ex.run(jobs)
    assert all(r.ok for r in res)
    # 1 train bin + 1 score bin
    assert len(ex.last_bin_stats) == 2
    assert all(b["jobs"] == 6 for b in ex.last_bin_stats)
