"""Durability subsystem: WAL codec properties, journal group-commit +
snapshot/compaction, idempotent flush-on-close, and crash-restart
end-to-end across all four forecasters plus the minutely detection flow.

Contracts pinned here:
  * codec: arbitrary record sequences round-trip BITWISE; every byte-level
    truncation and single-byte corruption of the tail decodes to exactly
    the longest valid prefix — and never raises;
  * recovery: ``Castor.open`` over snapshot-then-WAL rebuilds bitwise-
    equal stores, re-arms the calendar queue, and the boundary-stamped
    catch-up fills any lost suffix replay-faithfully (kill after poll k
    == uninterrupted run, for lr/gam/ann/lstm and the detection flow);
  * torn tails: a crash mid-segment-write (CrashingStorage) or any
    enumerated crash state (crash_states) recovers without error;
  * Castor.close: idempotent, flushes buffered WAL records before
    releasing storage; FilesystemStorage lists deterministically sorted.
"""
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.castor import Castor, HOUR, MINUTE
from repro.durability.chaos import (CrashingStorage, ProcessCrash,
                                    clone_to_memory, crash_states)
from repro.durability.journal import (Journal, load_records, replay_records,
                                      snapshot_records)
from repro.durability.wal import (HEADER_SIZE, decode_records, encode_record,
                                  split_frames)
from repro.forecast import (ANNForecaster, GAMForecaster, LSTMForecaster,
                            LinearForecaster)
from repro.serverless.storage import FilesystemStorage, InMemoryStorage
from repro.testing import (FLEET_NOW, assert_stores_bitwise_equal,
                           detection_plan, drive_plan, snapshot_stores,
                           steady_plan)

MODELS = {
    "lr": (LinearForecaster, {}),
    "gam": (GAMForecaster, {}),
    "ann": (ANNForecaster, {"hidden": 8, "epochs": 20}),
    "lstm": (LSTMForecaster, {"hidden": 8, "epochs": 20}),
}


# --------------------------------------------------------------- codec


def _mk_records(chunks):
    """Turn a list of float-lists into framed ("ts", ...) records."""
    return [("ts", {"id": f"s{i}", "t": np.asarray(c, np.float64),
                    "v": np.asarray(c, np.float64) * 2.0})
            for i, c in enumerate(chunks)]


def _assert_records_equal(got, want):
    assert len(got) == len(want)
    for (op_g, d_g), (op_w, d_w) in zip(got, want):
        assert op_g == op_w
        assert d_g["id"] == d_w["id"]
        assert d_g["t"].dtype == d_w["t"].dtype
        assert d_g["t"].tobytes() == d_w["t"].tobytes()
        assert d_g["v"].tobytes() == d_w["v"].tobytes()


@settings(max_examples=25)
@given(st.lists(st.lists(st.floats(min_value=-1e12, max_value=1e12),
                         min_size=0, max_size=7),
                min_size=0, max_size=6))
def test_codec_roundtrip_bitwise(chunks):
    recs = _mk_records(chunks)
    blob = b"".join(encode_record(op, obj) for op, obj in recs)
    got, valid, clean = decode_records(blob)
    assert clean and valid == len(blob)
    _assert_records_equal(got, recs)
    assert len(split_frames(blob)) == len(recs)


@settings(max_examples=25)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6),
                min_size=1, max_size=9),
       st.integers(min_value=0, max_value=10**9))
def test_codec_truncation_yields_longest_valid_prefix(chunk, cut_seed):
    recs = _mk_records([chunk, chunk[::-1], chunk])
    frames = [encode_record(op, obj) for op, obj in recs]
    blob = b"".join(frames)
    cut = cut_seed % len(blob)          # every byte offset reachable
    got, valid, clean = decode_records(blob[:cut])
    # exactly the frames that fit entirely under the cut survive
    want_n, pos = 0, 0
    for f in frames:
        if pos + len(f) <= cut:
            want_n += 1
            pos += len(f)
    assert len(got) == want_n
    assert valid == pos
    assert clean == (cut == pos)
    _assert_records_equal(got, recs[:want_n])


def test_codec_every_truncation_never_raises():
    """Exhaustive: all prefixes of a 3-record blob decode cleanly to a
    record prefix (the property test samples offsets; this nails all)."""
    recs = _mk_records([[1.0, 2.0], [3.0], [4.0, 5.0, 6.0]])
    blob = b"".join(encode_record(op, obj) for op, obj in recs)
    for cut in range(len(blob) + 1):
        got, valid, _clean = decode_records(blob[:cut])
        assert valid <= cut
        _assert_records_equal(got, recs[:len(got)])


@settings(max_examples=25)
@given(st.integers(min_value=0, max_value=10**9),
       st.integers(min_value=1, max_value=255))
def test_codec_single_byte_corruption_detected(pos_seed, xor):
    recs = _mk_records([[1.0, 2.0, 3.0], [4.0], [5.0, 6.0]])
    frames = [encode_record(op, obj) for op, obj in recs]
    blob = bytearray(b"".join(frames))
    # corrupt one byte of the LAST frame (header or payload)
    tail_start = len(blob) - len(frames[-1])
    pos = tail_start + pos_seed % len(frames[-1])
    blob[pos] ^= xor
    got, valid, clean = decode_records(bytes(blob))
    assert not clean
    assert len(got) == len(recs) - 1    # tail dropped, prefix intact
    assert valid == tail_start
    _assert_records_equal(got, recs[:-1])


def test_codec_corrupt_mid_frame_drops_suffix():
    """A flipped byte in frame 1 of 3 must also drop frames 2-3: after a
    bad checksum nothing downstream can be trusted (lengths may lie)."""
    recs = _mk_records([[1.0], [2.0], [3.0]])
    frames = [encode_record(op, obj) for op, obj in recs]
    blob = bytearray(b"".join(frames))
    blob[len(frames[0]) + HEADER_SIZE + 2] ^= 0x40
    got, _valid, clean = decode_records(bytes(blob))
    assert not clean and len(got) == 1
    _assert_records_equal(got, recs[:1])


# -------------------------------------------------------------- journal


def test_journal_group_commit_one_segment_per_commit():
    storage = InMemoryStorage()
    j = Journal(storage)
    for i in range(10):
        j.append("ts", {"id": "a", "t": np.arange(3.0), "v": np.arange(3.0)})
    assert storage.list() == []                  # buffered, not written
    assert j.commit()
    assert len(storage.list("wal/")) == 1        # ONE put for 10 records
    assert not j.commit()                        # empty commit: no segment
    j.append("meta", {"x": 1})
    j.commit()
    segs = storage.list("wal/")
    assert len(segs) == 2 and segs == sorted(segs)
    recs, stats = load_records(storage)
    assert len(recs) == 11 and stats["next_seq"] == 2


def test_journal_auto_flush_bounds_buffer():
    storage = InMemoryStorage()
    j = Journal(storage, max_buffer_bytes=1024)
    for i in range(50):
        j.append("ts", {"id": "a", "t": np.arange(16.0),
                        "v": np.arange(16.0)})
    assert j.auto_flushes > 0 and len(storage.list("wal/")) > 0
    j.commit()
    recs, _ = load_records(storage)
    assert len(recs) == 50


def test_journal_close_idempotent_and_final():
    storage = InMemoryStorage()
    j = Journal(storage)
    j.append("meta", {"x": 1})
    j.close()
    assert len(storage.list("wal/")) == 1        # flushed on close
    j.close()                                    # no-op, no raise
    j.append("meta", {"x": 2})                   # dropped after close
    j.commit()
    recs, _ = load_records(storage)
    assert len(recs) == 1


def test_journal_pipelined_commit_barrier_and_order():
    """Pipelined commit hands the put to a writer thread; barrier/close
    wait for it, segments land in seq order, and a writer-thread error
    surfaces at the NEXT commit (not silently)."""
    storage = InMemoryStorage()
    j = Journal(storage, pipelined=True)
    for k in range(4):
        j.append("meta", {"k": k})
        j.commit()
    j.barrier()
    segs = storage.list("wal/")
    assert len(segs) == 4 and segs == sorted(segs)
    recs, stats = load_records(storage)
    assert [d["k"] for _, d in recs] == [0, 1, 2, 3]
    j.close()
    # a crashing put in the writer thread re-raises on the next commit
    crashing = CrashingStorage(InMemoryStorage(), puts_before_crash=0)
    j2 = Journal(crashing, pipelined=True)
    j2.append("meta", {"x": 1})
    j2.commit()                                  # enqueues the dying put
    j2.append("meta", {"x": 2})
    with pytest.raises(ProcessCrash):
        j2.commit()


def test_forecast_batch_record_roundtrip():
    """Uniform fleet bins stack into (n, h) arrays; mixed batches fall
    back to the per-forecast list — both replay bitwise."""
    from repro.core.lineage import (Forecast, forecast_batch_record,
                                    forecasts_from_batch)
    rng = np.random.default_rng(5)

    def fc(i, h, banded=True):
        v = rng.normal(size=h)
        return Forecast(deployment_name=f"d{i}", signal="S", entity=f"e{i}",
                        created_at=float(i), times=np.arange(float(h)),
                        values=v, model_version=1,
                        lower=v - 1 if banded else None,
                        upper=v + 1 if banded else None)

    uniform = [fc(i, 7) for i in range(5)]
    d = forecast_batch_record(uniform)
    # all five share one horizon grid -> times dedupes to a single row
    assert "meta" in d and d["times"].shape == (7,)
    assert d["values"].shape == (5, 7)
    shifted = [fc(i, 7) for i in range(5)]       # distinct grids stay 2-D
    shifted[2] = Forecast(**{**shifted[2].__dict__,
                             "times": shifted[2].times + 0.5})
    d3 = forecast_batch_record(shifted)
    assert "meta" in d3 and d3["times"].shape == (5, 7)
    mixed = [fc(0, 7), fc(1, 9), fc(2, 7, banded=False)]
    d2 = forecast_batch_record(mixed)
    assert "forecasts" in d2                     # fallback format
    for batch, rec in ((uniform, d), (shifted, d3), (mixed, d2)):
        # through the actual codec, so stacking survives _enc/_dec
        [(op, dec)] = decode_records(encode_record("fc", rec))[0]
        back = forecasts_from_batch(dec)
        assert len(back) == len(batch)
        for a, b in zip(batch, back):
            assert a.deployment_name == b.deployment_name
            assert a.times.tobytes() == b.times.tobytes()
            assert a.values.tobytes() == b.values.tobytes()
            assert (a.lower is None) == (b.lower is None)
            if a.lower is not None:
                assert a.lower.tobytes() == b.lower.tobytes()
                assert a.upper.tobytes() == b.upper.tobytes()


def test_snapshot_compacts_and_recovery_prefers_it():
    storage = InMemoryStorage()
    c = Castor.open(storage=storage, snapshot_every=0)
    c.add_signal("S", "u")
    c.add_entity("E", "KIND")
    c.ingest("raw::E", np.arange(5.0), np.arange(5.0) * 2)
    c.link("raw::E", "S", "E")
    c.journal.commit()
    c.journal.snapshot()
    assert storage.list("wal/") == []            # compacted away
    snaps = storage.list("snap/")
    assert len(snaps) == 1
    c.ingest("raw::E", np.arange(5.0, 8.0), np.arange(5.0, 8.0) * 2)
    c.journal.commit()                           # post-snapshot delta
    c.close()
    c2 = Castor.open(storage=storage)
    t, v = c2.read("S", "E")
    np.testing.assert_array_equal(t, np.arange(8.0))
    np.testing.assert_array_equal(v, np.arange(8.0) * 2)
    assert c2._recovery_stats["snapshot"] == snaps[0]
    c2.close()


def test_corrupt_snapshot_falls_back_without_data_loss():
    """retain_segments keeps the pre-snapshot WAL; if the newest snapshot
    is corrupt, recovery must fall back to replaying it."""
    storage = InMemoryStorage()
    c = Castor.open(storage=storage, snapshot_every=0, retain_segments=True)
    c.ingest("raw::x", np.arange(4.0), np.arange(4.0))
    c.journal.commit()
    c.journal.snapshot()
    c.close()
    key = storage.list("snap/")[0]
    blob = bytearray(storage.get(key))
    blob[len(blob) // 2] ^= 0xFF
    storage.put(key, bytes(blob))
    c2 = Castor.open(storage=storage)
    assert c2._recovery_stats["corrupt_snapshots"] == 1
    assert c2._recovery_stats["snapshot"] is None
    t, _ = c2.store.read("raw::x")
    np.testing.assert_array_equal(t, np.arange(4.0))
    c2.close()


def test_snapshot_records_replay_into_equal_state():
    storage = InMemoryStorage()
    c = Castor.open(storage=storage)
    c.add_signal("S")
    c.add_entity("P", "ROOT")
    c.add_entity("E", "KIND", parent="P")
    c.ingest("raw::E", np.arange(6.0), np.sin(np.arange(6.0)))
    c.link("raw::E", "S", "E")
    frames = b"".join(snapshot_records(c))
    recs, _valid, clean = decode_records(frames)
    assert clean
    c2 = Castor()
    replay_records(c2, recs)
    assert c2.graph.parent("E").name == "P"
    np.testing.assert_array_equal(c2.store.read("raw::E")[0],
                                  c.store.read("raw::E")[0])
    c.close()


# ------------------------------------------------- Castor lifecycle


def test_castor_close_idempotent_and_context_manager():
    """Satellite: double-close and __exit__ after explicit close() are
    no-ops; buffered WAL records flush before storage is released."""
    storage = InMemoryStorage()
    c = Castor.open(storage=storage)
    c.ingest("raw::a", np.arange(3.0), np.arange(3.0))
    with c:
        c.close()                       # explicit close inside the block
    c.close()                           # triple close: still fine
    recs, _ = load_records(storage)     # the un-committed ingest survived
    assert any(op == "ts" for op, _d in recs)
    # plain (non-durable) castor: same contract
    p = Castor()
    with p:
        p.close()
    p.close()


def test_castor_open_filesystem_path(tmp_path):
    """Castor.open(path) end-to-end on a real directory with fsync'd
    atomic puts — reopen recovers across 'process restarts'."""
    root = str(tmp_path / "waldir")
    c = Castor.open(root)
    c.add_signal("S")
    c.add_entity("E")
    c.ingest("raw::E", np.arange(4.0), np.arange(4.0) * 3)
    c.link("raw::E", "S", "E")
    c.close()
    c2 = Castor.open(root)
    np.testing.assert_array_equal(c2.read("S", "E")[1], np.arange(4.0) * 3)
    c2.close()
    assert os.path.isdir(root)          # unowned root survives close


def test_filesystem_storage_list_sorted_deterministic(tmp_path):
    """Satellite: list() is sorted regardless of creation order or
    directory nesting (os.listdir order is filesystem-dependent)."""
    fs = FilesystemStorage(root=str(tmp_path / "b"), fsync=True)
    keys = ["z/9.log", "a/10.log", "m.log", "a/2.log", "z/1.log", "b/x/y.log"]
    for k in keys:
        fs.put(k, b"x")
    assert fs.list() == sorted(keys)
    assert fs.list("a/") == ["a/10.log", "a/2.log"]
    assert fs.list() == fs.list()       # stable across calls
    fs.close()


def test_weather_seed_survives_recovery():
    storage = InMemoryStorage()
    c = Castor.open(storage=storage, weather_seed=99)
    c.journal.commit()
    c.close()
    c2 = Castor.open(storage=storage, weather_seed=1)   # arg loses to WAL
    assert c2.weather_seed == 99
    c2.close()


# ------------------------------------------- crash-restart end-to-end


def _run_durable(plan, storage, k=None, **open_kw):
    """Drive ``plan`` on a durable castor over ``storage`` through the
    first ``k`` boundaries (all when None); leave the castor open."""
    c = Castor.open(storage=storage, **open_kw)
    drive_plan(c, plan, boundaries=plan["boundaries"][:k])
    return c


@pytest.mark.parametrize("kind", list(MODELS))
def test_crash_restart_forecasters_bitwise(kind):
    """Kill -9 after poll k (the cloned storage is byte-identical to a
    post-commit crash), reopen, catch up — bitwise-equal stores to the
    uninterrupted run, for every forecaster family."""
    cls, hp = MODELS[kind]
    plan = steady_plan(kind, cls, hp, n=2, polls=3)
    storage = InMemoryStorage()
    ref = _run_durable(plan, storage)
    ref_snap = snapshot_stores(ref)
    mid = _run_durable(plan, InMemoryStorage(), k=2)
    mid.journal.barrier()                 # pipelined write must land
    dead = clone_to_memory(mid.journal.storage)   # the post-crash disk
    mid.close()
    ref.close()
    c = Castor.open(storage=dead)
    assert c.versions.count() > 0                 # poll-k state recovered
    drive_plan(c, plan)                           # catch-up re-drive
    assert_stores_bitwise_equal(ref_snap, c, context=f"{kind} crash@2")
    c.close()


def test_crash_restart_detection_flow_bitwise():
    """The minutely detection flow: kill mid-stream, recover, catch up —
    detections AND the derived anomaly series are bitwise-equal (the
    atomic "det" record must keep them in lockstep across the tear)."""
    plan = detection_plan(n=2, minutes=8)
    ref = _run_durable(plan, InMemoryStorage())
    ref_snap = snapshot_stores(ref)
    ref.close()
    mid = _run_durable(plan, InMemoryStorage(), k=5)   # FLEET_NOW + 4 min
    mid.journal.barrier()
    dead = clone_to_memory(mid.journal.storage)
    mid.close()
    c = Castor.open(storage=dead)
    assert c.detections.count() > 0
    drive_plan(c, plan)
    assert_stores_bitwise_equal(ref_snap, c, context="detection crash@5")
    c.close()


def test_crash_restart_serverless_executor_bitwise():
    """Journaling also covers the serverless absorb path (worker results
    persist through the same stores the WAL hooks)."""
    plan = steady_plan("lr", LinearForecaster, {}, n=2, polls=2)
    ref = _run_durable(plan, InMemoryStorage())
    ref_snap = snapshot_stores(ref)
    ref.close()
    storage = InMemoryStorage()
    mid = Castor.open(storage=storage)
    drive_plan(mid, plan, executor="serverless",
               boundaries=plan["boundaries"][:1])
    mid.journal.barrier()
    dead = clone_to_memory(storage)
    mid.close()
    c = Castor.open(storage=dead)
    drive_plan(c, plan, executor="serverless")
    assert_stores_bitwise_equal(ref_snap, c, context="serverless crash@1")
    c.close()


def test_live_torn_write_crash_recovers():
    """A CrashingStorage kill mid-segment-put (half the bytes persisted)
    surfaces as a process death at the next commit — or at the barrier/
    close if the pipelined write of the LAST tick is the one that died;
    recovery drops the torn tail via checksum and catch-up restores
    bitwise equality."""
    plan = steady_plan("lr", LinearForecaster, {}, n=2, polls=3)
    ref = _run_durable(plan, InMemoryStorage())
    ref_snap = snapshot_stores(ref)
    ref.close()
    inner = InMemoryStorage()
    crashing = CrashingStorage(inner, puts_before_crash=2,
                               torn_fraction=0.5)
    with pytest.raises(ProcessCrash):
        _run_durable(plan, crashing).journal.barrier()
    assert crashing.crashed
    c = Castor.open(storage=inner)                # recover from the wreck
    assert c._recovery_stats["torn_segments"] == 1
    drive_plan(c, plan)
    assert_stores_bitwise_equal(ref_snap, c, context="live torn write")
    c.close()


def test_crash_state_sweep_smoke():
    """Mini chaos sweep (the full sweep is bench_durability's gate):
    every enumerated crash state of a short detection run — including
    torn and corrupted tails — recovers to bitwise equality."""
    plan = detection_plan(n=2, minutes=4)
    storage = InMemoryStorage()
    ref = _run_durable(plan, storage, snapshot_every=3,
                       retain_segments=True)
    ref_snap = snapshot_stores(ref)
    ref.close()
    states = list(crash_states(storage, torn=True, stride=4))
    assert len(states) > 5
    for label, st_ in states:
        c = Castor.open(storage=st_)
        drive_plan(c, plan)
        assert_stores_bitwise_equal(ref_snap, c, context=label)
        c.close()


def test_scheduler_retry_stamps_survive_restart():
    """A mark_failed retry queued at crash time must re-fire after
    recovery: the "sched" record re-arms the calendar entry."""
    from repro.core.scheduler import Job
    plan = steady_plan("lr", LinearForecaster, {}, n=2, polls=1)
    storage = InMemoryStorage()
    c = _run_durable(plan, storage)
    name = c.deployments.all()[0].name
    # a TRAIN retry at the already-covered FLEET_NOW boundary: the only
    # way it can ever fire again is through the persisted retry queue
    # (train_every is a day, so no new train boundary is due below)
    job = Job(deployment_name=name, package="lr", version="1.0",
              task="train", scheduled_at=FLEET_NOW,
              signal="ENERGY_LOAD", entity=c.deployments.get(name).entity)
    c.scheduler.mark_failed(job)
    c._commit_tick()                    # commit the retry delta, then die
    c.journal.barrier()
    dead = clone_to_memory(storage)
    c.close()
    c2 = Castor.open(storage=dead)
    assert (name, "train") in c2.scheduler._failed
    for pkg, ver, cls in plan["publish"]:
        c2.publish(pkg, ver, cls)
    jobs = c2.tick(FLEET_NOW + MINUTE)
    stamps = [r.job.scheduled_at for r in jobs
              if r.job.deployment_name == name and r.job.task == "train"]
    assert stamps == [FLEET_NOW]        # the queued retry re-fired
    assert all(r.ok for r in jobs)
    c2.close()
