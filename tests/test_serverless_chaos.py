"""Elastic storage-mediated serverless: chaos-proven exactly-once
execution (tests for repro/serverless/{chaos,storage,futures,autoscale}).

The core claim: under every seeded fault a real serverless platform
exhibits — kill-mid-action with partial persisted effects, dropped
results, duplicate delivery, straggler delay — the ModelVersionStore and
PredictionStore end up BITWISE identical to a fault-free run, because
at-least-once invocation composes with occurrence-stamped idempotent
persistence into exactly-once effects. Plus: property tests for the
object-store payload path, the futures/wait streaming surface, and the
telemetry-driven autoscaler.
"""
import tempfile
import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.forecast import (ANNForecaster, GAMForecaster, LSTMForecaster,
                            LinearForecaster)
from repro.serverless import (ALWAYS, ANY_COMPLETED, AutoscalePolicy,
                              Autoscaler, ChaosPolicy, FilesystemStorage,
                              FuturesTimeoutError, InMemoryStorage,
                              InlineBackend, InvocationMonitor,
                              InvocationPayload, ResponseFuture,
                              ServerlessExecutor, StorageKeyError, wait)
from repro.serverless.payload import (ForecastBlob, InvocationResult,
                                      JobOutcome, JobRef, VersionRef)
from repro.serverless.storage import (get_payload, get_result, payload_key,
                                      put_payload, put_result)
from repro.testing import (FLEET_NOW as NOW, HOUR,
                           assert_stores_bitwise_equal, build_steady_castor,
                           snapshot_stores)

MODELS = {
    "lr": (LinearForecaster, {}),
    "gam": (GAMForecaster, {}),
    "ann": (ANNForecaster, {"hidden": 8, "epochs": 10}),
    "lstm": (LSTMForecaster, {"hidden": 4, "epochs": 10}),
}
POLLS = 3
N = 3

#: each scenario fires on EVERY invocation's first delivery (prob 1.0,
#: max_attempt 1) — the retry is clean, so convergence is forced to go
#: through the fault path, never around it
CHAOS = {
    "kill": dict(seed=11, kill_mid_action=1.0),
    "drop": dict(seed=12, drop_result=1.0),
    "duplicate": dict(seed=13, duplicate=1.0),
    "delay": dict(seed=14, delay=1.0, delay_s=0.02),
}

_BASELINES = {}      # forecaster kind -> fault-free store snapshot


def _run_polls(kind, chaos):
    cls, hp = MODELS[kind]
    c = build_steady_castor(kind, cls, hp, n=N)
    ex = ServerlessExecutor(c, n_workers=2, chaos=chaos, max_retries=3,
                            backoff_base_s=0.01, speculative=False)
    c._serverless_ex = ex
    for k in range(POLLS):
        res = ex.run(c.scheduler.poll(NOW + k * HOUR))
        assert res and all(r.ok for r in res), \
            [r.error for r in res if not r.ok]
    return c, ex


def _baseline(kind):
    if kind not in _BASELINES:
        c, _ = _run_polls(kind, None)
        _BASELINES[kind] = snapshot_stores(c)
    return _BASELINES[kind]


# ------------------------------------------------- chaos equivalence
@pytest.mark.parametrize("fault", list(CHAOS))
@pytest.mark.parametrize("kind", list(MODELS))
def test_chaos_run_bitwise_equals_fault_free(kind, fault):
    """Acceptance: for every seeded chaos scenario and every forecaster,
    3 polls under injected faults leave the version + prediction stores
    bitwise identical to the fault-free inline run."""
    chaos = ChaosPolicy(**CHAOS[fault])
    c, ex = _run_polls(kind, chaos)
    assert chaos.summary().get(fault, 0) >= 1, chaos.summary()
    s = ex.stats()
    if fault in ("kill", "drop"):      # these fail the delivery: retried
        assert s["retries"] >= 1 and s["failed_invocations"] >= 1
    assert s["chaos"][fault] >= 1      # surfaced through executor stats
    assert_stores_bitwise_equal(_baseline(kind), c,
                                context=f"{kind}/{fault}")


def test_chaos_draws_are_deterministic():
    """Same (seed, invocation, attempt) -> same decisions, regardless of
    call order or thread interleaving; different seed -> different set."""
    def draws(seed):
        pol = ChaosPolicy(seed=seed, kill_mid_action=0.2, drop_result=0.2,
                          duplicate=0.2, delay=0.2, delay_s=0.0)
        out = []
        for i in range(40):
            p = InvocationPayload(invocation_id=f"inv-{i:06d}", jobs=())
            out.append((pol.kill_point(p), pol.should_drop(p),
                        pol.should_duplicate(p),
                        pol.maybe_delay(p) > 0.0))
        return out
    a, b = draws(5), draws(5)
    assert a == b
    assert a != draws(6)
    assert any(x != (None, False, False, False) for x in a)
    assert any(x == (None, False, False, False) for x in a)


def test_chaos_respects_max_attempt():
    pol = ChaosPolicy(seed=0, kill_mid_action=1.0, drop_result=1.0,
                      max_attempt=1)
    first = InvocationPayload(invocation_id="inv-1", jobs=(), attempt=1)
    retry = InvocationPayload(invocation_id="inv-1", jobs=(), attempt=2)
    assert pol.kill_point(first) is not None and pol.should_drop(first)
    assert pol.kill_point(retry) is None and not pol.should_drop(retry)


class _KillSecondBin(ChaosPolicy):
    """Kill every multi-bin action's first delivery after EXACTLY one
    completed bin — forces the partial-persisted-effects retry path that
    random seeds may or may not reach (a steady poll's single-bin actions
    can only die before any effect)."""

    def kill_point(self, payload):
        if payload.attempt > self.max_attempt or payload.n_bins < 2:
            return None
        with self._lock:
            self.injected["kill"] = self.injected.get("kill", 0) + 1
        return 1


def test_kill_mid_multibin_action_retries_partial_effects():
    """A catch-up action carrying 3 whole bins dies after persisting bin
    1; the retry re-executes ALL 3 bins and the persisted prefix must
    no-op at the stores — bitwise equal to the fault-free run, no
    duplicate or lost occurrence."""
    def run(chaos):
        c = build_steady_castor("lr", LinearForecaster, {}, n=4)
        ex = ServerlessExecutor(c, n_workers=2, chaos=chaos, max_retries=3,
                                backoff_base_s=0.01, speculative=False)
        c._serverless_ex = ex
        assert all(r.ok for r in ex.run(c.scheduler.poll(NOW)))
        # 3h stall: one aggregated catch-up action with 3 whole score bins
        res = ex.run(c.scheduler.poll(NOW + 3 * HOUR))
        assert len(res) == 12 and all(r.ok for r in res), \
            [r.error for r in res if not r.ok]
        return c, ex
    ref, _ = run(None)
    chaos = _KillSecondBin()
    got, ex = run(chaos)
    assert chaos.summary()["kill"] >= 1
    assert ex.stats()["retries"] >= 1
    assert_stores_bitwise_equal(ref, got, context="multibin-kill")


# ------------------------------------------------- storage properties
_DTYPES = ("float32", "float64", "int32", "int64")


def _roundtrip_payload(storage, vals, dtype_i, attempt):
    arr = np.asarray(vals, dtype=_DTYPES[dtype_i])
    job = JobRef(f"d{dtype_i}", "lr", "1.0", "score", NOW + attempt,
                 "ENERGY_LOAD", "E0", f"pk{dtype_i}")
    vr = VersionRef("d0", 1 + attempt, NOW - HOUR,
                    model_object={"params": {"w": arr},
                                  "nested": [arr[:1], {"b": arr * 2}],
                                  "scale": 2.5})
    p = InvocationPayload(invocation_id=f"inv-{dtype_i}-{attempt}",
                          jobs=(job,), versions=(vr,),
                          created_at=1.5, attempt=attempt)
    q = get_payload(storage, put_payload(storage, p))
    assert q.invocation_id == p.invocation_id and q.attempt == p.attempt
    assert q.jobs == p.jobs
    mo = q.versions[0].model_object
    for got, ref in ((mo["params"]["w"], arr),
                     (mo["nested"][0], arr[:1]),
                     (mo["nested"][1]["b"], arr * 2)):
        assert got.dtype == ref.dtype and got.shape == ref.shape
        assert got.tobytes() == ref.tobytes()        # bitwise
    assert mo["scale"] == 2.5

    res = InvocationResult(
        invocation_id=p.invocation_id, worker_id="w0", cold_start=False,
        started_at=2.0, finished_at=3.0,
        outcomes=(JobOutcome(ref=job, ok=True, duration_s=0.1),),
        forecasts=(ForecastBlob(
            deployment_name=job.deployment_name, signal=job.signal,
            entity=job.entity, created_at=job.scheduled_at,
            times=np.asarray(vals, dtype="float64"),
            values=arr.astype("float64") * 0.5, model_version=1),))
    r = get_result(storage, put_result(storage, res, p.attempt))
    assert r.outcomes == res.outcomes
    fb, fb0 = r.forecasts[0], res.forecasts[0]
    assert fb.times.tobytes() == fb0.times.tobytes()
    assert fb.values.tobytes() == fb0.values.tobytes()


@settings(max_examples=20)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6),
                min_size=0, max_size=32),
       st.integers(min_value=0, max_value=3),
       st.integers(min_value=1, max_value=4))
def test_storage_roundtrip_inmemory_bitwise(vals, dtype_i, attempt):
    _roundtrip_payload(InMemoryStorage(), vals, dtype_i, attempt)


@settings(max_examples=10)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6),
                min_size=0, max_size=32),
       st.integers(min_value=0, max_value=3),
       st.integers(min_value=1, max_value=4))
def test_storage_roundtrip_filesystem_bitwise(vals, dtype_i, attempt):
    # tempdir managed inline: the hypothesis-compat wrapper takes no
    # pytest fixtures
    with tempfile.TemporaryDirectory() as root:
        _roundtrip_payload(FilesystemStorage(root), vals, dtype_i, attempt)


def test_storage_semantics():
    for storage in (InMemoryStorage(), FilesystemStorage()):
        storage.put("jobs/a/1.json", b"one")
        storage.put("jobs/a/2.json", b"two")
        storage.put("results/a/1.json", b"three")
        assert storage.get("jobs/a/2.json") == b"two"
        assert storage.list("jobs/") == ["jobs/a/1.json", "jobs/a/2.json"]
        assert storage.list() == ["jobs/a/1.json", "jobs/a/2.json",
                                  "results/a/1.json"]
        storage.put("jobs/a/2.json", b"TWO")          # overwrite
        assert storage.get("jobs/a/2.json") == b"TWO"
        assert storage.delete("jobs/a/2.json")
        assert not storage.delete("jobs/a/2.json")
        with pytest.raises(StorageKeyError):
            storage.get("jobs/a/2.json")
        for bad in ("", "../escape", "a/../b", "a b", "jobs/é"):
            with pytest.raises(ValueError):
                storage.put(bad, b"x")
        st_ = storage.stats()
        assert st_["objects"] == 2 and st_["puts"] == 4
        storage.clear()
        assert storage.list() == []
        storage.close()


def test_filesystem_storage_owned_root_removed_on_close():
    import os
    storage = FilesystemStorage()
    root = storage.root
    storage.put("jobs/x.json", b"x")
    assert os.path.isdir(root)
    storage.close()
    assert not os.path.exists(root)
    # a shared (caller-owned) root survives close
    with tempfile.TemporaryDirectory() as shared:
        FilesystemStorage(shared).close()
        assert os.path.isdir(shared)


def test_inline_backend_storage_mediated_bitwise():
    """The inline path with storage mediation (payload and result each
    round-trip through the object store) stays bitwise equal to the
    direct path, and the store sees the traffic."""
    storage = InMemoryStorage()
    ref, _ = _run_polls("lr", None)
    cls, hp = MODELS["lr"]
    c = build_steady_castor("lr", cls, hp, n=N)
    ex = ServerlessExecutor(c, n_workers=2, storage=storage,
                            speculative=False)
    c._serverless_ex = ex
    for k in range(POLLS):
        res = ex.run(c.scheduler.poll(NOW + k * HOUR))
        assert res and all(r.ok for r in res)
    assert_stores_bitwise_equal(ref, c, context="storage-mediated")
    st_ = ex.stats()["storage"]
    assert st_["puts"] >= 2 * st_["gets"] / 2 >= 2    # payloads + results
    assert st_["bytes_in"] > 0 and st_["bytes_out"] > 0
    assert storage.list("jobs/") and storage.list("results/")
    assert payload_key("inv-000001", 1) in storage.list("jobs/")


# ------------------------------------------------- futures / wait
def _complete_later(fut, delay, value):
    def run():
        time.sleep(delay)
        fut._set_result(value)
    threading.Thread(target=run, daemon=True).start()


def test_wait_any_returns_in_completion_order():
    fs = [ResponseFuture(f"inv-{i}") for i in range(3)]
    _complete_later(fs[0], 0.30, "slow")
    _complete_later(fs[1], 0.02, "fast")
    _complete_later(fs[2], 0.15, "mid")
    done, pending = wait(fs, return_when=ANY_COMPLETED, timeout=5.0)
    assert [f.invocation_id for f in done] == ["inv-1"]
    assert len(pending) == 2
    done, pending = wait(fs, timeout=5.0)             # ALL_COMPLETED
    assert not pending
    assert [f.invocation_id for f in done] == ["inv-1", "inv-2", "inv-0"]
    assert [f.result() for f in done] == ["fast", "mid", "slow"]


def test_wait_always_never_blocks():
    fs = [ResponseFuture("a"), ResponseFuture("b")]
    fs[0]._set_result(1)
    t0 = time.perf_counter()
    done, pending = wait(fs, return_when=ALWAYS)
    assert time.perf_counter() - t0 < 0.05
    assert [f.invocation_id for f in done] == ["a"]
    assert [f.invocation_id for f in pending] == ["b"]


def test_wait_timeout_cancels_pending_and_raises():
    fs = [ResponseFuture(f"inv-{i}") for i in range(2)]
    _complete_later(fs[0], 0.02, "ok")
    with pytest.raises(FuturesTimeoutError) as ei:
        wait(fs, timeout=0.2)
    assert [f.invocation_id for f in ei.value.pending] == ["inv-1"]
    assert fs[1].cancelled and fs[1].done
    assert fs[1].result(throw_except=False) is None
    assert fs[0].success and fs[0].result() == "ok"
    # cancellation is terminal: a late result does not overwrite it
    assert not fs[1]._set_result("late")
    assert fs[1].cancelled


class _DelayNth(InlineBackend):
    """Delays the Nth (1-based) invoke call — a deterministic straggler
    for streaming tests."""

    def __init__(self, system, *, n_workers=2, nth=2, delay_s=0.6):
        super().__init__(system, n_workers=n_workers)
        self.nth, self.delay_s = nth, delay_s
        self._calls = 0
        self._calls_lock = threading.Lock()

    def invoke(self, payload, worker_id):
        with self._calls_lock:
            self._calls += 1
            me = self._calls
        if me == self.nth:
            time.sleep(self.delay_s)
        return super().invoke(payload, worker_id)


def test_run_async_streams_results_before_slowest_completes():
    """submit()/wait(ANY): the early-finishing action's forecasts are in
    the PredictionStore while the straggler is still executing — the
    anti-phase-barrier property the futures surface exists for."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=2)
    ex = ServerlessExecutor(c, backend=_DelayNth(c, nth=2, delay_s=0.8),
                            aggregation=2, speculative=False)
    c._serverless_ex = ex
    assert all(r.ok for r in ex.run(c.scheduler.poll(NOW)))   # train+score
    # 2h stall: two catch-up score bins -> two invocations (aggregation=2)
    jobs = c.scheduler.poll(NOW + 2 * HOUR)
    assert len(jobs) == 4
    ex.backend.nth = ex.backend._calls + 2     # straggle the SECOND one
    fs = ex.run_async(jobs)
    assert len(fs) == 2
    done, pending = wait(fs, return_when=ANY_COMPLETED, timeout=30.0)
    assert len(done) == 1 and len(pending) == 1
    assert not pending[0].done
    # the completed future's bin is already persisted and queryable...
    done_stamps = {r.scheduled_at for r in done[0].payload.jobs}
    hist = {f.created_at for f in c.predictions.history("s-Z_PRO_0_0")}
    assert done_stamps <= hist
    # ...while the straggler's bin is not there yet
    pending_stamps = {r.scheduled_at for r in pending[0].payload.jobs}
    assert not (pending_stamps & hist)
    done, pending = wait(fs, timeout=30.0)
    assert not pending and all(f.success for f in done)
    assert len(c.predictions.history("s-Z_PRO_0_0")) == 3
    assert all(all(o.ok for o in f.result().outcomes) for f in done)


def test_run_async_rejects_mixed_phases():
    c = build_steady_castor("lr", LinearForecaster, {}, n=2)
    ex = ServerlessExecutor(c, n_workers=1, speculative=False)
    c._serverless_ex = ex
    jobs = c.scheduler.poll(NOW)            # train + score due together
    with pytest.raises(ValueError, match="single-phase"):
        ex.run_async(jobs)
    assert all(r.ok for r in ex.run(jobs))  # jobs still runnable


def test_wait_timeout_cancellation_stops_retries_and_requeues():
    """A cancelled in-flight invocation is not retried; its jobs are
    marked failed so the scheduler re-fires the occurrences."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=2)
    ex = ServerlessExecutor(c, backend=_DelayNth(c, nth=1, delay_s=0.8),
                            speculative=False, max_retries=5)
    c._serverless_ex = ex
    assert all(r.ok for r in ex.run(c.scheduler.poll(NOW)))
    jobs = c.scheduler.poll(NOW + HOUR)
    ex.backend.nth = ex.backend._calls + 1     # delay the NEXT invocation
    fs = ex.run_async(jobs)
    with pytest.raises(FuturesTimeoutError):
        wait(fs, timeout=0.1)
    assert all(f.cancelled for f in fs)
    deadline = time.time() + 10.0
    while time.time() < deadline:           # drive thread finishes the
        refire = c.scheduler.poll(NOW + HOUR + 1.0)   # in-flight action,
        if refire:                          # then observes the cancel
            break
        time.sleep(0.05)
    assert sorted({j.scheduled_at for j in refire}) == [NOW + HOUR]
    assert ex.stats()["retries"] == 0
    # the occurrences converge on the re-fire (idempotent against any
    # late effects of the cancelled copy)
    assert all(r.ok for r in ex.run(refire))
    assert len(c.predictions.history("s-Z_PRO_0_0")) == 2


# ------------------------------------------------- autoscaler
def test_autoscaler_scales_out_and_reaps_deterministically():
    """Pure decision logic against injected clock + telemetry: scale out
    while backlogged and saturated (bounded by max_workers), reap idle
    containers past the TTL (bounded by min_workers), never reuse ids."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=2)
    be = InlineBackend(c, n_workers=2)
    pol = AutoscalePolicy(min_workers=2, max_workers=4,
                          target_queue_p95_s=0.5, idle_ttl_s=10.0)
    a = Autoscaler(be, pol, InvocationMonitor())
    t = 100.0
    a.observe(backlog=3, busy={"w0": 1, "w1": 1}, now=t)      # saturated
    assert be.worker_ids() == ["w0", "w1", "w2"]
    a.observe(backlog=3, busy={w: 1 for w in be.worker_ids()}, now=t + 1)
    assert be.worker_ids() == ["w0", "w1", "w2", "w3"]
    a.observe(backlog=9, busy={w: 1 for w in be.worker_ids()}, now=t + 2)
    assert len(be.worker_ids()) == 4                  # capped at max
    a.observe(backlog=5, busy={"w0": 1}, now=t + 3)   # idle capacity:
    assert len(be.worker_ids()) == 4                  # no scale-out
    # idle reaping: w0 busy + recently used, the rest idle past TTL
    a.note_dispatch("w0", now=t + 3)
    reaped = a.reap_idle(busy={"w0": 1}, now=t + 50)
    assert len(be.worker_ids()) == pol.min_workers
    assert "w0" in be.worker_ids() and set(reaped) & {"w2", "w3"}
    s = a.summary()
    assert s["scale_outs"] == 2 and s["reaps"] == 2
    assert s["peak_workers"] == 4 and s["workers"] == 2
    assert [e["action"] for e in s["events"]] \
        == ["scale_out", "scale_out", "reap", "reap"]
    assert be.add_worker() == "w4"                    # ids never reused


def test_autoscaler_queue_p95_signal():
    """Scale-out also triggers on recent queue p95 above target even when
    not every worker is busy at the instant of observation."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=2)
    be = InlineBackend(c, n_workers=1)
    mon = InvocationMonitor()
    for i in range(10):     # synthetic slow-queue telemetry
        p = InvocationPayload(invocation_id=f"inv-{i}", jobs=(),
                              created_at=0.0)
        r = InvocationResult(invocation_id=p.invocation_id, worker_id="w0",
                             cold_start=False, started_at=2.0,
                             finished_at=2.1, outcomes=())
        mon.record(payload=p, result=r, worker_id="w0")
    assert mon.recent_queue_p95() == pytest.approx(2.0)
    a = Autoscaler(be, AutoscalePolicy(min_workers=1, max_workers=2,
                                       target_queue_p95_s=0.5), mon)
    a.observe(backlog=1, busy={}, now=50.0)
    assert len(be.worker_ids()) == 2
    assert a.summary()["events"][0]["reason"] == "queue_p95"


class _SlowBackend(InlineBackend):
    """Uniform per-invocation stall so a catch-up backlog saturates a
    small pool long enough for the autoscaler to react."""

    def invoke(self, payload, worker_id):
        time.sleep(0.05)
        return super().invoke(payload, worker_id)


def test_elastic_executor_scales_under_load_and_reaps_idle():
    """End-to-end: a backlogged catch-up cycle on a min-sized pool scales
    out (work-stealing dispatch drains the backlog through the new
    containers), completes every job exactly once, and the pool reaps
    back to min after the work drains."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=4)
    cref = build_steady_castor("lr", LinearForecaster, {}, n=4)
    exref = ServerlessExecutor(cref, n_workers=1, speculative=False)
    cref._serverless_ex = exref
    be = _SlowBackend(c, n_workers=1)
    ex = ServerlessExecutor(
        c, backend=be, aggregation=4, speculative=False,
        autoscale=AutoscalePolicy(min_workers=1, max_workers=3,
                                  target_queue_p95_s=0.01, idle_ttl_s=0.0))
    c._serverless_ex = ex
    assert all(r.ok for r in ex.run(c.scheduler.poll(NOW)))
    assert all(r.ok for r in exref.run(cref.scheduler.poll(NOW)))
    # 6h stall: 6 catch-up bins of 4 jobs; aggregation=4 -> 6 invocations
    res = ex.run(c.scheduler.poll(NOW + 6 * HOUR))
    assert len(res) == 24 and all(r.ok for r in res), \
        [r.error for r in res if not r.ok]
    assert all(r.ok for r in exref.run(cref.scheduler.poll(NOW + 6 * HOUR)))
    s = ex.stats()
    assert s["autoscale"]["scale_outs"] >= 1
    assert s["autoscale"]["peak_workers"] >= 2
    # ttl=0: run() reaps every idle container back down to min at the end
    assert s["autoscale"]["reaps"] >= 1 and s["workers"] == 1
    # elasticity never compromises effects: bitwise equal to the
    # fixed-single-worker reference
    assert_stores_bitwise_equal(cref, c, context="elastic")
