"""Elastic restart: a checkpoint saved under one mesh restores onto a
DIFFERENT (shrunken) mesh with new shardings — the node-failure recovery
path claimed in DESIGN.md. Subprocess (needs 8 placeholder devices)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os, tempfile, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.checkpoint import save, restore
    from repro.distributed.fault import elastic_remesh, largest_mesh_shape
    from repro.launch.mesh import make_mesh

    mesh8 = make_mesh((2, 4), ("data", "model"))
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "step": jnp.asarray(5)}
    sh8 = {"w": NamedSharding(mesh8, P("data", "model")),
           "step": NamedSharding(mesh8, P())}
    placed = jax.tree_util.tree_map(jax.device_put, tree, sh8)
    d = tempfile.mkdtemp()
    save(d + "/ck", placed, step=5)

    # a node died: rebuild the largest mesh from 7 surviving devices
    surv = jax.devices()[:7]
    assert largest_mesh_shape(7, model_axis=4) == (1, 4)
    mesh4 = elastic_remesh(surv, model_axis=4)
    assert mesh4.devices.size == 4
    sh4 = {"w": NamedSharding(mesh4, P("data", "model")),
           "step": NamedSharding(mesh4, P())}
    got, man = restore(d + "/ck", tree, shardings=sh4)
    ok = bool(np.allclose(np.asarray(got["w"]), np.asarray(tree["w"])))
    ok = ok and man["step"] == 5
    ok = ok and got["w"].sharding.mesh.devices.size == 4
    # and training math continues on the new mesh
    y = jax.jit(lambda w: (w @ w.T).sum())(got["w"])
    ok = ok and bool(np.isfinite(float(y)))
    print(json.dumps({"ok": ok}))
""")


def test_checkpoint_restores_onto_shrunken_mesh():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=300,
        env={"PYTHONPATH": str(Path(__file__).parent.parent / "src"),
             "PATH": "/usr/bin:/bin",
             # without this, jax probes for accelerator plugins and hangs
             # on hosts with a baked-in (but absent) TPU toolchain
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert json.loads(proc.stdout.strip().splitlines()[-1])["ok"]
