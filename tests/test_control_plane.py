"""Control-plane tests: calendar-queue scheduler, drift-proof schedule
arithmetic, indexed deployment store, interned semantic graph, interned
bin grouping (PR 7).

The equivalence anchor throughout is the PRE-refactor behavior: the
old full-fleet scanner is reimplemented here as a reference model and
the calendar queue is driven against it on randomized fleets — same
jobs, same order, same watermark/retry semantics.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from _hypothesis_compat import given, settings, st
from repro.core.deployment import DeploymentStore, ModelDeployment
from repro.core.interning import InternTable
from repro.core.registry import ModelInterface, ModelRegistry
from repro.core.scheduler import (Job, ModelScheduler, Schedule, bin_jobs,
                                  bin_key_of)
from repro.core.semantics import Entity, SemanticGraph, Signal

HOUR = 3600.0
DAY = 24 * HOUR


class _Dummy(ModelInterface):
    def load(self):
        pass

    def transform(self):
        pass

    def train(self):
        return {}

    def score(self, model_object):
        return [], []


def make_registry(packages=("pkg",)):
    reg = ModelRegistry()
    for p in packages:
        reg.register(p, "1.0", _Dummy)
    return reg


def make_system(packages=("pkg",), max_catchup=168):
    deps = DeploymentStore()
    reg = make_registry(packages)
    sched = ModelScheduler(deps, reg, max_catchup=max_catchup)
    return deps, reg, sched


def dep(name, *, package="pkg", train=None, score=None, params=None,
        signal="S", entity="E", version=None, rank=0):
    return ModelDeployment(
        name=name, package=package, version=version, signal=signal,
        entity=entity, train=train, score=score,
        user_params=dict(params or {}), rank=rank)


# ===================================================================
# Schedule arithmetic: drift-proof occurrence indexing
# ===================================================================

# (start, every, k) triples where the OLD ``int((t - start) // every)``
# arithmetic miscounted: stepping from boundary k to boundary k+1
# reported 0 or 2 occurrences due instead of exactly 1
OLD_DRIFT_CASES = [
    (16527635.528529095, 5744.376150152334, 40973523),      # old: 0
    (912755577.2777218, 19.03835011408123, 970742837),      # old: 2
    (33585575.30546436, 569.987533589485, 857404276),       # old: 2
    (28319671.145462967, 3.100268573409856e-05, 8284309),   # old: 2
    (647189511.5742501, 24.24495251003103, 670624414),      # old: 2
]


@pytest.mark.parametrize("start,every,k", OLD_DRIFT_CASES)
def test_drift_regression_one_step_fires_once(start, every, k):
    s = Schedule(start, every)
    b0 = start + k * every
    b1 = start + (k + 1) * every
    assert s.occurrences_due(b0, b1) == 1
    assert s.boundaries_due(b0, b1) == [b1]
    assert s.next_boundary_after(b0) == b1
    # and the boundary instant itself is not double-counted
    assert s.occurrences_due(b1, b1) == 0


def _lattice(start, exp_every, k):
    """Build (Schedule, boundary_k, boundary_k+1); None if ``every`` is
    below the float lattice's resolution at this magnitude (degenerate:
    start + k*every stops being strictly increasing)."""
    every = float(10.0 ** exp_every)
    s = Schedule(start, every)
    b0 = start + k * every
    b1 = start + (k + 1) * every
    if not (start < b0 < b1):
        return None
    return s, b0, b1


@settings(max_examples=200)
@given(start=st.floats(min_value=1e-3, max_value=1e9),
       exp_every=st.floats(min_value=-6.0, max_value=6.0),
       k=st.integers(min_value=1, max_value=10**9))
def test_drift_property_single_step(start, exp_every, k):
    lat = _lattice(start, exp_every, k)
    if lat is None:
        return
    s, b0, b1 = lat
    # exactly one firing per consecutive boundary pair, stamped at b1
    assert s.occurrences_due(b0, b1) == 1
    assert s.boundaries_due(b0, b1) == [b1]
    # a boundary never re-fires against itself
    assert s.occurrences_due(b1, b1) == 0
    assert s.boundaries_due(b1, b1) == []
    # the armed wake-up agrees with the firing lattice
    assert s.next_boundary_after(b0) == b1


@settings(max_examples=200)
@given(start=st.floats(min_value=1e-3, max_value=1e9),
       exp_every=st.floats(min_value=-6.0, max_value=6.0),
       k=st.integers(min_value=1, max_value=10**9),
       span=st.integers(min_value=1, max_value=50),
       frac=st.floats(min_value=0.0, max_value=0.999))
def test_drift_property_window_consistency(start, exp_every, k, span, frac):
    lat = _lattice(start, exp_every, k)
    if lat is None:
        return
    s, b0, _ = lat
    every = s.every
    now = start + (k + span) * every + frac * every
    n = s.occurrences_due(b0, now)
    bs = s.boundaries_due(b0, now)
    # count and stamps come from the same arithmetic
    assert len(bs) == n
    # every stamp lies in (last_run, now], strictly increasing
    assert all(b0 < b <= now for b in bs)
    assert all(x < y for x, y in zip(bs, bs[1:]))
    # additivity: splitting the window at any returned boundary conserves
    # the total count (no occurrence lost or double-counted at the seam)
    if bs:
        mid = bs[len(bs) // 2]
        assert s.occurrences_due(b0, mid) \
            + s.occurrences_due(mid, now) == n
        # the last stamp's successor is strictly beyond now
        assert s.next_boundary_after(bs[-1]) > now


@settings(max_examples=100)
@given(start=st.floats(min_value=1e-3, max_value=1e9),
       exp_every=st.floats(min_value=-6.0, max_value=6.0),
       # small k: the no-limit branch below MATERIALIZES k+1 boundaries
       k=st.integers(min_value=1, max_value=500))
def test_drift_property_before_start_and_limit(start, exp_every, k):
    lat = _lattice(start, exp_every, k)
    if lat is None:
        return
    s, b0, _ = lat
    assert s.occurrences_due(None, start - 1.0) == 0
    assert s.occurrences_due(None, b0) == 1          # fire once, catch up
    assert s.next_boundary_after(start - 1.0) == s.start
    # a pre-start watermark owes every boundary up to now
    bs_all = s.boundaries_due(s.start - 1.0, b0)
    assert len(bs_all) == k + 1
    # limit keeps the MOST RECENT stamps
    bs_lim = s.boundaries_due(s.start - 1.0, b0, limit=3)
    assert bs_lim == bs_all[-3:]


# ===================================================================
# Calendar queue: remove / re-register / schedule edits
# ===================================================================

def test_remove_then_reregister_fires_from_scratch():
    """The satellite bugfix: ``remove`` must clear the scheduler's
    watermark and queued retries, so a same-name re-registration behaves
    exactly like a brand-new deployment."""
    deps, _, sched = make_system()
    deps.register(dep("m", score=Schedule(0.0, HOUR)))
    jobs = sched.poll(10 * HOUR)
    assert len(jobs) == 1                       # first firing collapses
    assert jobs[0].scheduled_at == 10 * HOUR
    sched.mark_failed(jobs[0])                  # leave a queued retry too

    deps.remove("m")
    assert sched.poll(11 * HOUR) == []          # nothing lingers
    assert ("m", "score") not in sched._last
    assert ("m", "score") not in sched._failed

    deps.register(dep("m", score=Schedule(0.0, HOUR)))
    jobs = sched.poll(12 * HOUR)
    # from scratch: ONE collapsed first firing at the poll's boundary —
    # not a catch-up from the stale watermark, not the old retry stamp
    assert [j.scheduled_at for j in jobs] == [12 * HOUR]
    jobs = sched.poll(13 * HOUR)
    assert [j.scheduled_at for j in jobs] == [13 * HOUR]


def test_schedule_edit_rekeys_calendar_entry():
    """Redeploying with a different Schedule must re-key the wake-up:
    firings follow the NEW lattice immediately, with no ghost wake-ups or
    stamps from the old one."""
    deps, _, sched = make_system()
    deps.register(dep("m", score=Schedule(0.0, HOUR)))
    assert len(sched.poll(HOUR)) == 1

    deps.remove("m")
    deps.register(dep("m", score=Schedule(0.0, DAY)))   # edited: hourly -> daily
    jobs = sched.poll(2 * HOUR)     # old lattice had a boundary here...
    # ...and the fresh first firing stamps at the NEW lattice's last
    # boundary <= now (0.0), not at the old hourly boundary
    assert [j.scheduled_at for j in jobs] == [0.0]
    assert sched.poll(5 * HOUR) == []   # new lattice: nothing until DAY
    jobs = sched.poll(DAY)
    assert [j.scheduled_at for j in jobs] == [DAY]


def test_remove_clears_both_tasks_and_train_schedule_edits():
    deps, _, sched = make_system()
    deps.register(dep("m", train=Schedule(0.0, DAY), score=Schedule(0.0, HOUR)))
    jobs = sched.poll(DAY)
    assert [(j.task, j.scheduled_at) for j in jobs] == \
        [("train", DAY), ("score", DAY)]
    deps.remove("m")
    deps.register(dep("m", score=Schedule(0.0, HOUR)))  # train schedule dropped
    jobs = sched.poll(2 * DAY)
    assert [(j.task, j.scheduled_at) for j in jobs] == [("score", 2 * DAY)]


def test_mark_failed_after_remove_is_dropped():
    """A failure surfacing after its deployment was removed (job was in
    flight) must not queue a retry against a future re-registration."""
    deps, _, sched = make_system()
    deps.register(dep("m", score=Schedule(0.0, HOUR)))
    (job,) = sched.poll(HOUR)
    deps.remove("m")
    sched.mark_failed(job)                      # in-flight failure lands late
    assert sched._failed == {}
    deps.register(dep("m", score=Schedule(0.0, HOUR)))
    jobs = sched.poll(2 * HOUR)
    assert [j.scheduled_at for j in jobs] == [2 * HOUR]   # no replayed retry


def test_retries_and_new_boundaries_share_catchup_cap():
    """Queued failure stamps and newly missed boundaries share ONE
    ``max_catchup`` budget per (deployment, task); the most recent
    boundaries win (queued retries are the oldest, so they are dropped
    first)."""
    deps, _, sched = make_system(max_catchup=4)
    deps.register(dep("m", score=Schedule(0.0, HOUR)))
    (j0,) = sched.poll(HOUR)
    sched.mark_failed(j0)                       # queued retry at 1h
    # stall until 10h: retry(1h) + new(2..10h) = 10 candidates, cap 4
    jobs = sched.poll(10 * HOUR)
    assert [j.scheduled_at / HOUR for j in jobs] == [7, 8, 9, 10]
    # the queued retry was dropped along with the older new boundaries
    assert sched._failed == {}

    # when the combined set fits, the retry fires at its ORIGINAL stamp
    (j1,) = [j for j in sched.poll(11 * HOUR)]
    sched.mark_failed(j1)
    jobs = sched.poll(13 * HOUR)
    assert [j.scheduled_at / HOUR for j in jobs] == [11, 12, 13]


def test_spurious_wakeup_rearms_without_emitting():
    """Duplicate retry entries whose stamps already cleared pop as
    spurious wake-ups: no jobs, but the boundary entry re-arms so the
    deployment keeps firing."""
    deps, _, sched = make_system()
    deps.register(dep("m", score=Schedule(0.0, HOUR)))
    (j,) = sched.poll(HOUR)
    sched.mark_failed(j)
    sched.mark_failed(j)                        # duplicate retry entry
    jobs = sched.poll(HOUR + 60.0)              # retry fires once
    assert [x.scheduled_at for x in jobs] == [HOUR]
    assert sched.poll(HOUR + 120.0) == []       # duplicate: spurious, silent
    jobs = sched.poll(2 * HOUR)                 # and the boundary still armed
    assert [x.scheduled_at for x in jobs] == [2 * HOUR]


def test_poll_atomic_on_registry_failure_restores_heap():
    """A poll that raises (unpublished package) must leave the calendar
    queue able to re-fire everything on the next poll."""
    deps, reg, sched = make_system()
    deps.register(dep("a", score=Schedule(0.0, HOUR)))
    deps.register(dep("z", package="ghost", score=Schedule(0.0, HOUR)))
    with pytest.raises(KeyError):
        sched.poll(HOUR)
    reg.register("ghost", "1.0", _Dummy)        # publish, then retry the poll
    jobs = sched.poll(HOUR)
    assert sorted(j.deployment_name for j in jobs) == ["a", "z"]
    assert all(j.scheduled_at == HOUR for j in jobs)


def test_scheduler_seeds_from_prepopulated_store():
    """A scheduler built over an already-populated store must arm
    wake-ups for the existing fleet (the subscribe-then-seed path)."""
    deps = DeploymentStore()
    deps.register(dep("m", score=Schedule(0.0, HOUR)))
    sched = ModelScheduler(deps, make_registry())
    assert [j.scheduled_at for j in sched.poll(HOUR)] == [HOUR]


def test_poll_cost_tracks_due_not_fleet():
    """The point of the calendar queue: a steady-state poll where nothing
    is due pops zero entries regardless of fleet size."""
    deps, _, sched = make_system()
    for i in range(500):
        deps.register(dep(f"idle-{i:04d}", score=Schedule(0.0, 10_000 * DAY)))
    deps.register(dep("hot", score=Schedule(0.0, HOUR)))
    jobs = sched.poll(HOUR)                     # drains every start entry once
    assert len(jobs) == 501
    before = len(sched._heap)
    for k in range(2, 6):
        jobs = sched.poll(k * HOUR)
        assert [j.deployment_name for j in jobs] == ["hot"]
    # steady state: one boundary entry per live key, no growth
    assert len(sched._heap) == before


# ===================================================================
# Calendar queue vs the old full-fleet scanner (reference model)
# ===================================================================

class _OldScanner:
    """The pre-refactor scheduler, verbatim semantics: scan every
    deployment each poll, plan, then commit after all lookups."""

    def __init__(self, deployments, registry, max_catchup=168):
        self.deployments = deployments
        self.registry = registry
        self.max_catchup = max_catchup
        self._last = {}
        self._failed = {}

    def poll(self, now):
        jobs, planned = [], []
        for d in self.deployments.all():
            for task in ("train", "score"):
                sched = getattr(d, task)
                if sched is None:
                    continue
                key = (d.name, task)
                new = sched.boundaries_due(self._last.get(key), now,
                                           self.max_catchup)
                stamps = sorted(self._failed.get(key, ())) + new
                if self.max_catchup:
                    stamps = stamps[-self.max_catchup:]
                if not stamps:
                    continue
                version = self.registry.resolve_version(d.package, d.version)
                planned.append((d, task, key, stamps, bool(new), version))
        for d, task, key, stamps, advance, version in planned:
            self._failed.pop(key, None)
            if advance:
                self._last[key] = now
            for ts in dict.fromkeys(stamps):
                jobs.append(Job(
                    deployment_name=d.name, package=d.package,
                    version=version, task=task, scheduled_at=ts,
                    signal=d.signal, entity=d.entity,
                    user_params_key=repr(sorted(d.user_params.items()))))
        jobs.sort(key=lambda j: (j.task != "train", j.scheduled_at,
                                 j.deployment_name))
        return jobs

    def mark_failed(self, job):
        self._failed.setdefault((job.deployment_name, job.task),
                                set()).add(job.scheduled_at)


def test_poll_order_determinism_vs_old_scanner():
    """Drive the calendar queue and the old scanner over the same
    randomized fleet, poll instants and failure pattern: identical job
    sequences, poll after poll."""
    rng = np.random.default_rng(7)
    deps, reg, new = make_system(packages=("p0", "p1", "p2"), max_catchup=6)
    old = _OldScanner(deps, reg, max_catchup=6)

    fleet = []
    for i in range(40):
        d = dep(f"d{i:03d}",
                package=f"p{rng.integers(3)}",
                train=(Schedule(float(rng.integers(0, 48)) * HOUR,
                                float(rng.integers(1, 7)) * DAY)
                       if rng.random() < 0.6 else None),
                score=(Schedule(float(rng.integers(0, 24)) * HOUR,
                                float(rng.integers(1, 13)) * HOUR)
                       if rng.random() < 0.9 else None),
                params={"h": int(rng.integers(1, 4))})
        fleet.append(deps.register(d))

    now = 0.0
    for step in range(60):
        now += float(rng.integers(1, 30)) * (HOUR / 2)
        a, b = new.poll(now), old.poll(now)
        assert a == b, f"poll {step} diverged at now={now}"
        # fail a random subset; both schedulers see the same failures
        for j in a:
            if rng.random() < 0.25:
                new.mark_failed(j)
                old.mark_failed(j)
    # end state agrees too
    assert new._last == old._last
    assert {k: set(v) for k, v in new._failed.items()} == \
        {k: set(v) for k, v in old._failed.items()}


# ===================================================================
# DeploymentStore: indexes, revision, listeners
# ===================================================================

def test_store_indexes_and_revision():
    deps = DeploymentStore()
    r0 = deps.revision
    a = deps.register(dep("a", package="p1", signal="S", entity="E1", rank=1))
    b = deps.register(dep("b", package="p1", signal="S", entity="E1", rank=0))
    c = deps.register(dep("c", package="p2", signal="S", entity="E2"))
    assert deps.revision == r0 + 3
    # context index: rank-sorted (Fig. 5 ranking), index bucket only
    assert deps.for_context("S", "E1") == [b, a]
    assert deps.for_context("S", "E2") == [c]
    assert deps.for_context("S", "nope") == []
    # package index: name-sorted
    assert deps.for_package("p1") == [a, b]
    assert deps.for_package("p2") == [c]
    assert deps.for_package("ghost") == []
    assert deps.all() == [a, b, c]

    deps.remove("b")
    assert deps.revision == r0 + 4
    assert deps.for_context("S", "E1") == [a]
    assert deps.for_package("p1") == [a]
    deps.remove("b")                            # idempotent, no revision bump
    assert deps.revision == r0 + 4
    deps.remove("a")
    deps.remove("c")
    # empty index buckets are deleted, not left as empty dicts
    assert deps._by_context == {} and deps._by_package == {}


def test_store_duplicate_name_raises():
    deps = DeploymentStore()
    deps.register(dep("a"))
    with pytest.raises(ValueError):
        deps.register(dep("a"))


def test_store_listener_protocol():
    events = []

    class Listener:
        def on_register(self, d):
            events.append(("reg", d.name))

        def on_remove(self, name):
            events.append(("rm", name))

    deps = DeploymentStore()
    deps.subscribe(Listener())
    deps.register(dep("a"))
    deps.register(dep("b"))
    deps.remove("a")
    deps.remove("missing")                      # no event for a no-op remove
    assert events == [("reg", "a"), ("reg", "b"), ("rm", "a")]


# ===================================================================
# SemanticGraph: interned indexes vs brute force
# ===================================================================

def _brute_find(g, kind=None, has_signal=None, under=None):
    """The old scanner semantics: filter ALL entities predicate by
    predicate, name-sorted result."""
    names = set(g.entities)
    if has_signal is not None:
        names &= {e for (s, e) in g._ts if s == has_signal}
    if kind is not None:
        names &= {n for n, e in g.entities.items() if e.kind == kind}
    if under is not None:
        names &= {e.name for e in g.descendants(under)}
    return [g.entities[n] for n in sorted(names)]


def _random_graph(seed, n_entities=60, n_signals=4):
    rng = np.random.default_rng(seed)
    g = SemanticGraph()
    sigs = [f"SIG{i}" for i in range(n_signals)]
    for s in sigs:
        g.add_signal(Signal(s))
    kinds = ["SUBSTATION", "FEEDER", "PROSUMER"]
    names = []
    for i in range(n_entities):
        name = f"E{i:03d}"
        parent = (names[int(rng.integers(len(names)))]
                  if names and rng.random() < 0.8 else None)
        g.add_entity(Entity(name, kinds[int(rng.integers(3))]), parent)
        names.append(name)
        for s in sigs:
            if rng.random() < 0.4:
                g.link_timeseries(f"ts-{s}-{name}", s, name)
    return g, sigs, kinds, names, rng


def test_graph_find_entities_matches_brute_force():
    g, sigs, kinds, names, rng = _random_graph(3)
    combos = [(None, None, None)]
    for _ in range(40):
        combos.append((
            kinds[int(rng.integers(3))] if rng.random() < 0.7 else None,
            sigs[int(rng.integers(len(sigs)))] if rng.random() < 0.7 else None,
            names[int(rng.integers(len(names)))] if rng.random() < 0.7 else None))
    for kind, sig, under in combos:
        got = g.find_entities(kind=kind, has_signal=sig, under=under)
        want = _brute_find(g, kind=kind, has_signal=sig, under=under)
        assert got == want, (kind, sig, under)


def test_graph_contexts_for_signal_matches_brute_force():
    g, sigs, _, _, _ = _random_graph(4)
    for s in sigs:
        got = g.contexts_for_signal(s)
        want_names = sorted(e for (sg, e) in g._ts if sg == s)
        assert [c.entity.name for c in got] == want_names
        assert all(c.signal.name == s for c in got)
        assert [g._ts[(s, c.entity.name)] for c in got] == \
            [c.ts_id for c in got]


def test_graph_descendants_memo_invalidation():
    g = SemanticGraph()
    for name, parent in [("root", None), ("a", "root"), ("b", "root"),
                         ("a1", "a")]:
        g.add_entity(Entity(name), parent)
    # the scanner's traversal order: all children of a node (name-sorted)
    # are appended before descending, deepest-last-child first
    assert [e.name for e in g.descendants("root")] == ["a", "b", "a1"]
    assert [e.name for e in g.descendants("a")] == ["a1"]
    # memo is now warm; a new edge deep in the tree must invalidate the
    # whole ancestor chain
    g.add_entity(Entity("a1x"), "a1")
    assert [e.name for e in g.descendants("a")] == ["a1", "a1x"]
    assert [e.name for e in g.descendants("root")] == ["a", "b", "a1", "a1x"]
    # re-parenting keeps the old edge (scanner quirk) AND invalidates
    # through BOTH parents
    g.add_entity(Entity("moved"), "b")
    assert [e.name for e in g.descendants("b")] == ["moved"]
    g.add_entity(Entity("moved"), "a")
    g.add_entity(Entity("deep"), "moved")
    assert "deep" in {e.name for e in g.descendants("a")}
    assert "deep" in {e.name for e in g.descendants("b")}   # old edge kept


def test_graph_kind_change_readd_updates_kind_index():
    g = SemanticGraph()
    g.add_entity(Entity("x", "FEEDER"))
    assert [e.name for e in g.find_entities(kind="FEEDER")] == ["x"]
    g.add_entity(Entity("x", "SUBSTATION"))     # re-add with a new kind
    assert g.find_entities(kind="FEEDER") == []
    assert [e.name for e in g.find_entities(kind="SUBSTATION")] == ["x"]


def test_graph_id_handles():
    g = SemanticGraph()
    g.add_signal(Signal("S"))
    g.add_entity(Entity("e0"))
    g.add_entity(Entity("e1"))
    assert g.entity_id("e0") != g.entity_id("e1")
    assert g.entity_id("e0") == g.entity_id("e0")       # stable
    with pytest.raises(KeyError):
        g.entity_id("ghost")
    with pytest.raises(KeyError):
        g.signal_id("ghost")
    assert isinstance(g.signal_id("S"), int)


# ===================================================================
# Interning + vectorized bin grouping
# ===================================================================

def test_intern_table_basics():
    t = InternTable()
    a = t.intern(("x", 1.0))
    b = t.intern(("y", 2.0))
    assert a != b
    assert t.intern(("x", 1.0)) == a            # idempotent
    assert t.value(a) == ("x", 1.0)
    assert t.get(("y", 2.0)) == b
    assert t.get(("never",)) is None            # get never inserts
    assert len(t) == 2
    assert ("x", 1.0) in t and ("z",) not in t


def _mk_job(i, *, pkg="pkg", task="score", at=HOUR, pk=""):
    return Job(deployment_name=f"d{i:04d}", package=pkg, version="1.0",
               task=task, scheduled_at=at, signal="S", entity=f"e{i}",
               user_params_key=pk)


def test_job_bin_id_interns_bin_key():
    j1 = _mk_job(1)
    j2 = _mk_job(2)                             # same bin, different job
    j3 = _mk_job(3, at=2 * HOUR)                # different bin
    assert j1.bin_key == j2.bin_key
    assert j1.bin_id == j2.bin_id
    assert j1.bin_id != j3.bin_id
    assert bin_key_of(j1.bin_id) == j1.bin_key
    assert j1.bin_id == j1.bin_id               # memo stable


@pytest.mark.parametrize("n", [5, 96, 500])
def test_bin_jobs_vectorized_matches_dict_reference(n):
    """The >= _VECTORIZE_MIN numpy path must be bitwise-indistinguishable
    from plain dict grouping: same keys, same first-appearance key order,
    same within-bin member order."""
    rng = np.random.default_rng(n)
    jobs = [_mk_job(i,
                    pkg=f"p{rng.integers(3)}",
                    task=("train", "score")[int(rng.integers(2))],
                    at=float(rng.integers(1, 5)) * HOUR,
                    pk=f"k{rng.integers(2)}")
            for i in range(n)]
    got = bin_jobs(jobs)
    want = {}
    for j in jobs:
        want.setdefault(j.bin_key, []).append(j)
    assert list(got.keys()) == list(want.keys())    # first-appearance order
    assert got == want                              # identical members


def test_affinity_key_interned_and_order_insensitive():
    from repro.serverless.payload import affinity_key
    j1, j2 = _mk_job(1), _mk_job(2)
    k12 = affinity_key([j1, j2])
    assert isinstance(k12, int)
    assert affinity_key([j2, j1]) == k12        # member order irrelevant
    assert affinity_key([j1, j2]) == k12        # stable across calls
    # train/score halves and catch-up stamps of one logical bin coincide
    j1t = _mk_job(1, task="train")
    j2t = _mk_job(2, task="train", at=2 * HOUR)
    assert affinity_key([j1t, j2t]) == k12
    # different deployment set or params -> different warm container
    assert affinity_key([j1]) != k12
    assert affinity_key([_mk_job(1, pk="other"), j2]) != k12
