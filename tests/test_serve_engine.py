"""Continuous-batching engine correctness: greedy generations match a
reference single-request loop; slot reuse is isolated between requests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.arch import model as M
from repro.configs import get_config
from repro.serve import Request, ServeEngine


def _reference_generate(cfg, params, prompt, n_new):
    """Single-request greedy generation via raw decode steps."""
    state = M.init_decode_state(cfg, 1, 96)
    for tok in prompt[:-1]:
        _, state = M.decode_step(cfg, params, state,
                                 {"tokens": jnp.asarray([[int(tok)]])})
    out = []
    nxt = int(prompt[-1])
    for _ in range(n_new):
        logits, state = M.decode_step(cfg, params, state,
                                      {"tokens": jnp.asarray([[nxt]])})
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
    return out


def test_engine_matches_reference_and_isolates_slots():
    cfg = get_config("qwen3-1.7b-smoke").replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]

    eng = ServeEngine(cfg, params, max_slots=2, max_seq=96)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert all(r.done for r in reqs)

    for r, p in zip(reqs, prompts):
        want = _reference_generate(cfg, params, p, 6)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_engine_throughput_accounting():
    cfg = get_config("qwen3-1.7b-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=np.asarray([1, 2, 3], np.int32),
                           max_new_tokens=4))
    total = eng.run_until_idle()
    assert total == 8 == eng.tokens_out
