"""Unified observability plane (repro/obs/): histogram bucket math and
quantile bounds (property-tested), span nesting and ring eviction,
cross-process trace stitching through a real spawned ``ProcessBackend``
worker, exporter formats, and the ``Castor.stats()`` schema-stability
contract ISSUE 10 makes ``snapshot()`` a superset of."""
import functools
import json
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Castor
from repro.forecast import LinearForecaster
from repro.obs.export import chrome_trace, prometheus_text, write_chrome_trace
from repro.obs.metrics import (_EMIN, _NBUCKETS, Histogram, MetricsRegistry,
                               bucket_bounds, bucket_index)
from repro.obs.trace import Tracer, get_tracer, set_tracer
from repro.serverless import ProcessBackend, ServerlessExecutor
from repro.testing import FLEET_NOW as NOW, build_steady_castor

#: positive range safely inside the unclamped buckets: lower edge of
#: bucket 1 is 2**_EMIN, upper edge of the second-to-last 2**(_EMIN+62)
_LO = 2.0 ** _EMIN
_HI = 2.0 ** (_EMIN + 40)


class _FakeClock:
    """Injectable monotonic clock: each ``advance`` is explicit, so span
    durations and orderings are exact, not wall-time dependent."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt=1.0):
        self.t += dt
        return self.t


@pytest.fixture
def tracer():
    """Fresh deterministic tracer installed as the process default (the
    components look the default up at call time), restored afterwards."""
    clock = _FakeClock()
    tr = Tracer(capacity=4096, clock=clock, epoch=(0.0, 0.0))
    tr.clock_fake = clock
    prev = set_tracer(tr)
    try:
        yield tr
    finally:
        set_tracer(prev)


# ------------------------------------------------------- histogram math
@settings(max_examples=50)
@given(st.floats(min_value=_LO, max_value=_HI))
def test_bucket_index_brackets_value(v):
    i = bucket_index(v)
    lo, hi = bucket_bounds(i)
    assert lo <= v < hi or v == _LO == hi  # frexp: [2**(e-1), 2**e)
    assert 0 <= i < _NBUCKETS
    assert hi == (2.0 * lo if i else 2.0 ** _EMIN)


def test_bucket_index_edges():
    assert bucket_index(0.0) == 0
    assert bucket_index(-1.0) == 0
    assert bucket_index(5e-300) == 0          # underflow clamps
    assert bucket_index(1e300) == _NBUCKETS - 1


@settings(max_examples=30)
@given(st.lists(st.floats(min_value=_LO, max_value=_HI),
                min_size=1, max_size=200),
       st.floats(min_value=0.05, max_value=0.99))
def test_quantile_within_bucket_factor_of_order_statistic(vals, q):
    """The estimate is the upper edge of the crossing bucket, clamped to
    the observed range: always in [min, max], and within a factor of 2
    above the true order statistic (log2 buckets)."""
    h = Histogram("t")
    for v in vals:
        h.observe(v)
    est = h.quantile(q)
    true = sorted(vals)[max(0, math.ceil(q * len(vals)) - 1)]
    assert min(vals) <= est <= max(vals)
    assert true <= est <= 2.0 * true


@settings(max_examples=20)
@given(st.lists(st.floats(min_value=_LO, max_value=_HI),
                min_size=1, max_size=100))
def test_quantile_monotone_in_q(vals):
    h = Histogram("t")
    for v in vals:
        h.observe(v)
    qs = [h.quantile(q) for q in (0.1, 0.5, 0.9, 0.95, 0.99, 1.0)]
    assert qs == sorted(qs)


def test_histogram_summary_and_empty():
    h = Histogram("t")
    assert h.quantile(0.5) == 0.0
    assert h.summary()["count"] == 0 and h.summary()["p99"] == 0.0
    for v in (1.0, 2.0, 4.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["sum"] == 7.0
    assert s["min"] == 1.0 and s["max"] == 4.0
    assert s["mean"] == pytest.approx(7.0 / 3.0)


def test_registry_get_or_create_and_type_collision():
    r = MetricsRegistry()
    c = r.counter("a.b")
    c.inc()
    c.inc(3)
    assert r.counter("a.b") is c and c.value == 4
    r.gauge("g").set(2.5)
    r.histogram("h").observe(1.0)
    with pytest.raises(TypeError):
        r.gauge("a.b")                 # registered as a Counter
    snap = r.snapshot()
    assert snap["a.b"] == 4 and snap["g"] == 2.5
    assert snap["h"]["count"] == 1
    assert list(snap) == sorted(snap)


# ------------------------------------------------------------- tracer
def test_span_nesting_parents_and_trace_ids(tracer):
    with tracer.span("root", k=1):
        tracer.clock_fake.advance()
        with tracer.span("child"):
            tracer.clock_fake.advance()
            with tracer.span("grandchild"):
                tracer.clock_fake.advance()
    with tracer.span("root2"):
        pass
    by_name = {s.name: s for s in tracer.spans()}
    root, child, grand = (by_name["root"], by_name["child"],
                          by_name["grandchild"])
    assert root.parent_id == 0
    assert child.parent_id == root.span_id
    assert grand.parent_id == child.span_id
    assert root.trace_id == child.trace_id == grand.trace_id
    assert by_name["root2"].trace_id != root.trace_id   # new root trace
    # children finish inside the parent interval (deterministic clock)
    assert root.t0 <= child.t0 <= grand.t0
    assert grand.t1 <= child.t1 <= root.t1
    assert root.duration == 3.0 and grand.duration == 1.0
    assert root.args == {"k": 1}


def test_span_late_args_and_disabled_noop(tracer):
    with tracer.span("s") as sp:
        sp.set(jobs=7)
    assert tracer.spans()[-1].args == {"jobs": 7}
    tracer.enabled = False
    before = tracer.finished
    with tracer.span("off") as sp:
        sp.set(ignored=True)           # the shared no-op accepts set()
    assert tracer.finished == before
    assert tracer.current() is None


def test_ring_eviction_bounds_buffer(tracer):
    small = Tracer(capacity=4, clock=tracer.clock_fake, epoch=(0.0, 0.0))
    for i in range(10):
        with small.span(f"s{i}"):
            pass
    assert len(small.spans()) == 4
    assert small.finished == 10 and small.evicted == 6
    assert [s.name for s in small.spans()] == ["s6", "s7", "s8", "s9"]
    st_ = small.stats()
    assert st_["buffered"] == 4 and st_["evicted"] == 6


def test_export_since_and_absorb_remap(tracer):
    """The stitching primitives, single-process: a 'worker' tracer adopts
    the invoker's context, its shipped spans re-id onto the invoker's
    counter with internal parentage remapped, the remote parent link
    preserved, and timestamps rebased to ``t_base``."""
    worker = Tracer(capacity=64, clock=tracer.clock_fake, epoch=(0.0, 0.0))
    invoke_id = tracer.allocate_id()
    trace_id = tracer.new_trace_id()
    mark = worker.mark()
    with worker.adopt({"trace_id": trace_id, "parent_id": invoke_id}):
        with worker.span("worker.execute"):
            tracer.clock_fake.advance()
            with worker.span("exec.bin"):
                tracer.clock_fake.advance()
    shipped = worker.export_since(mark)
    assert [d["name"] for d in shipped] == ["exec.bin", "worker.execute"]
    assert all(d["trace_id"] == trace_id for d in shipped)
    n = tracer.absorb(shipped, t_base=100.0)
    assert n == 2
    by_name = {s.name: s for s in tracer.spans()}
    we, eb = by_name["worker.execute"], by_name["exec.bin"]
    assert we.parent_id == invoke_id          # remote parent preserved
    assert eb.parent_id == we.span_id         # internal link remapped
    assert we.span_id != shipped[1]["span_id"]  # re-id'd locally
    assert we.trace_id == eb.trace_id == trace_id
    assert min(we.t0, eb.t0) == 100.0         # rebased onto t_base


def test_record_with_preallocated_id(tracer):
    sid = tracer.allocate_id()
    tid = tracer.new_trace_id()
    got = tracer.record("serverless.invoke", 1.0, 2.0, span_id=sid,
                        trace_id=tid, args={"ok": True})
    (sp,) = tracer.spans()
    assert got == sid and sp.span_id == sid and sp.trace_id == tid
    assert sp.duration == 1.0 and sp.args == {"ok": True}


# ----------------------------------------------------------- exporters
def test_chrome_trace_export(tracer, tmp_path):
    with tracer.span("castor.tick", now=1.0):
        tracer.clock_fake.advance(0.5)
        with tracer.span("scheduler.poll"):
            tracer.clock_fake.advance(0.25)
    doc = chrome_trace(tracer)
    evs = doc["traceEvents"]
    assert len(evs) == 2 and all(e["ph"] == "X" for e in evs)
    tick = next(e for e in evs if e["name"] == "castor.tick")
    assert tick["cat"] == "castor"
    assert tick["dur"] == pytest.approx(0.75e6)      # µs
    assert tick["args"]["now"] == 1.0
    assert "span_id" in tick["args"] and "parent_id" in tick["args"]
    path = tmp_path / "t.perfetto-trace.json"
    write_chrome_trace(path, tracer)
    assert json.loads(path.read_text())["traceEvents"]


def test_prometheus_text_exposition():
    r = MetricsRegistry()
    r.counter("serverless.invocations").inc(3)
    r.gauge("store.points").set(12.0)
    h = r.histogram("exec.bin_seconds")
    h.observe(0.5)
    h.observe(1.5)
    text = prometheus_text(r)
    assert "repro_serverless_invocations 3" in text
    assert "repro_store_points 12.0" in text
    assert 'repro_exec_bin_seconds_bucket{le="+Inf"} 2' in text
    assert "repro_exec_bin_seconds_count 2" in text
    assert "repro_exec_bin_seconds_sum 2.0" in text
    # cumulative: every bucket count is non-decreasing
    counts = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
              if line.startswith("repro_exec_bin_seconds_bucket")]
    assert counts == sorted(counts)


# ------------------------------------------ stitched cross-process trace
def test_process_backend_produces_one_stitched_trace(tracer):
    """ISSUE 10 acceptance: a serverless run through a REAL spawned
    ``ProcessBackend`` worker yields ONE trace in the invoker's tracer —
    worker spans parent under the pre-allocated invoke-span ids, and the
    span counts agree with ``InvocationMonitor``'s invocation counts."""
    tracer.clock = __import__("time").perf_counter   # real latencies
    factory = functools.partial(build_steady_castor, "lr",
                                LinearForecaster, {}, n=2)
    c = factory()
    ex = ServerlessExecutor(c, backend=ProcessBackend(factory, n_workers=1),
                            speculative=False)
    c._serverless_ex = ex
    try:
        res = c.tick(NOW, executor="serverless")
        assert res and all(r.ok for r in res)
    finally:
        ex.close()
    spans = tracer.spans()
    ticks = [s for s in spans if s.name == "castor.tick"]
    invokes = [s for s in spans if s.name == "serverless.invoke"]
    workers = [s for s in spans if s.name == "worker.execute"]
    assert len(ticks) == 1
    # ONE stitched trace: every span shares the tick's trace id
    assert {s.trace_id for s in spans} == {ticks[0].trace_id}
    # span counts == monitor counts (the 1:1 record/span contract)
    assert len(invokes) == len(ex.monitor.records) >= 2  # train + score
    assert len(workers) == sum(1 for r in ex.monitor.records if r["ok"])
    # stitched parentage: each worker span under exactly one invoke span
    invoke_ids = {s.span_id for s in invokes}
    assert all(w.parent_id in invoke_ids for w in workers)
    # invoke spans hang off the serverless.phase spans under the tick
    phases = {s.span_id for s in spans if s.name == "serverless.phase"}
    assert all(s.parent_id in phases for s in invokes)
    # worker-side children (exec phases) parent under worker.execute
    worker_ids = {w.span_id for w in workers}
    inner = [s for s in spans if s.name.startswith("exec.phase.")
             and s.parent_id in worker_ids]
    assert inner, "worker executor spans did not ship back"


def test_invoke_spans_match_monitor_with_retries(tracer):
    """Failed copies get spans too: one 'serverless.invoke' span per
    monitor record even when deliveries fail and retry."""
    import threading

    from repro.serverless import InlineBackend
    from repro.serverless.backend import InvocationError

    class _Flaky(InlineBackend):
        def __init__(self, system):
            super().__init__(system, n_workers=2)
            self.seen = {}
            self._l = threading.Lock()

        def invoke(self, payload, worker_id):
            with self._l:
                n = self.seen.get(payload.invocation_id, 0)
                self.seen[payload.invocation_id] = n + 1
            if n < 1:
                raise InvocationError("transient")
            return super().invoke(payload, worker_id)

    tracer.clock = __import__("time").perf_counter
    c = build_steady_castor("lr", LinearForecaster, {}, n=3)
    ex = ServerlessExecutor(c, backend=_Flaky(c), max_retries=2,
                            backoff_base_s=0.01, speculative=False)
    res = ex.run(c.scheduler.poll(NOW))
    assert res and all(r.ok for r in res)
    invokes = [s for s in tracer.spans() if s.name == "serverless.invoke"]
    assert len(invokes) == len(ex.monitor.records)
    failed = [s for s in invokes if not s.args["ok"]]
    assert len(failed) == sum(1 for r in ex.monitor.records if not r["ok"])
    assert all(s.args.get("error") for s in failed)


# ------------------------------------------------- monitor ring bound
def test_invocation_monitor_ring_is_bounded():
    from repro.serverless.monitor import InvocationMonitor
    from repro.serverless.payload import InvocationPayload, InvocationResult

    mon = InvocationMonitor(max_records=8)
    for i in range(20):
        p = InvocationPayload(invocation_id=f"i{i}", jobs=(),
                              created_at=0.0)
        r = InvocationResult(invocation_id=f"i{i}", worker_id="w0",
                             cold_start=(i == 0), started_at=float(i),
                             finished_at=float(i) + 0.5, outcomes=())
        mon.record(payload=p, result=r, worker_id="w0")
    assert len(mon.records) == 8                   # ring, not a list
    assert mon.dropped == 12
    assert mon.invocations == 20                   # totals keep counting
    assert [r["queue_s"] for r in mon.records] == [float(i)
                                                   for i in range(12, 20)]
    # p95 over the tail window still works on the deque
    assert mon.recent_queue_p95(window=4) >= 18.0
    s = mon.summary()
    assert s["invocations"] == 20 and s["records_dropped"] == 12


# ------------------------------------------------ rolling error gauges
def test_detection_rolling_error_gauges():
    from repro.flows.detection import DetectionRecord, DetectionStore
    from repro.obs.metrics import get_metrics

    ds = DetectionStore(rolling_window=4)
    for i, score in enumerate([0.0, 1.0, 2.0, 3.0, 4.0, 5.0]):
        ds.save(DetectionRecord(
            deployment_name="det-a", signal="S", entity="E",
            scheduled_at=float(i), score=score, n_readings=1,
            n_anomalies=0, band_misses=0, model_version=1,
            derived_signal="S.anomaly"))
    # window 4 over [2,3,4,5] -> mean 3.5; duplicates must not move it
    ds.save(DetectionRecord(
        deployment_name="det-a", signal="S", entity="E",
        scheduled_at=5.0, score=99.0, n_readings=1, n_anomalies=0,
        band_misses=0, model_version=1, derived_signal="S.anomaly"))
    assert ds.rolling_errors() == {"det-a": pytest.approx(3.5)}
    g = get_metrics().gauge("detection.rolling_error.det-a")
    assert g.value == pytest.approx(3.5)


# ------------------------------------------------- schema stability
def test_castor_stats_schema_is_stable():
    """``stats()`` is the backward-compatible view ``snapshot()`` wraps:
    the pre-ISSUE-10 key set must survive verbatim."""
    c = build_steady_castor("lr", LinearForecaster, {}, n=2)
    res = c.tick(NOW)
    assert res and all(r.ok for r in res)
    s = c.stats()
    for key in ("points", "segments", "store_reads", "store_read_many",
                "deployments", "deployments_by_flow",
                "deployment_revision", "model_versions", "forecasts",
                "detection", "scheduler"):
        assert key in s, key
    for key in ("records", "scored_readings", "anomalies_flagged",
                "band_misses", "band_miss_rate"):
        assert key in s["detection"], key
    snap = c.snapshot()
    assert snap["stats"] == c.stats()
    assert snap["trace"]["capacity"] > 0
    assert any(k.startswith("store.") for k in snap["metrics"])
    assert any(k.startswith("scheduler.") for k in snap["metrics"])


def test_castor_dump_trace_writes_chrome_json(tmp_path):
    tr = Tracer(capacity=1024)
    prev = set_tracer(tr)
    try:
        c = build_steady_castor("lr", LinearForecaster, {}, n=2)
        res = c.tick(NOW)
        assert res and all(r.ok for r in res)
        path = c.dump_trace(tmp_path / "tick.perfetto-trace.json")
        doc = json.loads(open(path).read())
        names = {e["name"] for e in doc["traceEvents"]}
        assert {"castor.tick", "scheduler.poll"} <= names
        assert any(n.startswith("exec.") for n in names)
    finally:
        set_tracer(prev)


def test_retrace_counters_named_per_program():
    """Satellite 2: the shared helper breaks the legacy retrace total
    down per jitted program family without changing its deltas."""
    from repro.forecast.features import note_trace, trace_count
    from repro.obs.metrics import get_metrics, retrace_counts

    before_total = trace_count()
    before = retrace_counts().get("test_prog", 0)
    note_trace("test_prog")
    note_trace("test_prog")
    assert trace_count() - before_total == 2       # legacy delta intact
    assert retrace_counts()["test_prog"] - before == 2
    assert get_metrics().counter("jit.retrace.test_prog").value >= 2
