"""The loop-aware HLO cost model is the roofline measurement instrument —
validate it against XLA's own cost_analysis where XLA is correct (no loops)
and against analytical counts where XLA is wrong (scan bodies)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_matches_xla_on_straightline():
    def f(a, b):
        return jnp.tanh(a @ b)

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = _compile(f, a, b)
    got = hlo_cost.analyze(c.as_text(), 1)
    xla = hlo_cost.xla_cost_properties(c)
    # dot flops dominate; ours adds elementwise tanh
    assert abs(got.flops - xla["flops"]) / xla["flops"] < 0.05
    assert abs(got.bytes - xla["bytes accessed"]) / xla["bytes accessed"] < 0.2


def test_scan_multiplied_by_trip_count():
    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    T = 12
    ws = jax.ShapeDtypeStruct((T, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    c = _compile(f, ws, x)
    got = hlo_cost.analyze(c.as_text(), 1)
    dot_flops = 2 * 8 * 64 * 64
    assert got.flops == pytest.approx(T * dot_flops, rel=0.05)
    # XLA undercounts by the trip count (the motivating bug)
    assert hlo_cost.xla_cost_properties(c)["flops"] == \
        pytest.approx(dot_flops, rel=0.05)


def test_nested_scan():
    def f(ws, x):
        def outer(h, w):
            def inner(g, _):
                return jnp.tanh(g @ w), None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        h, _ = jax.lax.scan(outer, x, ws)
        return h

    ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    c = _compile(f, ws, x)
    got = hlo_cost.analyze(c.as_text(), 1)
    assert got.flops == pytest.approx(4 * 3 * 2 * 8 * 32 * 32, rel=0.1)


def test_collectives_counted_with_group_size():
    import os
    import re
    # parse a hand-written HLO snippet (device-count independent)
    hlo = """
HloModule test
ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  ROOT %ar = f32[64,64]{1,0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
}
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
"""
    got = hlo_cost.analyze(hlo, 8)
    bytes_full = 64 * 64 * 4
    want = 2 * bytes_full * (4 - 1) / 4          # ring, group size 4
    assert got.collective_wire_bytes == pytest.approx(want)
    assert got.collective_counts["all-reduce"] == 1


def test_shape_parser_tuples_and_layouts():
    s, pos = hlo_cost._parse_shape("(f32[2,3]{1,0}, (bf16[4], pred[]))")
    assert s.bytes == 2 * 3 * 4 + 4 * 2 + 1
