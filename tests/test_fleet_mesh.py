"""Mesh-sharded fleet execution: sharded == unsharded equivalence, padding
of uneven bins, telemetry, and the opt-out.

Two layers of coverage:
  * in-process tests run whenever the suite sees >1 jax device (the CI
    matrix entry sets XLA_FLAGS=--xla_force_host_platform_device_count=8);
    on a single device they skip and the always-on subprocess smoke below
    still exercises the sharded path.
  * single-device behaviors (auto-select declines, opt-out) always run.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.executor import FleetExecutor
from repro.forecast import (ANNForecaster, GAMForecaster, LSTMForecaster,
                            LinearForecaster)
from repro.testing import (FLEET_ATOL, FLEET_NOW as NOW, FLEET_RTOL,
                           build_fleet_castor, subprocess_env)

MODELS = {
    "lr": (LinearForecaster, {}),
    "gam": (GAMForecaster, {}),
    "ann": (ANNForecaster, {"hidden": 8, "epochs": 20}),
    "lstm": (LSTMForecaster, {"hidden": 8, "epochs": 20}),
}

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=N)")


def _fleet_castor(kind, mesh_opt, n=6):
    cls, hp = MODELS[kind]
    return build_fleet_castor(kind, cls, hp, mesh_opt, n=n)


@multi_device
@pytest.mark.parametrize("kind", list(MODELS))
def test_sharded_equals_unsharded_fleet(kind):
    """The mesh-sharded fleet path persists the same model versions and
    forecasts as the single-device vmap (tolerance-pinned: float32 batched
    solves/matmuls reassociate across shard boundaries)."""
    ca, fa = _fleet_castor(kind, "auto")
    cb, fb = _fleet_castor(kind, "off")
    mdev = min(jax.device_count(), 6)           # mesh sized to the bin
    for b in fa.last_bin_stats:
        assert b["sharded"] and b["mesh_devices"] == mdev
        assert b["pad"] == (-6) % mdev          # uneven bins padded+masked
        assert b["dispatches"] == 1             # still ONE dispatch per bin
    assert all(not b["sharded"] and b["mesh_devices"] == 1
               for b in fb.last_bin_stats)
    for i in range(6):
        name = f"s-Z_PRO_0_{i}"
        pa = ca.versions.get(name).params["params"]
        pb = cb.versions.get(name).params["params"]
        assert pa.keys() == pb.keys()
        for k in pa:
            np.testing.assert_allclose(pa[k], pb[k], rtol=5e-2, atol=5e-3,
                                       err_msg=f"{kind} params[{k}]")
        fca = ca.predictions.history(name)
        fcb = cb.predictions.history(name)
        assert len(fca) == len(fcb) == 1
        np.testing.assert_allclose(fca[0].times, fcb[0].times)
        np.testing.assert_allclose(fca[0].values, fcb[0].values,
                                   rtol=FLEET_RTOL, atol=FLEET_ATOL,
                                   err_msg=kind)


@multi_device
def test_fleet_sharded_helper_pads_and_replicates():
    """Unit contract of distributed.sharding.fleet_sharded: uneven leading
    axes are padded to a shard multiple and sliced back; replicated args
    broadcast; results equal the unsharded function."""
    from repro.distributed.sharding import fleet_sharded
    from repro.launch.mesh import make_fleet_mesh
    mesh = make_fleet_mesh()
    assert mesh is not None

    def fn(x, scale):                     # x sharded (N, F), scale replicated
        return {"out": x * scale, "sum": x.sum(axis=-1)}

    ndev = jax.device_count()
    n = ndev + 1                          # deliberately uneven
    x = np.arange(n * 3, dtype=np.float32).reshape(n, 3)
    scale = np.asarray(2.0, np.float32)
    got = fleet_sharded(fn, mesh, replicated_argnums=(1,))(x, scale)
    np.testing.assert_array_equal(np.asarray(got["out"]), x * 2.0)
    np.testing.assert_array_equal(np.asarray(got["sum"]), x.sum(-1))


def test_single_device_auto_declines_mesh():
    """mesh='auto' on one device (or an opted-out deployment) runs the
    plain vmap path and says so in telemetry."""
    if jax.device_count() > 1:
        pytest.skip("needs exactly 1 device")
    _, fx = _fleet_castor("lr", "auto", n=3)
    assert all(not b["sharded"] and b["mesh_devices"] == 1 and b["pad"] == 0
               for b in fx.last_bin_stats)


def test_mesh_off_opt_out_via_user_params():
    _, fx = _fleet_castor("lr", "off", n=3)
    assert all(not b["sharded"] for b in fx.last_bin_stats)


def test_executor_level_mesh_off():
    c, _ = _fleet_castor("lr", "auto", n=3)
    fx = FleetExecutor(c, mesh="off")
    res = fx.run(c.scheduler.poll(NOW + 1e12))
    assert res and all(r.ok for r in res)
    assert all(not b["sharded"] for b in fx.last_bin_stats)


_SMOKE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.forecast import ANNForecaster, LinearForecaster
    from repro.testing import FLEET_ATOL, FLEET_RTOL, build_fleet_castor

    assert jax.device_count() == 8
    out = {}
    for kind, cls, hp in [("lr", LinearForecaster, {}),
                          ("ann", ANNForecaster, {"hidden": 8, "epochs": 20})]:
        ca, fa = build_fleet_castor(kind, cls, hp, "auto")
        cb, fb = build_fleet_castor(kind, cls, hp, "off")
        # mesh sized to the 6-job bin (not all 8 devices), so pad == 0
        assert all(b["sharded"] and b["mesh_devices"] == 6 and b["pad"] == 0
                   for b in fa.last_bin_stats), fa.last_bin_stats
        assert all(not b["sharded"] for b in fb.last_bin_stats)
        dev = 0.0
        for i in range(6):
            name = f"s-Z_PRO_0_{i}"
            va = ca.predictions.history(name)[0].values
            vb = cb.predictions.history(name)[0].values
            assert np.allclose(va, vb, rtol=FLEET_RTOL, atol=FLEET_ATOL), \\
                (kind, name)
            dev = max(dev, float(np.max(np.abs(va - vb))))
        out[kind] = dev
    print(json.dumps(out))
""")


def test_sharded_fleet_subprocess_smoke():
    """Always-on sharded coverage: even a single-device test host verifies
    the 8-device mesh path in a subprocess (the device-count override must
    precede jax init)."""
    proc = subprocess.run(
        [sys.executable, "-c", _SMOKE], capture_output=True, text=True,
        timeout=520,
        env=subprocess_env(Path(__file__).parent.parent / "src"),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    devs = json.loads(proc.stdout.strip().splitlines()[-1])
    assert set(devs) == {"lr", "ann"}
    assert all(d < 1e-3 for d in devs.values()), devs
