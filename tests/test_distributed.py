"""Distributed runtime: checkpoint/restore (incl. elastic resharding),
supervisor failure handling, gradient compression properties, mesh logic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.distributed import (CheckpointManager, NodeFailure, TrainSupervisor,
                               compress_with_feedback, dequantize_int8,
                               init_error_state, largest_mesh_shape,
                               quantize_int8)
from repro.distributed.checkpoint import latest_step, restore, save


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)},
            "s": jnp.asarray(3)}
    save(tmp_path / "ck", tree, step=7, extra={"note": "x"})
    got, man = restore(tmp_path / "ck", tree)
    assert man["step"] == 7 and man["extra"]["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_retention(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    t = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        cm.save_sync(t, step=s)
    kept = sorted(d.name for d in tmp_path.iterdir())
    assert kept == ["step-3", "step-4"]
    assert latest_step(tmp_path) == 4


def test_checkpoint_async_double_buffer(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3)
    for s in range(3):
        cm.save_async({"w": jnp.full(4, float(s))}, step=s)
    cm.wait()
    got, man = cm.restore_latest({"w": jnp.zeros(4)})
    assert man["step"] == 2 and float(got["w"][0]) == 2.0


def test_checkpoint_leaf_mismatch_raises(tmp_path):
    save(tmp_path / "ck", {"a": jnp.zeros(2)}, step=1)
    with pytest.raises(AssertionError):
        restore(tmp_path / "ck", {"a": jnp.zeros(2), "b": jnp.zeros(2)})


def test_supervisor_restore_and_preempt(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3)
    calls = {"n": 0}

    def step_fn(s, b):
        calls["n"] += 1
        if calls["n"] == 5:
            raise NodeFailure("boom")
        return {"w": s["w"] + 1}

    def batches():
        while True:
            yield None

    sup = TrainSupervisor(cm, checkpoint_every=2, max_restores=3)
    state, rep = sup.run({"w": jnp.zeros(())}, batches(), step_fn,
                         num_steps=10)
    assert rep.failures_handled == 1 and rep.restores == 1
    assert rep.final_step == 10 and float(state["w"]) == 10

    # preemption: checkpoint-and-exit
    sup2 = TrainSupervisor(CheckpointManager(tmp_path / "p", keep=1),
                           checkpoint_every=100)
    sup2.request_preemption()
    state2, rep2 = sup2.run({"w": jnp.zeros(())}, batches(),
                            lambda s, b: {"w": s["w"] + 1}, num_steps=10)
    assert rep2.preempted and rep2.steps_run == 0
    assert latest_step(tmp_path / "p") == 0


# ---------------- compression ----------------
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=200))
@settings(max_examples=50, deadline=None)
def test_quantize_error_bounded_by_half_scale(xs):
    x = jnp.asarray(xs, jnp.float32)
    codes, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(codes, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=512),
                          jnp.float32)}
    e = init_error_state(g)
    acc = jnp.zeros(512)
    for _ in range(64):
        cg, e = compress_with_feedback(g, e)
        acc = acc + cg["w"]
    np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g["w"]),
                               atol=2e-3)


# ---------------- elastic mesh ----------------
@given(n=st.integers(1, 4096))
@settings(max_examples=200, deadline=None)
def test_largest_mesh_shape_valid(n):
    d, m = largest_mesh_shape(n, model_axis=16)
    assert d * m <= n
    assert d >= 1 and m >= 1
    assert (d & (d - 1)) == 0                        # power of two
    if n >= 16:
        assert m == 16                               # TP degree preserved


def test_mesh_shrink_sequence():
    assert largest_mesh_shape(256) == (16, 16)
    assert largest_mesh_shape(255) == (8, 16)        # lose a node -> shrink DP
    assert largest_mesh_shape(8) == (1, 8)           # tiny: shrink TP too
