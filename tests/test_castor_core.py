"""Unit + property tests for the paper's core: registry, scheduler, semantic
graph, deployments, lineage."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Castor, ModelDeployment, Schedule
from repro.core.registry import ModelInterface, ModelRegistry
from repro.core.scheduler import Job, ModelScheduler, bin_jobs
from repro.core.semantics import Entity, SemanticGraph, Signal
from repro.core.lineage import Forecast, ModelVersionStore, PredictionStore


class _Dummy(ModelInterface):
    def load(self): pass
    def transform(self): pass
    def train(self): return {"w": 1}
    def score(self, m): return np.arange(3.0), np.ones(3)


# ---------------- registry ----------------
def test_registry_versions_and_immutability():
    r = ModelRegistry()
    r.register("pkg", "1.0", _Dummy)
    r.register("pkg", "1.10", _Dummy)
    r.register("pkg", "1.2", _Dummy)
    assert r.resolve_version("pkg") == "1.10"       # numeric, not lexical
    with pytest.raises(ValueError):
        r.register("pkg", "1.0", _Dummy)            # immutable artifacts
    with pytest.raises(KeyError):
        r.get("nope")


# ---------------- scheduler ----------------
@given(start=st.floats(0, 1e6), every=st.floats(1.0, 1e5),
       off1=st.floats(0, 1e6), d1=st.floats(0.0, 1e6), d2=st.floats(0.0, 1e6))
@settings(max_examples=200, deadline=None)
def test_schedule_occurrences_additive_after_first(start, every, off1, d1, d2):
    """After the first firing (catch-up collapses history by design),
    occurrences are additive over consecutive windows and non-negative."""
    s = Schedule(start=start, every=every)
    t0 = start + off1
    t1, t2 = t0 + d1, t0 + d1 + d2
    a = s.occurrences_due(t0, t1)
    b = s.occurrences_due(t1, t2)
    c = s.occurrences_due(t0, t2)
    assert a >= 0 and b >= 0
    assert a + b == c


def test_schedule_first_poll_fires_once_not_replay():
    s = Schedule(start=0.0, every=10.0)
    assert s.occurrences_due(None, 1000.0) == 1
    assert s.occurrences_due(None, -1.0) == 0


def test_scheduler_emits_and_requeues_on_failure():
    c = Castor()
    c.publish("pkg", "1.0", _Dummy)
    c.add_signal("S")
    c.add_entity("E")
    c.deploy(ModelDeployment(name="d1", package="pkg", signal="S", entity="E",
                             train=Schedule(0.0, 100.0),
                             score=Schedule(0.0, 10.0)))
    jobs = c.scheduler.poll(0.0)
    assert {(j.task) for j in jobs} == {"train", "score"}
    assert c.scheduler.poll(5.0) == []              # nothing due yet
    jobs2 = c.scheduler.poll(10.0)
    assert [j.task for j in jobs2] == ["score"]
    # failure -> re-fires on next poll
    c.scheduler.mark_failed(jobs2[0])
    jobs3 = c.scheduler.poll(11.0)
    assert [j.task for j in jobs3] == ["score"]


def _score_only_castor(every=10.0):
    c = Castor()
    c.publish("pkg", "1.0", _Dummy)
    c.add_signal("S")
    c.add_entity("E")
    c.deploy(ModelDeployment(name="d1", package="pkg", signal="S", entity="E",
                             train=None, score=Schedule(0.0, every)))
    return c


def test_scheduler_catchup_emits_one_job_per_missed_occurrence():
    """K missed occurrences yield K jobs stamped at their scheduled
    boundaries (start + k*every) — NOT one job stamped at poll time.
    Regression: catch-up used to collapse to a single job whose lineage
    timestamp drifted to whenever the poll happened to run."""
    c = _score_only_castor(every=10.0)
    assert [j.scheduled_at for j in c.scheduler.poll(0.0)] == [0.0]
    jobs = c.scheduler.poll(35.0)            # occurrences 10, 20, 30 missed
    assert [j.scheduled_at for j in jobs] == [10.0, 20.0, 30.0]
    assert all(j.task == "score" for j in jobs)
    # occurrences already emitted never re-fire
    assert c.scheduler.poll(39.0) == []
    assert [j.scheduled_at for j in c.scheduler.poll(41.0)] == [40.0]


def test_scheduler_first_fire_stamped_at_boundary_not_poll_time():
    """The first firing collapses history by design (one catch-up job),
    but even that job is stamped at its occurrence boundary."""
    c = _score_only_castor(every=10.0)
    jobs = c.scheduler.poll(1003.0)
    assert [j.scheduled_at for j in jobs] == [1000.0]


def test_scheduler_mark_failed_refires_at_boundary():
    """A failed job re-fires on the next poll (at-least-once) stamped at
    its ORIGINAL occurrence boundary."""
    c = _score_only_castor(every=10.0)
    c.scheduler.poll(0.0)
    (job,) = c.scheduler.poll(10.0)
    assert job.scheduled_at == 10.0
    c.scheduler.mark_failed(job)
    refire = c.scheduler.poll(13.0)
    assert [j.scheduled_at for j in refire] == [10.0]
    # and the re-fired occurrence, once polled, does not fire again
    assert c.scheduler.poll(14.0) == []


def test_mark_failed_occurrence_not_lost_among_catchup_siblings():
    """When one catch-up occurrence fails while its siblings succeed, the
    FAILED boundary re-fires — it must not be collapsed into the latest
    boundary (whose forecast already persisted, so the idempotent stores
    would silently no-op the retry and leave a permanent lineage hole)."""
    c = _score_only_castor(every=10.0)
    c.scheduler.poll(0.0)
    jobs = c.scheduler.poll(35.0)
    assert [j.scheduled_at for j in jobs] == [10.0, 20.0, 30.0]
    c.scheduler.mark_failed(jobs[0])         # @10 failed; @20/@30 succeeded
    refire = c.scheduler.poll(36.0)
    assert [j.scheduled_at for j in refire] == [10.0]
    assert c.scheduler.poll(37.0) == []
    # a failed stamp combines with newly due occurrences in one poll
    c.scheduler.mark_failed(refire[0])
    combined = c.scheduler.poll(41.0)
    assert [j.scheduled_at for j in combined] == [10.0, 40.0]


def test_scheduler_catchup_is_capped():
    """An in-process stall must not replay an unbounded backlog: one poll
    emits at most max_catchup occurrences per (deployment, task), keeping
    the most recent boundaries."""
    c = _score_only_castor(every=10.0)
    c.scheduler.max_catchup = 5
    c.scheduler.poll(0.0)
    jobs = c.scheduler.poll(1000.0)          # 100 occurrences missed
    assert [j.scheduled_at for j in jobs] == \
        [960.0, 970.0, 980.0, 990.0, 1000.0]
    assert c.scheduler.poll(1001.0) == []    # dropped ones stay dropped


def test_failed_retry_backlog_shares_the_catchup_cap():
    """A permanently failing deployment re-queues every occurrence; the
    retry backlog must stay bounded by max_catchup (most recent win)
    instead of growing by one replayed megabatch per poll forever."""
    c = _score_only_castor(every=10.0)
    c.scheduler.max_catchup = 3
    for j in c.scheduler.poll(0.0):
        c.scheduler.mark_failed(j)
    jobs = c.scheduler.poll(35.0)            # retry @0 + new @10/@20/@30
    assert [j.scheduled_at for j in jobs] == [10.0, 20.0, 30.0]  # capped
    for j in jobs:
        c.scheduler.mark_failed(j)
    jobs = c.scheduler.poll(45.0)            # retries + new @40, capped
    assert [j.scheduled_at for j in jobs] == [20.0, 30.0, 40.0]
    for j in jobs:
        c.scheduler.mark_failed(j)
    # steady state: the backlog never exceeds the cap
    assert [j.scheduled_at for j in c.scheduler.poll(46.0)] == \
        [20.0, 30.0, 40.0]


def test_catchup_jobs_bin_separately_per_occurrence():
    """scheduled_at is part of the bin key: a fleet score bin shares one
    execution time axis, so catch-up occurrences must not share a bin."""
    c = _score_only_castor(every=10.0)
    c.scheduler.poll(0.0)
    bins = bin_jobs(c.scheduler.poll(35.0))
    assert len(bins) == 3
    assert sorted(k[-1] for k in bins) == [10.0, 20.0, 30.0]


def test_poll_with_unresolvable_package_loses_no_occurrences():
    """A raising registry lookup (deployment of a never-published package)
    must not advance ANY deployment's watermark or drop queued retries —
    the poll is atomic, so occurrences already processed for healthy
    deployments are not emitted into a poll that then throws them away."""
    c = Castor()
    c.publish("pkg", "1.0", _Dummy)
    c.add_signal("S")
    c.add_entity("E")
    # 'a' sorts before 'z': the healthy deployment is processed FIRST
    c.deploy(ModelDeployment(name="a", package="pkg", signal="S",
                             entity="E", train=None,
                             score=Schedule(0.0, 10.0)))
    c.deploy(ModelDeployment(name="z", package="ghost", signal="S",
                             entity="E", train=None,
                             score=Schedule(0.0, 10.0)))
    with pytest.raises(KeyError):
        c.scheduler.poll(5.0)
    c.publish("ghost", "1.0", _Dummy)
    jobs = c.scheduler.poll(6.0)
    assert sorted((j.deployment_name, j.scheduled_at) for j in jobs) == \
        [("a", 0.0), ("z", 0.0)]


def test_job_binning_key():
    j1 = Job("a", "p", "1.0", "score", 0.0, "S", "E1", "k")
    j2 = Job("b", "p", "1.0", "score", 0.0, "S", "E2", "k")
    j3 = Job("c", "p", "1.0", "train", 0.0, "S", "E1", "k")
    bins = bin_jobs([j1, j2, j3])
    assert len(bins) == 2
    assert len(bins[j1.bin_key]) == 2


# ---------------- semantics ----------------
def test_semantic_graph_queries():
    g = SemanticGraph()
    g.add_signal(Signal("LOAD"))
    g.add_entity(Entity("SUB", "SUBSTATION"))
    g.add_entity(Entity("FD", "FEEDER"), parent="SUB")
    g.add_entity(Entity("P1", "PROSUMER"), parent="FD")
    g.add_entity(Entity("P2", "PROSUMER"), parent="FD")
    g.link_timeseries("ts1", "LOAD", "P1")
    assert [e.name for e in g.find_entities(kind="PROSUMER")] == ["P1", "P2"]
    assert [e.name for e in g.find_entities(has_signal="LOAD")] == ["P1"]
    assert [e.name for e in g.find_entities(kind="PROSUMER", under="SUB")] \
        == ["P1", "P2"]
    assert g.parent("P1").name == "FD"
    assert {e.name for e in g.descendants("SUB")} == {"FD", "P1", "P2"}


def test_find_entities_and_descendants_are_deterministic():
    """The fleet-deployment queries must return identical, sorted results
    regardless of entity/edge insertion order — `deploy_for_all` derives
    deployment NAMES from them, and a nondeterministic order would make
    'the same rule' deploy different fleets on different runs."""
    def build(order):
        g = SemanticGraph()
        g.add_signal(Signal("LOAD"))
        g.add_entity(Entity("SUB", "SUBSTATION"))
        g.add_entity(Entity("FD1", "FEEDER"), parent="SUB")
        g.add_entity(Entity("FD2", "FEEDER"), parent="SUB")
        for name in order:
            g.add_entity(Entity(name, "PROSUMER"),
                         parent="FD1" if name < "P3" else "FD2")
        for name in reversed(order):
            g.link_timeseries(f"ts-{name}", "LOAD", name)
        return g

    names = ["P1", "P2", "P3", "P4", "P5"]
    a = build(names)
    b = build(list(reversed(names)))
    for g in (a, b):
        assert [e.name for e in g.find_entities(kind="PROSUMER")] == names
        assert [e.name for e in g.find_entities(kind="PROSUMER",
                                                under="SUB")] == names
        assert [e.name for e in g.find_entities(has_signal="LOAD",
                                                under="FD1")] == ["P1", "P2"]
    assert [e.name for e in a.descendants("SUB")] \
        == [e.name for e in b.descendants("SUB")]
    # repeated calls are stable too
    assert [e.name for e in a.descendants("SUB")] \
        == [e.name for e in a.descendants("SUB")]


def test_programmatic_fleet_deployment():
    c = Castor()
    c.publish("pkg", "1.0", _Dummy)
    c.add_signal("LOAD")
    c.add_entity("SUB", "SUBSTATION")
    for i in range(5):
        c.add_entity(f"P{i}", "PROSUMER", parent="SUB")
        if i < 3:                                   # only 3 have data
            c.link(f"ts{i}", "LOAD", f"P{i}")
    deps = c.deploy_for_all(package="pkg", signal="LOAD", name_prefix="m",
                            kind="PROSUMER", score=Schedule(0.0, 60.0))
    assert len(deps) == 3                           # semantic rule respected
    assert all(d.name.startswith("m-P") for d in deps)


def test_deploy_for_all_is_incremental_and_idempotent():
    """Re-applying the SAME rule after the application grew deploys only
    the new contexts and returns just those; a no-change re-run returns
    [] and rewrites nothing (paper §3.2: automated replication as the IoT
    application grows)."""
    c = Castor()
    c.publish("pkg", "1.0", _Dummy)
    c.add_signal("LOAD")
    for i in range(3):
        c.add_entity(f"P{i}", "PROSUMER")
        c.link(f"ts{i}", "LOAD", f"P{i}")
    rule = dict(package="pkg", signal="LOAD", name_prefix="m",
                kind="PROSUMER", score=Schedule(0.0, 60.0))
    first = c.deploy_for_all(**rule)
    assert [d.name for d in first] == ["m-P0", "m-P1", "m-P2"]
    existing = c.deployments.get("m-P0")
    assert c.deploy_for_all(**rule) == []           # idempotent no-op
    assert c.deployments.get("m-P0") is existing    # not rewritten
    # two new sensors arrive: only THEY deploy on re-apply
    for i in (3, 4):
        c.add_entity(f"P{i}", "PROSUMER")
        c.link(f"ts{i}", "LOAD", f"P{i}")
    second = c.deploy_for_all(**rule)
    assert [d.name for d in second] == ["m-P3", "m-P4"]
    assert len(c.deployments) == 5
    # a DIFFERENT rule colliding on the same names must stay loud — the
    # incremental skip is only for re-applying the SAME rule
    c.publish("pkg2", "1.0", _Dummy)
    with pytest.raises(ValueError):
        c.deploy_for_all(**{**rule, "package": "pkg2"})


def test_run_until_index_stepping_has_no_float_drift():
    """`run_until` must step as t0 + k*step: accumulating `t += step`
    drifts off the boundary lattice over long horizons (0.1 summed 1000x
    overshoots 100.0), skipping the final scheduler boundary."""
    c = Castor()
    ticked = []
    c.tick = lambda now, executor="fleet": ticked.append(now) or []
    c.run_until(0.0, 100.0, 0.1)
    assert len(ticked) == 1001                      # inclusive of t1
    assert ticked[-1] == 0.0 + 1000 * 0.1           # exactly on-lattice
    assert ticked[500] == 0.0 + 500 * 0.1
    # the final boundary fires even when k*step rounds a hair ABOVE t1
    # (3*0.1 > 0.3 in floats) ...
    ticked.clear()
    c.run_until(0.0, 0.3, 0.1)
    assert len(ticked) == 4
    # ... while a t1 strictly between boundaries floors, never overshoots
    ticked.clear()
    c.run_until(0.0, 0.46, 0.3)
    assert ticked == [0.0, 0.3]
    ticked.clear()
    c.run_until(5.0, 4.0, 1.0)                      # empty interval
    assert ticked == []


# ---------------- lineage ----------------
def test_version_store_latest_is_by_trained_at_not_save_order():
    """Catch-up training jobs complete out of chronological order on a
    parallel executor: 'latest' must mean max trained_at, never whichever
    save happened to land last."""
    vs = ModelVersionStore()
    vs.save("m", {"a": 1}, trained_at=20.0)
    vs.save("m", {"a": 2}, trained_at=30.0)
    vs.save("m", {"a": 3}, trained_at=10.0)   # stale boundary finished last
    assert vs.get("m").trained_at == 30.0
    assert vs.get("m").params == {"a": 2}
    # explicit version ids keep save order (artifact identity)
    assert vs.get("m", version=3).trained_at == 10.0
    # replay-faithful lookup: newest version trained AT OR BEFORE the
    # boundary; pre-first-training replays fall back to the oldest
    assert vs.get("m", at=25.0).trained_at == 20.0
    assert vs.get("m", at=10.0).trained_at == 10.0
    assert vs.get("m", at=5.0).trained_at == 10.0


def test_prediction_store_append_only_and_ranking():
    ps = PredictionStore()
    t = np.arange(3.0)
    ps.save(Forecast("m1", "S", "E", 0.0, t, np.ones(3), 1, rank=1))
    ps.save(Forecast("m2", "S", "E", 0.0, t, 2 * np.ones(3), 1, rank=0))
    ps.save(Forecast("m1", "S", "E", 10.0, t + 10, 3 * np.ones(3), 2, rank=1))
    assert len(ps.history("m1")) == 2               # rolling horizons kept
    assert ps.latest("S", "E").deployment_name == "m1"  # newest wins
    assert ps.latest("S", "E", at=0.0).deployment_name == "m2"  # rank breaks tie
    # Fig. 7 view: multiple created_at for one target time
    ps.save(Forecast("m1", "S", "E", 5.0, np.asarray([10.0]),
                     np.asarray([9.9]), 2))
    hz = ps.horizons("m1", 10.0)
    assert len(hz) == 2 and hz[0][0] == 5.0


@given(st.lists(st.tuples(st.floats(0, 100), st.integers(0, 3)), min_size=1,
                max_size=20))
@settings(max_examples=50, deadline=None)
def test_latest_is_max_created_then_min_rank(entries):
    ps = PredictionStore()
    t = np.arange(2.0)
    for i, (created, rank) in enumerate(entries):
        ps.save(Forecast(f"m{i}", "S", "E", created, t, t, 1, rank=rank))
    best = ps.latest("S", "E")
    newest = max(e[0] for e in entries)
    assert best.created_at == newest
    min_rank_at_newest = min(r for (cr, r) in entries if cr == newest)
    assert best.rank == min_rank_at_newest
