"""Forecast models: fit quality on synthetic signal, fleet==single for the
closed-form models, recursive scoring shape/finite checks."""
import numpy as np
import pytest

from repro.core import Castor, ModelDeployment, Schedule
from repro.forecast import (ANNForecaster, GAMForecaster, LSTMForecaster,
                            LinearForecaster)
from repro.forecast.transform_models import EnergyFromCurrentModel
from repro.timeseries.ingest import SiteSpec, build_site, ingest_current_feed
from repro.timeseries.transforms import DAY, HOUR, mape

NOW = 40 * DAY


@pytest.fixture(scope="module")
def castor():
    c = Castor()
    build_site(c, SiteSpec("X", n_prosumers=3, n_feeders=1,
                           n_substations=1, seed=2),
               t0=0.0, t1=NOW + 2 * DAY)
    for k, cls in [("lr", LinearForecaster), ("gam", GAMForecaster),
                   ("ann", ANNForecaster), ("lstm", LSTMForecaster)]:
        c.publish(k, "1.0", cls)
    return c


def _mape_for(c, pkg, hp=None):
    dep = ModelDeployment(name=f"t-{pkg}", package=pkg, signal="ENERGY_LOAD",
                          entity="X_SUB_0", train=Schedule(NOW, 1e12),
                          score=Schedule(NOW, 1e12),
                          user_params={"train_window_days": 21, **(hp or {})})
    c.deploy(dep)
    res = c.tick(NOW, executor="local", max_parallel=2)
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    fc = c.predictions.history(dep.name)[-1]
    t, actual = c.read("ENERGY_LOAD", "X_SUB_0", fc.times[0] - 1,
                       fc.times[-1] + 1)
    n = min(len(actual), len(fc.values))
    return mape(actual[:n], fc.values[:n])


def test_lr_and_gam_beat_naive(castor):
    m_lr = _mape_for(castor, "lr")
    m_gam = _mape_for(castor, "gam")
    assert m_lr < 15.0, m_lr
    assert m_gam < 15.0, m_gam


def test_ann_trains_reasonably(castor):
    m = _mape_for(castor, "ann", {"epochs": 80, "hidden": 16,
                                  "target_lags": 24})
    assert np.isfinite(m) and m < 30.0, m


def test_lstm_trains_reasonably(castor):
    # LSTM is the paper's weakest model too (6.37% vs 2.76-3.92% at full
    # scale); at CPU-test width/epochs we only gate on sanity.
    m = _mape_for(castor, "lstm", {"epochs": 200, "hidden": 16})
    assert np.isfinite(m) and m < 40.0, m


def test_fleet_train_matches_single_for_lr(castor):
    insts = []
    for e in ["X_PRO_0_0", "X_PRO_0_1"]:
        ctx = castor.graph.context("ENERGY_LOAD", e)
        insts.append(LinearForecaster(
            context=ctx, task="train", model_id=f"f-{e}", model_version=None,
            user_params={"train_window_days": 14, "now": NOW}, system=castor))
    fleet = LinearForecaster.fleet_train(insts)
    for inst, fm in zip(insts, fleet):
        single = inst.train()
        # float32 solver noise: vmapped and single lax solves differ at a
        # few 1e-4 relative; the contract is fleet == single up to that
        # (atol covers small-magnitude coefficients, where the absolute
        # solver noise floor sits just above 1e-4)
        np.testing.assert_allclose(fm["params"]["theta"],
                                   single["params"]["theta"],
                                   rtol=1e-3, atol=3e-4)


def test_transform_model_energy_from_current(castor):
    ingest_current_feed(castor, "X_SUB_0", t0=NOW - 2 * DAY, t1=NOW, seed=9)
    castor.publish("xform", "1.0", EnergyFromCurrentModel)
    castor.add_signal("ENERGY_LOAD_DERIVED")
    castor.deploy(ModelDeployment(
        name="xf", package="xform", signal="ENERGY_LOAD_DERIVED",
        entity="X_SUB_0", train=Schedule(NOW, 1e12), score=Schedule(NOW, 1e12),
        user_params={"window_days": 2}))
    res = [r for r in castor.tick(NOW + 1, executor="local")
           if r.job.deployment_name == "xf"]
    assert all(r.ok for r in res), [r.error for r in res]
    fc = castor.predictions.history("xf")[-1]
    assert fc.values.size > 0 and np.all(fc.values >= 0)
    # 15-minute grid
    assert np.allclose(np.diff(fc.times), 900.0)
