import os

# Tests run on the single real CPU device. (The 512-device override is
# reserved for launch/dryrun.py — do NOT set it here.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
