"""Device-resident scoring rollout + fleet/local equivalence with
NON-DEFAULT hyperparameters (regression for the `_fleet_fit` hardcoded-hp
and GAM default-spline-cols bugs).

Three contracts pinned here, each across all four forecasters:
  * jitted lax.scan rollout == numpy ``recursive_forecast`` reference
  * ``fleet_score`` == per-instance ``score()`` given the same trained
    params (the scoring half of LocalPool ≡ Fleet)
  * fleet training honors the bin's user_params (widths, spline columns)
"""
import numpy as np
import pytest

from repro.core import Castor, ModelDeployment, Schedule
from repro.forecast import (ANNForecaster, GAMForecaster, LSTMForecaster,
                            LinearForecaster)
from repro.timeseries.ingest import SiteSpec, build_site
from repro.timeseries.transforms import DAY

NOW = 40 * DAY
ENTS = ["R_PRO_0_0", "R_PRO_0_1", "R_PRO_0_2"]

# deliberately NON-default hyperparameters: the fleet path must derive
# everything from user_params, never from redeclared defaults
MODELS = {
    "lr": (LinearForecaster, {"target_lags": 12, "weather_lags": 4}),
    "gam": (GAMForecaster, {"target_lags": 12, "weather_lags": 4}),
    "ann": (ANNForecaster, {"hidden": 24, "epochs": 40, "target_lags": 12}),
    "lstm": (LSTMForecaster, {"hidden": 12, "epochs": 40, "target_lags": 12}),
}


@pytest.fixture(scope="module")
def castor():
    c = Castor()
    build_site(c, SiteSpec("R", n_prosumers=3, n_feeders=1,
                           n_substations=1, seed=5),
               t0=0.0, t1=NOW + 2 * DAY)
    return c


def _instances(c, cls, hp, extra=None):
    up = {"train_window_days": 14, "now": NOW, **hp, **(extra or {})}
    return [cls(context=c.graph.context("ENERGY_LOAD", e), task="score",
                model_id=f"fr-{e}", model_version=None,
                user_params=up, system=c) for e in ENTS]


@pytest.fixture(scope="module")
def trained(castor):
    """Fleet-trained model objects per kind (shared across tests)."""
    return {kind: cls.fleet_train(_instances(castor, cls, hp))
            for kind, (cls, hp) in MODELS.items()}


@pytest.mark.parametrize("kind", list(MODELS))
def test_device_rollout_matches_numpy_reference(castor, trained, kind):
    """rollout='device' (one jitted lax.scan per bin) and rollout='host'
    (numpy recursive_forecast) must agree — same recursion, same params."""
    cls, hp = MODELS[kind]
    device = cls.fleet_score(_instances(castor, cls, hp), trained[kind])
    host = cls.fleet_score(_instances(castor, cls, hp, {"rollout": "host"}),
                           trained[kind])
    for (dt, dv, *_), (ht, hv, *_) in zip(device, host):
        np.testing.assert_allclose(dt, ht)
        np.testing.assert_allclose(dv, hv, rtol=2e-3, atol=1e-3)


@pytest.mark.parametrize("kind", list(MODELS))
def test_fleet_score_matches_single_score(castor, trained, kind):
    """Given identical trained params, the megabatched fleet scoring path
    equals N per-instance score() calls (observational equivalence)."""
    cls, hp = MODELS[kind]
    insts = _instances(castor, cls, hp)
    fleet = cls.fleet_score(insts, trained[kind])
    for inst, mo, (ft, fv, *_) in zip(insts, trained[kind], fleet):
        st, sv = inst.score(mo)[:2]
        np.testing.assert_allclose(ft, st)
        np.testing.assert_allclose(fv, sv, rtol=2e-3, atol=1e-3)


def test_fleet_fit_honors_user_hyperparams(trained):
    """Regression: ANN/LSTM fleet training hardcoded width/epochs/lr, so a
    hidden=24 deployment fleet-trained a width-64 model."""
    ann = trained["ann"][0]["params"]
    assert ann["w0"].shape[-1] == 24, ann["w0"].shape
    assert ann["w1"].shape == (24, 24)
    lstm = trained["lstm"][0]["params"]
    assert lstm["wh0"].shape == (12, 48), lstm["wh0"].shape
    # GAM: non-default target_lags moves the concurrent-temp spline column
    gam = trained["gam"][0]["params"]
    np.testing.assert_array_equal(gam["cols"], [0, 12])


def _deployed_castor(kind, executor):
    cls, hp = MODELS[kind]
    c = Castor()
    build_site(c, SiteSpec("Q", n_prosumers=4, n_feeders=1,
                           n_substations=1, seed=6),
               t0=0.0, t1=NOW + 2 * DAY)
    c.publish(kind, "1.0", cls)
    c.deploy_for_all(package=kind, signal="ENERGY_LOAD", name_prefix="e",
                     kind="PROSUMER", train=Schedule(NOW, 1e12),
                     score=Schedule(NOW, 1e12),
                     user_params={"train_window_days": 14, **hp})
    res = c.tick(NOW, executor=executor)
    assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    return c


@pytest.mark.parametrize("kind", ["lr", "gam"])
def test_fleet_equals_local_tick_nondefault_hp(kind):
    """End-to-end: with non-default hyperparameters, the two executors
    persist identical forecasts for the deterministic (closed-form)
    models. Catches both satellite bugs: hardcoded fleet hp and GAM's
    default spline columns."""
    ca = _deployed_castor(kind, "fleet")
    cb = _deployed_castor(kind, "local")
    for i in range(4):
        fa = ca.predictions.history(f"e-Q_PRO_0_{i}")
        fb = cb.predictions.history(f"e-Q_PRO_0_{i}")
        assert len(fa) == len(fb) == 1
        np.testing.assert_allclose(fa[0].times, fb[0].times)
        np.testing.assert_allclose(fa[0].values, fb[0].values,
                                   rtol=2e-3, atol=1e-3)


@pytest.mark.parametrize("kind", ["ann", "lstm"])
def test_fleet_tick_trains_configured_width(kind):
    """End-to-end regression through the executor: fleet-trained versions
    carry the deployment's width, not the hardcoded default."""
    c = _deployed_castor(kind, "fleet")
    width = MODELS[kind][1]["hidden"]
    for i in range(4):
        params = c.versions.get(f"e-Q_PRO_0_{i}").params["params"]
        shape = (params["w1"].shape if kind == "ann"
                 else params["wh0"].shape)
        assert shape == ((width, width) if kind == "ann"
                         else (width, 4 * width)), shape
        fc = c.predictions.history(f"e-Q_PRO_0_{i}")[-1]
        assert np.all(np.isfinite(fc.values))
