"""Deterministic fallback for ``hypothesis`` when it is not installed.

The container image does not always ship ``hypothesis``; hard-importing it
made ``pytest`` fail at collection. Property tests import from this module
instead::

    from _hypothesis_compat import given, settings, st

When ``hypothesis`` is available it is re-exported untouched. Otherwise a
tiny deterministic engine runs each property over a seeded example grid:
boundary cases first (min/max of each scalar strategy), then samples from
``numpy.random.default_rng`` seeded by the test name — every run explores
the identical examples, so failures reproduce exactly.

Only the strategy surface this repo uses is implemented: ``floats``,
``integers``, ``lists``, ``tuples`` (plus kwargs like ``allow_nan``, which
the bounded fallbacks never generate anyway).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    _DEFAULT_EXAMPLES = 25          # cap: determinism matters, volume doesn't

    class _Strategy:
        def sample(self, rng, boundary=None):
            raise NotImplementedError

    class _Floats(_Strategy):
        def __init__(self, min_value=0.0, max_value=1.0, **_kw):
            self.lo = float(min_value)
            self.hi = float(max_value)

        def sample(self, rng, boundary=None):
            if boundary == 0:
                return self.lo
            if boundary == 1:
                return self.hi
            return float(rng.uniform(self.lo, self.hi))

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=1, **_kw):
            self.lo = int(min_value)
            self.hi = int(max_value)

        def sample(self, rng, boundary=None):
            if boundary == 0:
                return self.lo
            if boundary == 1:
                return self.hi
            return int(rng.integers(self.lo, self.hi + 1))

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=10, **_kw):
            self.elements = elements
            self.min_size = int(min_size)
            self.max_size = int(max_size)

        def sample(self, rng, boundary=None):
            if boundary == 0:
                size = self.min_size
            elif boundary == 1:
                size = self.max_size
            else:
                size = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elements.sample(rng) for _ in range(size)]

    class _Tuples(_Strategy):
        def __init__(self, *elements):
            self.elements = elements

        def sample(self, rng, boundary=None):
            return tuple(e.sample(rng) for e in self.elements)

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **kw):
            return _Floats(min_value, max_value, **kw)

        @staticmethod
        def integers(min_value=0, max_value=1, **kw):
            return _Integers(min_value, max_value, **kw)

        @staticmethod
        def lists(elements, **kw):
            return _Lists(elements, **kw)

        @staticmethod
        def tuples(*elements):
            return _Tuples(*elements)

    def settings(*, max_examples=_DEFAULT_EXAMPLES, **_kw):
        def deco(fn):
            fn._max_examples = min(int(max_examples), _DEFAULT_EXAMPLES)
            return fn
        return deco

    def given(*pos_strats, **kw_strats):
        def deco(fn):
            n = getattr(fn, "_max_examples", _DEFAULT_EXAMPLES)

            def wrapper():
                rng = np.random.default_rng(
                    zlib.crc32(fn.__name__.encode()))
                for i in range(n):
                    boundary = i if i < 2 else None
                    args = [s.sample(rng, boundary) for s in pos_strats]
                    kwargs = {k: s.sample(rng, boundary)
                              for k, s in kw_strats.items()}
                    try:
                        fn(*args, **kwargs)
                    except Exception:
                        print(f"falsifying example ({fn.__name__}, "
                              f"case {i}): args={args!r} kwargs={kwargs!r}")
                        raise
                return None
            # NOT functools.wraps: pytest would introspect the wrapped
            # signature and demand fixtures for the strategy parameters
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
