"""Serving driver: continuous-batching engine over synthetic request traffic.

    python -m repro.launch.serve --arch qwen3-1.7b --smoke --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..arch import model as M
from ..configs import get_config
from ..serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    arch = args.arch + ("-smoke" if args.smoke else "")
    cfg = get_config(arch)
    print(f"[serve] arch={cfg.name} slots={args.slots} max_seq={args.max_seq}")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    eng = ServeEngine(cfg, params, max_slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len)
                    .astype(np.int32),
                    max_new_tokens=args.new_tokens, arrived_at=0.0)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    total = eng.run_until_idle()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    print(f"[serve] {done}/{len(reqs)} requests, {total} tokens in {dt:.1f}s "
          f"({total/max(dt,1e-9):.1f} tok/s, {eng.steps} engine steps)")
    assert done == len(reqs)
    return reqs


if __name__ == "__main__":
    main()
