"""Production mesh builders (functions, not module constants — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def _axis_type_kw(n_axes: int) -> dict:
    """``axis_types`` only where the running jax has it (>= 0.5); on older
    versions every axis is Auto-typed already, so omitting it is identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kw(len(axes)))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kw(len(axes)))


#: axis name of the fleet-execution mesh (instance axis of a job bin)
FLEET_AXIS = "fleet"

_FLEET_MESHES: dict = {}


def make_fleet_mesh(n_devices: int | None = None):
    """1-D mesh over the local devices for sharding a fleet bin's instance
    axis. Returns None with fewer than 2 devices (nothing to shard over).
    Memoized per device count: FleetExecutor asks once per bin and jit
    caches key on mesh identity."""
    n = n_devices if n_devices is not None else jax.device_count()
    if n < 2:
        return None
    mesh = _FLEET_MESHES.get(n)
    if mesh is None:
        mesh = _FLEET_MESHES[n] = make_mesh((n,), (FLEET_AXIS,))
    return mesh


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
