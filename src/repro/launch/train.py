"""Training driver: supervised loop with sharded async checkpointing,
restart-on-failure and (optional) simulated node loss.

    python -m repro.launch.train --arch qwen3-1.7b --smoke --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..arch import model as M
from ..configs import get_config
from ..data.synthetic import SyntheticTokenStream
from ..distributed.checkpoint import CheckpointManager
from ..distributed.fault import NodeFailure, TrainSupervisor
from ..train import AdamWConfig, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a node failure at this step (test fault path)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = args.arch + ("-smoke" if args.smoke else "")
    cfg = get_config(arch)
    print(f"[train] arch={cfg.name} params~{M.param_count(cfg)/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=args.lr)
    opt_state = init_state(params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt=opt))
    stream = SyntheticTokenStream(cfg.vocab_size, args.batch, args.seq)

    ckpt = CheckpointManager(f"{args.checkpoint_dir}/{cfg.name}", keep=3)
    state = {"params": params, "opt": opt_state}
    start = 0
    if args.resume:
        restored, manifest = ckpt.restore_latest(state)
        if restored is not None:
            state, start = restored, manifest["step"]
            print(f"[train] resumed from step {start}")

    losses = []
    fail_at = {"n": args.inject_failure_at}

    def supervised_step(st, batch):
        if fail_at["n"] == len(losses):
            fail_at["n"] = -1
            raise NodeFailure("injected failure (--inject-failure-at)")
        p, o, metrics = step_fn(st["params"], st["opt"], batch)
        losses.append(float(metrics["loss"]))
        return {"params": p, "opt": o}

    sup = TrainSupervisor(ckpt, checkpoint_every=args.checkpoint_every)
    t0 = time.time()
    state, rep = sup.run(state, iter(stream), supervised_step,
                         start_step=start, num_steps=args.steps)
    dt = time.time() - t0
    tok_s = rep.steps_run * args.batch * args.seq / max(dt, 1e-9)
    print(f"[train] ran {rep.steps_run} steps in {dt:.1f}s "
          f"({tok_s:,.0f} tok/s) failures={rep.failures_handled} "
          f"restores={rep.restores}")
    if losses:
        k = max(len(losses) // 10, 1)
        print(f"[train] loss first10={np.mean(losses[:k]):.4f} "
              f"last10={np.mean(losses[-k:]):.4f}")
    ckpt.save_sync(state, step=rep.final_step)
    print(f"[train] final checkpoint at step {rep.final_step}")
    return losses


if __name__ == "__main__":
    main()
