import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
on placeholder devices; record memory/cost/collective analysis to JSON.

The XLA_FLAGS assignment above MUST run before any jax import (device count
locks on first init) — keep it the first statement of this module.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from ..configs import SHAPES, get_config, list_archs, shape_applicable  # noqa: E402
from . import hlo_cost  # noqa: E402
from .cells import build_cell, lower_cell  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link (we count per-device wire bytes)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Path, keep_hlo: bool = False,
             optimized: bool = False, **cell_kw) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    if optimized:
        # §Perf configuration: weight-stationary serving + distributed
        # flash-decode for serve cells; sequence parallelism for train cells
        from ..distributed.sharding import serve_rules
        kind = SHAPES[shape_name].kind
        if kind in ("decode", "prefill"):
            cell_kw.setdefault("rules", serve_rules(multi_pod))
            if kind == "decode":
                cell_kw.setdefault("dist_decode", True)
        # train: sequence parallelism (sp_rules) is a per-cell lever — it
        # halves llama4's memory term but regresses internlm2's collectives
        # (§Perf); pass rules=sp_rules(...) explicitly where it wins.
    cell = build_cell(arch, shape_name, mesh, **cell_kw)
    lowered = lower_cell(cell)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = hlo_cost.xla_cost_properties(compiled)
    text = compiled.as_text()
    cost = hlo_cost.analyze(text, n_dev)

    result = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "mesh": dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names))),
        "n_devices": n_dev,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "xla_cost_analysis": {"flops_per_iter": ca.get("flops", 0.0),
                              "bytes_per_iter": ca.get("bytes accessed", 0.0)},
        "hlo_cost": {
            "flops": cost.flops,
            "bytes": cost.bytes,
            "collective_wire_bytes": cost.collective_wire_bytes,
            "collectives": dict(cost.collectives),
            "collective_counts": dict(cost.collective_counts),
        },
        "roofline": {
            "compute_s": cost.flops / PEAK_FLOPS,
            "memory_s": cost.bytes / HBM_BW,
            "collective_s": cost.collective_wire_bytes / ICI_BW,
        },
    }
    rl = result["roofline"]
    result["roofline"]["dominant"] = max(rl, key=lambda k: rl[k])
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'pod'}"
    with open(out_dir / f"{tag}.json", "w") as f:
        json.dump(result, f, indent=1)
    if keep_hlo:
        (out_dir / f"{tag}.hlo.txt").write_text(text)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf optimized layouts (serve_rules + "
                         "distributed flash-decode + SP)")
    args = ap.parse_args()
    out_dir = Path(args.out)

    cells = []
    if args.all:
        for a in list_archs():
            cfg = get_config(a)
            for s in SHAPES.values():
                ok, why = shape_applicable(cfg, s)
                if ok:
                    cells.append((a, s.name))
                else:
                    print(f"SKIP {a} x {s.name}: {why}")
    else:
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
            if args.skip_existing and (out_dir / f"{tag}.json").exists():
                print(f"skip existing {tag}")
                continue
            try:
                r = run_cell(arch, shape, multi_pod=mp, out_dir=out_dir,
                             keep_hlo=args.keep_hlo, optimized=args.optimized)
                rl = r["roofline"]
                print(f"OK  {tag}: compile={r['t_compile_s']}s "
                      f"mem/dev={r['memory']['peak_per_device_bytes']/2**30:.2f}GiB "
                      f"compute={rl['compute_s']*1e3:.2f}ms "
                      f"memory={rl['memory_s']*1e3:.2f}ms "
                      f"coll={rl['collective_s']*1e3:.2f}ms "
                      f"dom={rl['dominant']}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
