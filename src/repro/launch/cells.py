"""Build one dry-run cell: (arch x input-shape x mesh) -> jitted step +
ShapeDtypeStruct args + shardings. Shared by dryrun.py, the roofline bench
and the perf-iteration harness.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..arch import model as M
from ..arch.params import shape_structs
from ..configs import SHAPES, get_config
from ..configs.base import ModelConfig, ShapeSpec
from ..data.synthetic import input_specs_for
from ..distributed.sharding import (Rules, baseline_rules, batch_shardings,
                                    decode_state_shardings, make_shard_fn,
                                    param_shardings)
from ..train import AdamWConfig, make_train_step, state_specs
from ..train.step import make_decode_step, make_prefill_step


@dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeSpec
    mesh: Any
    rules: Rules
    fn: Callable            # jitted
    args: Tuple             # ShapeDtypeStructs
    kind: str


def auto_microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh,
                      budget_bytes: float = 4 * 2**30) -> int:
    """Gradient-accumulation factor so the per-device remat residual stack
    (num_periods x B_loc x S x d x 2 bytes) fits the activation budget."""
    import math
    dp = math.prod(mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names)
    b_loc = max(1, shape.global_batch // dp)
    stack = cfg.num_periods * b_loc * shape.seq_len * cfg.d_model * 2
    mb = 1
    while stack / mb > budget_bytes and mb * 2 <= b_loc \
            and shape.global_batch % (mb * 2) == 0:
        mb *= 2
    return mb


def build_cell(arch: str, shape_name: str, mesh, *,
               rules: Optional[Rules] = None,
               opt: AdamWConfig = AdamWConfig(),
               moe_path: str = "dispatch",
               remat: bool = True,
               microbatches: int = 0,
               scan_unroll: int = 1,
               serve_dtype: str = "bfloat16",
               dist_decode: bool = False,
               cast_params_bf16: bool = False,
               extra: Optional[dict] = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi_pod = "pod" in mesh.axis_names
    rules = rules or baseline_rules(multi_pod)
    shard = make_shard_fn(mesh, rules)
    if microbatches == 0:           # auto-size gradient accumulation
        microbatches = (auto_microbatches(cfg, shape, mesh)
                        if shape.kind == "train" else 1)

    pspecs = M.build_param_specs(cfg)
    in_batch = input_specs_for(cfg, shape)
    b_shardings = batch_shardings(mesh, rules, in_batch)

    if shape.kind == "train":
        params = shape_structs(pspecs, jnp.dtype(cfg.param_dtype))
        p_shard = param_shardings(mesh, rules, pspecs)
        ostate = state_specs(pspecs, opt)
        # moments shard exactly like the parameters
        o_shard = type(ostate)(step=NamedSharding(mesh, P()),
                               mu=p_shard, nu=p_shard)
        step = make_train_step(cfg, opt=opt, shard=shard, remat=remat,
                               moe_path=moe_path, microbatches=microbatches,
                               scan_unroll=scan_unroll, moe_groups=mesh.size,
                               cast_params_bf16=cast_params_bf16)
        fn = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shardings),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        args = (params, ostate, in_batch)
    elif shape.kind == "prefill":
        params = shape_structs(pspecs, jnp.dtype(serve_dtype))
        p_shard = param_shardings(mesh, rules, pspecs)
        step = make_prefill_step(cfg, shard=shard, moe_path=moe_path,
                                 moe_groups=mesh.size)
        fn = jax.jit(step, in_shardings=(p_shard, b_shardings))
        args = (params, in_batch)
    else:  # decode
        params = shape_structs(pspecs, jnp.dtype(serve_dtype))
        p_shard = param_shardings(mesh, rules, pspecs)
        dstate = M.decode_state_specs(cfg, shape.global_batch, shape.seq_len,
                                      jnp.dtype(serve_dtype))
        s_shard = decode_state_shardings(mesh, rules, cfg, dstate)
        attn_dist = None
        if dist_decode:
            attn_dist = {"mesh": mesh, "seq_axis": "model",
                         "batch_axes": ("pod", "data") if multi_pod else ("data",)}
        step = make_decode_step(cfg, shard=shard, moe_path=moe_path,
                                scan_unroll=scan_unroll, moe_groups=mesh.size,
                                attn_dist=attn_dist)
        fn = jax.jit(step,
                     in_shardings=(p_shard, s_shard, b_shardings),
                     out_shardings=(None, s_shard),
                     donate_argnums=(1,))
        args = (params, dstate, in_batch)
    return Cell(cfg=cfg, shape=shape, mesh=mesh, rules=rules, fn=fn,
                args=args, kind=shape.kind)


def lower_cell(cell: Cell):
    with cell.mesh:
        return cell.fn.lower(*cell.args)
