"""Loop-aware HLO cost model.

NOTE (CPU-legalization discount): the dry-run lowers for the CPU backend,
which legalises bf16 matmuls by materialising f32 CONVERTs of the operands —
traffic that does not exist on the TPU target (bf16 x bf16 -> f32 is native
MXU). ``analyze(..., discount_converts=True)`` therefore zero-costs convert
ops and convert-only fusions. Real model-level casts (f32 master params ->
bf16 compute) are orders of magnitude smaller and noted in EXPERIMENTS.md.

``compiled.cost_analysis()`` counts each computation ONCE — a ``lax.scan``
over 48 layers reports 1/48th of the real FLOPs (verified empirically). This
module parses the post-optimization HLO text, builds the call graph, extracts
while-loop trip counts from loop conditions, and accumulates

    * flops              (dot: 2*M*N*K; elementwise/reduce: 1/elem)
    * bytes              (operand + result bytes of non-fused top-level ops)
    * collective bytes   (per-device wire bytes per collective, ring model)

with every computation weighted by its loop multiplicity. Fusion callees are
folded into their fusion op (operand/result bytes counted once, internals 0),
matching XLA's own bytes-accessed semantics.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "sign", "cosine", "sine", "logistic",
    "floor", "ceil", "round-nearest-afz", "select", "compare", "and", "or",
    "xor", "not", "clamp", "remainder", "atan2", "cbrt", "erf",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")


@dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]
    tuple_elems: Optional[List["Shape"]] = None

    @property
    def elems(self) -> int:
        return math.prod(self.dims) if self.tuple_elems is None else 0

    @property
    def bytes(self) -> int:
        if self.tuple_elems is not None:
            return sum(s.bytes for s in self.tuple_elems)
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


def _parse_shape(text: str, pos: int = 0) -> Tuple[Shape, int]:
    """Parse one shape starting at text[pos]. Handles tuples recursively."""
    if text[pos] == "(":
        elems = []
        pos += 1
        while text[pos] != ")":
            s, pos = _parse_shape(text, pos)
            elems.append(s)
            if text[pos] == ",":
                pos += 1
                while text[pos] == " ":
                    pos += 1
        return Shape("tuple", (), elems), pos + 1
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", text[pos:])
    if not m:
        # e.g. token[] style or unranked; consume identifier
        m2 = re.match(r"(\w+)", text[pos:])
        return Shape(m2.group(1) if m2 else "opaque", ()), pos + (m2.end() if m2 else 1)
    dtype = m.group(1)
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    end = pos + m.end()
    # skip layout {...} and memory space annotations
    while end < len(text) and text[end] == "{":
        depth = 0
        while end < len(text):
            if text[end] == "{":
                depth += 1
            elif text[end] == "}":
                depth -= 1
                if depth == 0:
                    end += 1
                    break
            end += 1
    return Shape(dtype, dims), end


@dataclass
class Op:
    name: str
    shape: Shape
    opcode: str
    operands: List[str]
    attrs: str
    args: str = ""


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    by_name: Dict[str, Shape] = field(default_factory=dict)


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(|\w+\[)")
_CALL_ATTRS = ("calls=", "body=", "condition=", "to_apply=",
               "true_computation=", "false_computation=", "branch_computations=")


def _parse_operands(rest: str) -> Tuple[str, List[str], str, str]:
    """rest starts at opcode: 'dot(%a, %b), attrs...'."""
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return rest.strip(), [], "", ""
    opcode = m.group(1)
    depth, i = 0, m.end() - 1
    start = m.end()
    while i < len(rest):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
        i += 1
    args = rest[start:i]
    attrs = rest[i + 1:]
    operands = re.findall(r"%([\w.\-]+)", args)
    return opcode, operands, attrs, args


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith(("//", "#")):
            continue
        # computation header: `%name (params...) -> type {` or `ENTRY %name ... {`
        # (param lists contain nested parens for tuple types, so detect by the
        # trailing "{" plus absence of "=" before the first paren)
        if stripped.endswith("{") and "=" not in stripped.split("(", 1)[0] \
                and not stripped.startswith("HloModule"):
            hm = re.match(r"(ENTRY\s+)?%?([\w.\-~!]+)", stripped)
            if hm:
                cur = Computation(hm.group(2))
                comps[cur.name] = cur
                if hm.group(1):
                    entry = cur.name
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        name = om.group(1)
        eq = line.index("=", om.start())
        shape, pos = _parse_shape(line, eq + 2 if line[eq + 1] == " " else eq + 1)
        rest = line[pos:].strip()
        opcode, operands, attrs, args = _parse_operands(rest)
        op = Op(name, shape, opcode, operands, attrs, args)
        cur.ops.append(op)
        cur.by_name[name] = shape
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _called(op: Op) -> List[str]:
    out = []
    for key in _CALL_ATTRS:
        for m in re.finditer(re.escape(key) + r"(\{[^}]*\}|%?[\w.\-]+)", op.attrs):
            val = m.group(1)
            out.extend(re.findall(r"%?([\w.\-]+)", val.strip("{}")))
    return [c.lstrip("%") for c in out]


def _trip_count(cond: Computation, body: Computation) -> int:
    """Scan loops compare the induction var against a constant bound."""
    consts = []
    for op in cond.ops:
        if op.opcode == "constant" and op.shape.dtype in ("s32", "u32", "s64", "u64"):
            m = re.search(r"(\d+)", op.args)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _group_size(attrs: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _dot_flops(op: Op, comp: Computation) -> int:
    out_elems = op.shape.elems
    lhs = comp.by_name.get(op.operands[0]) if op.operands else None
    if lhs is None:
        return 2 * out_elems
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    cdims = [int(d) for d in m.group(1).split(",")] if m and m.group(1) else []
    k = math.prod(lhs.dims[d] for d in cdims) if cdims else 1
    return 2 * out_elems * k


def _fusion_bytes(op: Op, comp: Computation, comps: Dict[str, Computation]) -> float:
    """HBM traffic of a fusion op.

    Walks the fused computation tracing each parameter through TRANSPARENT
    ops (convert/bitcast/reshape/transpose/copy — no HBM traffic of their
    own inside a fusion) to its effective consumers:
      * consumed only by dynamic-slice(operand 0)  -> count slice bytes
      * aliased through a root dynamic-update-slice -> count 2x update bytes
      * anything else                               -> full buffer bytes
    This captures both native scan slicing AND the CPU-legalised
    convert(DUS(convert(...))) cache write-back pattern.
    """
    callees = [comps[c] for c in _called(op) if c in comps]
    if not callees:
        return sum(comp.by_name.get(o, Shape("opaque", ())).bytes
                   for o in op.operands) + op.shape.bytes
    fc = callees[0]
    by_name = {o.name: o for o in fc.ops}
    TRANSPARENT = ("convert", "bitcast", "reshape", "transpose", "copy")

    param_idx = {}
    for fop in fc.ops:
        if fop.opcode == "parameter" and fop.args.strip().isdigit():
            param_idx[fop.name] = int(fop.args.strip())

    # consumers map: name -> [(op, operand_position)]
    consumers: Dict[str, list] = {}
    for fop in fc.ops:
        for pos, o in enumerate(fop.operands):
            consumers.setdefault(o, []).append((fop, pos))

    root = fc.ops[-1] if fc.ops else None

    def flows_to_root_transparent(name: str) -> bool:
        seen = set()
        stack = [name]
        while stack:
            n = stack.pop()
            if root is not None and n == root.name:
                return True
            for (cop, _pos) in consumers.get(n, ()):  # noqa: B007
                if cop.name in seen:
                    continue
                seen.add(cop.name)
                if cop.opcode in TRANSPARENT or cop is root:
                    stack.append(cop.name)
        return root is not None and name == root.name

    total = 0.0
    root_aliased = False
    for i, o in enumerate(op.operands):
        full = comp.by_name.get(o, Shape("opaque", ())).bytes
        pname = next((n for n, idx in param_idx.items() if idx == i), None)
        if pname is None:
            total += full
            continue
        # effective consumers through transparent chains
        eff = []
        seen = set()
        stack = [pname]
        while stack:
            n = stack.pop()
            for (cop, pos) in consumers.get(n, ()):
                if (cop.name, pos) in seen:
                    continue
                seen.add((cop.name, pos))
                if cop.opcode in TRANSPARENT:
                    stack.append(cop.name)
                else:
                    eff.append((cop, pos))
        if not eff:
            continue                                 # unused param
        b = 0.0
        fallback = False
        for (cop, pos) in eff:
            if cop.opcode == "dynamic-slice" and pos == 0:
                b += cop.shape.bytes
            elif cop.opcode == "dynamic-update-slice" and pos == 0 \
                    and flows_to_root_transparent(cop.name):
                upd = (fc.by_name.get(cop.operands[1], Shape("opaque", ()))
                       if len(cop.operands) > 1 else Shape("opaque", ()))
                b += 2 * upd.bytes
                root_aliased = True
            elif cop.opcode == "scatter" and pos == 0 \
                    and flows_to_root_transparent(cop.name):
                upd = (fc.by_name.get(cop.operands[-1], Shape("opaque", ()))
                       if len(cop.operands) >= 3 else Shape("opaque", ()))
                b += 2 * upd.bytes
                root_aliased = True
            else:
                fallback = True
                break
        total += full if fallback else b
    if not root_aliased:
        total += op.shape.bytes                      # output written in full
    return total


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))


def _op_wire_bytes(op: Op, n_devices: int) -> Tuple[str, float]:
    base = op.opcode.replace("-start", "")
    g = _group_size(op.attrs, n_devices)
    R = op.shape.bytes
    if base == "all-reduce":
        return base, 2 * R * (g - 1) / g
    if base in ("all-gather", "all-to-all", "collective-broadcast",
                "ragged-all-to-all"):
        return base, R * (g - 1) / g
    if base == "reduce-scatter":
        return base, R * (g - 1)
    if base.startswith("collective-permute"):
        return "collective-permute", R
    return base, 0.0


def _is_convert_only(callee: Computation) -> bool:
    for fop in callee.ops:
        if fop.opcode not in ("convert", "parameter", "bitcast", "copy",
                              "tuple", "get-tuple-element", "reshape",
                              "transpose"):
            return False
    return any(fop.opcode == "convert" for fop in callee.ops)


def analyze(text: str, n_devices: int, *,
            discount_converts: bool = True) -> CostTotals:
    comps, entry = parse_hlo(text)
    totals = CostTotals()
    # computations reachable only via fusion are folded into the fusion op
    fused: set = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                for c in _called(op):
                    fused.add(c)

    memo: Dict[str, CostTotals] = {}

    def cost_of(name: str) -> CostTotals:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = CostTotals()
        memo[name] = out
        if comp is None:
            return out
        for op in comp.ops:
            oc = op.opcode
            if oc.endswith("-done"):
                continue
            if discount_converts and oc == "convert":
                continue
            if discount_converts and oc == "fusion":
                callees = [comps[c] for c in _called(op) if c in comps]
                if callees and _is_convert_only(callees[0]):
                    continue
            if oc.replace("-start", "") in _COLLECTIVES:
                kind, wb = _op_wire_bytes(op, n_devices)
                out.collective_wire_bytes += wb
                out.collectives[kind] += wb
                out.collective_counts[kind] += 1
                out.bytes += op.shape.bytes
                continue
            if oc == "while":
                body, cond = None, None
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                if bm and cm and bm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)], comps[bm.group(1)])
                    sub = cost_of(bm.group(1))
                    csub = cost_of(cm.group(1))
                    out.flops += trips * (sub.flops + csub.flops)
                    out.bytes += trips * (sub.bytes + csub.bytes)
                    out.collective_wire_bytes += trips * sub.collective_wire_bytes
                    for k, v in sub.collectives.items():
                        out.collectives[k] += trips * v
                        out.collective_counts[k] += trips * sub.collective_counts[k]
                continue
            if oc == "dynamic-slice":
                # reads only the slice, not the sliced operand
                out.bytes += 2 * op.shape.bytes
                continue
            if oc == "dynamic-update-slice":
                # in-place: traffic = read+write of the update region
                upd = (comp.by_name.get(op.operands[1], Shape("opaque", ()))
                       if len(op.operands) > 1 else Shape("opaque", ()))
                out.bytes += 2 * upd.bytes
                continue
            if oc == "scatter":
                # in-place on TPU: traffic = indices + 2x updates region
                upd = (comp.by_name.get(op.operands[-1], Shape("opaque", ()))
                       if len(op.operands) >= 3 else Shape("opaque", ()))
                idxs = (comp.by_name.get(op.operands[1], Shape("opaque", ()))
                        if len(op.operands) >= 2 else Shape("opaque", ()))
                out.bytes += 2 * upd.bytes + idxs.bytes
                continue
            if oc in ("fusion", "call", "conditional", "custom-call", "reduce",
                      "sort", "map", "reduce-window", "select-and-scatter"):
                # bytes at the op boundary; operands a fusion consumes only
                # through dynamic-slice count at slice size, and a fusion
                # rooted in dynamic-update-slice aliases its big operand
                out.bytes += _fusion_bytes(op, comp, comps) if oc == "fusion" \
                    else (sum(comp.by_name.get(o, Shape("opaque", ())).bytes
                              for o in op.operands) + op.shape.bytes)
                if oc == "reduce":
                    out.flops += sum(comp.by_name.get(o, Shape("opaque", ())).elems
                                     for o in op.operands[:len(op.operands) // 2])
                for c in _called(op):
                    if oc == "fusion":
                        fc = comps.get(c)
                        if fc:        # flops inside fusions still count
                            for fop in fc.ops:
                                if fop.opcode == "dot":
                                    out.flops += _dot_flops(fop, fc)
                                elif fop.opcode in _ELEMENTWISE:
                                    out.flops += fop.shape.elems
                                elif fop.opcode == "reduce":
                                    out.flops += sum(
                                        fc.by_name.get(o, Shape("opaque", ())).elems
                                        for o in fop.operands[:len(fop.operands) // 2])
                    else:
                        sub = cost_of(c)
                        out.flops += sub.flops
                        out.bytes += sub.bytes
                        out.collective_wire_bytes += sub.collective_wire_bytes
                        for k, v in sub.collectives.items():
                            out.collectives[k] += v
                            out.collective_counts[k] += sub.collective_counts[k]
                continue
            # plain op
            if oc == "dot":
                out.flops += _dot_flops(op, comp)
            elif oc == "convolution":
                # flops = 2 * out_elems * (kernel elems / out_channels)
                rhs = comp.by_name.get(op.operands[1]) if len(op.operands) > 1 else None
                kmul = (rhs.elems // max(rhs.dims[-1], 1)) if rhs and rhs.dims else 1
                out.flops += 2 * op.shape.elems * kmul
            elif oc in _ELEMENTWISE:
                out.flops += op.shape.elems
            if oc not in ("parameter", "constant", "get-tuple-element", "tuple",
                          "bitcast", "copy-start", "copy-done"):
                opnd = sum(comp.by_name.get(o, Shape("opaque", ())).bytes
                           for o in op.operands)
                out.bytes += opnd + op.shape.bytes
        return out

    ent = cost_of(entry)
    return ent


def xla_cost_properties(compiled) -> dict:
    """jax-version-portable ``compiled.cost_analysis()``: jax <= 0.4.x
    returns a one-element list of property dicts, newer jax returns the
    dict itself. Always hands back a plain dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
