"""Continuous-batching serving engine.

Slot-based scheduler in the vLLM style, sized for the examples/tests (the
production-mesh serving path is exercised by the decode/prefill dry-run
cells): a fixed pool of B cache slots; arriving requests are admitted into
free slots via single-request prefill, every engine step decodes one token
for all active slots, finished requests free their slot immediately.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..arch import model as M
from ..configs.base import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    arrived_at: float = 0.0
    # filled by the engine:
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_seq: int = 256, eos_id: Optional[int] = None,
                 greedy: bool = True):
        assert cfg.is_decoder, f"{cfg.name} cannot decode"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.greedy = greedy
        self.state = M.init_decode_state(cfg, max_slots, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, s, b: M.decode_step(cfg, p, s, b))
        self.steps = 0
        self.tokens_out = 0

    # ------------- request plumbing -------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Prefill pending requests into free slots (token-by-token prefill
        through the decode path keeps one compiled program; fine at example
        scale — the prefill_32k dry-run cells cover the batched path)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            self._reset_slot(slot)
            for tok in req.prompt[:-1]:
                self._step_slot(slot, int(tok))
            self.slot_req[slot] = req
            req.tokens = []
            req._next_input = int(req.prompt[-1])      # type: ignore

    def _reset_slot(self, slot: int):
        def zero_slot(x):
            if x.ndim >= 2 and x.shape[1] == self.max_slots:
                return x.at[:, slot].set(0)
            return x
        caches = jax.tree_util.tree_map(zero_slot, self.state["caches"])
        lengths = self.state["lengths"].at[slot].set(0)
        self.state = {"caches": caches, "lengths": lengths}

    def _step_slot(self, slot: int, token: int):
        """Advance ONE slot by one token (prefill path)."""
        toks = np.zeros((self.max_slots, 1), np.int32)
        toks[slot] = token
        logits, new_state = self._decode(self.params, self.state,
                                         {"tokens": jnp.asarray(toks)})
        # only this slot's cache/length advance
        def merge(new, old):
            if new.ndim >= 2 and new.shape[1] == self.max_slots:
                return old.at[:, slot].set(new[:, slot])
            return old
        caches = jax.tree_util.tree_map(merge, new_state["caches"],
                                        self.state["caches"])
        lengths = self.state["lengths"].at[slot].add(1)
        self.state = {"caches": caches, "lengths": lengths}
        return np.asarray(logits[slot])

    # ------------- main loop -------------
    def step(self, now: Optional[float] = None) -> int:
        """One engine iteration: admit + one decode for all active slots.
        Returns number of tokens emitted."""
        now = time.perf_counter() if now is None else now
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        toks = np.zeros((self.max_slots, 1), np.int32)
        for i in active:
            toks[i] = self.slot_req[i]._next_input     # type: ignore
        logits, new_state = self._decode(self.params, self.state,
                                         {"tokens": jnp.asarray(toks)})
        logits = np.asarray(logits)
        # inactive slots must not advance: merge per-slot
        def merge(new, old):
            if new.ndim >= 2 and new.shape[1] == self.max_slots:
                for i in active:
                    old = old.at[:, i].set(new[:, i])
                return old
            return old
        caches = jax.tree_util.tree_map(merge, new_state["caches"],
                                        self.state["caches"])
        lengths = self.state["lengths"]
        for i in active:
            lengths = lengths.at[i].add(1)
        self.state = {"caches": caches, "lengths": lengths}

        emitted = 0
        for i in active:
            req = self.slot_req[i]
            nxt = int(np.argmax(logits[i])) if self.greedy else \
                int(np.random.default_rng(self.steps).choice(
                    len(logits[i]), p=_softmax(logits[i])))
            req.tokens.append(nxt)
            if req.first_token_at is None:
                req.first_token_at = now
            emitted += 1
            self.tokens_out += 1
            req._next_input = nxt                       # type: ignore
            full = int(self.state["lengths"][i]) >= self.max_seq - 1
            if (len(req.tokens) >= req.max_new_tokens or full
                    or (self.eos_id is not None and nxt == self.eos_id)):
                req.done = True
                req.finished_at = now
                self.slot_req[i] = None
        self.steps += 1
        return emitted

    def run_until_idle(self, max_steps: int = 10_000) -> int:
        total = 0
        for _ in range(max_steps):
            got = self.step()
            if got == 0 and not self.queue:
                break
            total += got
        return total


def _softmax(x):
    e = np.exp(x - x.max())
    return e / e.sum()
