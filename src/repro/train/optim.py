"""Minimal pytree AdamW with global-norm clipping (f32 master weights).

Optimizer state shards exactly like the parameters (same tree structure), so
FSDP rules apply transparently to moments.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"    # "bfloat16" is a memory-term lever


class AdamWState(NamedTuple):
    step: Any
    mu: Any
    nu: Any


def init_state(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(z, params),
                      nu=jax.tree_util.tree_map(z, params))


def state_specs(param_specs, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    """ShapeDtypeStruct mirror for dry-runs."""
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)  # noqa: E731
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree_util.tree_map(z, param_specs),
                      nu=jax.tree_util.tree_map(z, param_specs))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_update(params, grads, state: AdamWState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
