from .optim import AdamWConfig, AdamWState, init_state, state_specs, apply_update  # noqa: F401
from .step import make_train_step, make_prefill_step, make_decode_step  # noqa: F401
