"""Train / serve step builders shared by the launcher, dry-run and tests.

``make_train_step(cfg)`` -> f(params, opt_state, batch) -> (params, opt_state,
metrics), with optional gradient accumulation (microbatching) and a gradient
post-processing hook (cross-pod compression lives there).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..arch import model as M
from ..configs.base import ModelConfig
from .optim import AdamWConfig, apply_update

_ID = lambda x, names: x  # noqa: E731


def make_train_step(cfg: ModelConfig, *, opt: AdamWConfig = AdamWConfig(),
                    shard: Callable = _ID, remat: bool = True,
                    moe_path: str = "dispatch", microbatches: int = 1,
                    grad_hook: Optional[Callable] = None,
                    scan_unroll: int = 1, moe_groups: int = 0,
                    cast_params_bf16: bool = False):
    """Returns train_step(params, opt_state, batch).

    cast_params_bf16: cast the f32 master params to bf16 BEFORE the layer
    scans, so FSDP all-gathers move bf16 (half the wire) — grads still flow
    to the f32 masters through the cast (§Perf lever)."""

    def loss_fn(params, batch):
        if cast_params_bf16:
            from ..arch.params import cast_tree
            params = cast_tree(params, jnp.bfloat16)
        return M.train_loss(cfg, params, batch, shard=shard, remat=remat,
                            moe_path=moe_path, scan_unroll=scan_unroll,
                            moe_groups=moe_groups)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def _to_micro(x):
        # (B, ...) -> (mb, B/mb, ...); M-RoPE positions (3, B, S) keep their
        # leading 3 inside each microbatch: (3, B, S) -> (mb, 3, B/mb, S)
        if x.ndim == 3 and x.shape[0] == 3:
            return x.reshape(3, microbatches, -1, x.shape[2]).transpose(1, 0, 2, 3)
        return x.reshape((microbatches, -1) + x.shape[1:])

    def _reshard_micro(x):
        if x.ndim == 4 and x.shape[1] == 3:
            return shard(x, (None, None, "batch", None))
        return shard(x, (None, "batch") + (None,) * (x.ndim - 2))

    def accumulated(params, batch):
        mb = jax.tree_util.tree_map(_to_micro, batch)
        mb = jax.tree_util.tree_map(_reshard_micro, mb)

        def body(g_acc, xs):
            grads, metrics = single(params, xs)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return g_acc, metrics

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g_acc, metrics_stack = jax.lax.scan(body, g0, mb)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, g_acc)
        metrics = jax.tree_util.tree_map(jnp.mean, metrics_stack)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            grads, metrics = accumulated(params, batch)
        else:
            grads, metrics = single(params, batch)
        if grad_hook is not None:
            grads = grad_hook(grads)
        params, opt_state, opt_metrics = apply_update(params, grads, opt_state, opt)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, *, shard: Callable = _ID,
                      moe_path: str = "dispatch", moe_groups: int = 0):
    def prefill(params, batch):
        return M.forward(cfg, params, batch, mode="prefill", shard=shard,
                         remat=False, moe_path=moe_path, moe_groups=moe_groups)
    return prefill


def make_decode_step(cfg: ModelConfig, *, shard: Callable = _ID,
                     moe_path: str = "dispatch", scan_unroll: int = 1,
                     moe_groups: int = 0, attn_dist=None):
    def decode(params, state, batch):
        return M.decode_step(cfg, params, state, batch, shard=shard,
                             moe_path=moe_path, scan_unroll=scan_unroll,
                             moe_groups=moe_groups, attn_dist=attn_dist)
    return decode
