"""The minutely anomaly-detection flow (paper §2 derived signals +
ROADMAP item 2).

``DetectionDeployment`` is a flow-typed ``ModelDeployment``: it binds the
band-compare detector to a monitored context and schedules ``detect``
occurrences (typically every minute) instead of train/score. The
scheduler treats ``detect`` as a third task phase, the fleet executor
runs whole detection bins as ONE vectorized band-compare, and the
serverless invoker ships detection bins with the same exactly-once
payload protocol as forecasting.

``DetectionStore`` is the flow's idempotent persistence: one
``DetectionRecord`` per (deployment, occurrence boundary), however many
times at-least-once delivery executes it. On FIRST save of a record the
store also appends ``(scheduled_at, score)`` to the context's *derived
anomaly signal* — registered through the ``SemanticGraph`` so downstream
consumers query it like any other series (``Castor.read("X.anomaly",
entity)``). Idempotence is what keeps the derived series append-only
correct under chaos: a duplicate execution is dropped before it can
double-append.
"""
from __future__ import annotations

import threading
from collections import deque
from contextlib import nullcontext
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

from ..core.deployment import DeploymentStore, ModelDeployment
from ..core.scheduler import Schedule
from ..core.semantics import Signal
from ..obs.metrics import get_metrics


@dataclass
class DetectionDeployment(ModelDeployment):
    """A detection-flow deployment: ``detect`` fires at minutely cadence;
    ``train``/``score`` stay None (the banded forecast it compares
    against is produced by a separate forecast-flow deployment on the
    same context)."""
    flow: str = "detection"


@dataclass(frozen=True)
class DetectionRecord:
    """One detection occurrence's outcome — the detection analogue of a
    ``Forecast``. ``score`` is the worst normalized band exceedance over
    the occurrence's reading window (0.0 = all in band)."""
    deployment_name: str
    signal: str                   # monitored signal
    entity: str
    scheduled_at: float           # occurrence boundary (lineage timestamp)
    score: float
    n_readings: int               # readings scored in the window
    n_anomalies: int              # readings that exceeded the band
    band_misses: int              # readings outside the band's horizon
    model_version: int            # version of the forecast compared against
    derived_signal: str           # e.g. "ENERGY_LOAD.anomaly"


class DetectionStore:
    """Idempotent on (deployment, scheduled_at) — the detection analogue
    of ``PredictionStore`` — plus the derived-signal write-back."""

    def __init__(self, store=None, graph=None, *, rolling_window: int = 64):
        self._store = store
        self._graph = graph
        self._by_dep: Dict[str, List[DetectionRecord]] = {}
        self._seen: set = set()
        self._lock = threading.Lock()
        # per-deployment rolling forecast-error gauges (ROADMAP item-4
        # prerequisite): the mean band-exceedance score over the last
        # ``rolling_window`` occurrences, surfaced in the metrics
        # registry as ``detection.rolling_error.<deployment>`` — the
        # drift signal a retraining trigger would threshold on.
        # dep -> [deque, running_sum, gauge]; running sum so a minutely
        # fleet pays O(1) per record, not O(window)
        self.rolling_window = int(rolling_window)
        self._roll: Dict[str, list] = {}
        # (derived_signal, entity) -> ts_id: derived contexts are static
        # once registered, so a minutely fleet resolves each ONCE instead
        # of one graph round-trip per record per bin
        self._ts_ids: Dict[tuple, str] = {}
        # flow telemetry (Castor.stats)
        self.scored_readings = 0
        self.anomalies_flagged = 0
        self.band_misses = 0
        self.journal = None           # durability.Journal when Castor.open'd

    def save(self, rec: DetectionRecord) -> DetectionRecord:
        self.save_many([rec])
        return rec

    def save_many(self, recs: List[DetectionRecord],
                  write_back: bool = True) -> None:
        """One lock acquisition AND one batched derived-signal append per
        fleet bin (mirrors ``PredictionStore.save_many``; per-record
        ``store.append`` round-trips dominated the minutely bin before
        batching).

        Durability: the bin's fresh records journal as ONE atomic "det"
        record that SUBSUMES the derived-signal write-back — the inner
        ``append_points`` is journal-suppressed, because a torn WAL tail
        splitting a detection from its derived points (in either order)
        would diverge from any state a live run passes through. WAL
        replay re-runs ``save_many(write_back=True)``; snapshot replay
        passes ``write_back=False`` (the snapshotted series already hold
        every derived point)."""
        seen = self._seen
        by_dep_setdefault = self._by_dep.setdefault
        ts_ids_get = self._ts_ids.get
        fresh: List[DetectionRecord] = []
        write_back = write_back and self._store is not None \
            and self._graph is not None
        readings = anomalies = misses = 0
        j = self.journal
        with self._lock:
            ids: List[str] = []
            ts: List[float] = []
            vs: List[float] = []
            n_seen = len(seen)
            for rec in recs:
                key = (rec.deployment_name, float(rec.scheduled_at))
                # add-then-compare-length: one hash probe instead of a
                # membership test followed by an add
                seen.add(key)
                if len(seen) == n_seen:              # duplicate execution
                    continue
                n_seen += 1
                fresh.append(rec)
                by_dep_setdefault(rec.deployment_name, []).append(rec)
                readings += rec.n_readings
                anomalies += rec.n_anomalies
                misses += rec.band_misses
                # rolling forecast-error gauge, O(1) per fresh record
                roll = self._roll.get(rec.deployment_name)
                if roll is None:
                    roll = self._roll[rec.deployment_name] = [
                        deque(maxlen=self.rolling_window), 0.0,
                        get_metrics().gauge("detection.rolling_error."
                                            + rec.deployment_name)]
                dq = roll[0]
                if len(dq) == self.rolling_window:
                    roll[1] -= dq[0]
                dq.append(rec.score)
                roll[1] += rec.score
                roll[2].set(roll[1] / len(dq))
                if not write_back:
                    continue
                # derived-signal write-back, exactly once per occurrence:
                # the anomaly score becomes a first-class series on the
                # semantic graph, queryable like any ingested signal
                ckey = (rec.derived_signal, rec.entity)
                tid = ts_ids_get(ckey)
                if tid is None:
                    if rec.derived_signal not in self._graph.signals:
                        self._graph.add_signal(Signal(
                            rec.derived_signal, unit="score",
                            description=f"band-exceedance anomaly score "
                                        f"of {rec.signal}"))
                    tid = self._graph.context(rec.derived_signal,
                                              rec.entity).ts_id
                    self._ts_ids[ckey] = tid
                ids.append(tid)
                ts.append(rec.scheduled_at)
                vs.append(rec.score)
            self.scored_readings += readings
            self.anomalies_flagged += anomalies
            self.band_misses += misses
            if ids:
                with j.suppressed() if j is not None else nullcontext():
                    self._store.append_points(ids, ts, vs)
            if j is not None and fresh:
                j.append("det", {"records": [asdict(r) for r in fresh],
                                 "wb": write_back})

    def rolling_errors(self) -> Dict[str, float]:
        """{deployment: mean score over its last ``rolling_window``
        occurrences} — the per-deployment drift signal (also exported as
        ``detection.rolling_error.*`` gauges in the metrics registry)."""
        with self._lock:
            return {dep: roll[1] / len(roll[0])
                    for dep, roll in self._roll.items() if roll[0]}

    def history(self, deployment_name: str) -> List[DetectionRecord]:
        return list(self._by_dep.get(deployment_name, ()))

    def deployment_names(self) -> List[str]:
        return sorted(self._by_dep)

    def count(self) -> int:
        return sum(len(v) for v in self._by_dep.values())

    def stats(self) -> dict:
        # scored_readings counts every reading a detection inspected;
        # band_misses is the subset whose timestamps fell outside the
        # resolved band's horizon (stale band), so the rate is miss/total
        return {"records": self.count(),
                "scored_readings": self.scored_readings,
                "anomalies_flagged": self.anomalies_flagged,
                "band_misses": self.band_misses,
                "band_miss_rate":
                    (self.band_misses / self.scored_readings
                     if self.scored_readings else 0.0)}


def deploy_detections_for_all(
        graph, deployments: DeploymentStore, *, package: str, signal: str,
        name_prefix: str, detect: Schedule,
        user_params: Optional[dict] = None, version: Optional[str] = None,
        kind: Optional[str] = None, under: Optional[str] = None,
        rank: int = 0) -> List[DetectionDeployment]:
    """``deploy_for_all`` for the detection flow: one
    ``DetectionDeployment`` per entity carrying ``signal`` — typically
    applied over an existing forecast fleet so every monitored context
    gets a minutely detector against its own banded forecasts.

    Same incremental-idempotent contract as ``deploy_for_all``:
    re-applying the identical rule deploys only new contexts; a same-name
    deployment with a different rule collides loudly."""
    out = []
    for ent in graph.find_entities(kind=kind, has_signal=signal, under=under):
        name = f"{name_prefix}-{ent.name}"
        if name in deployments:        # already applied to this context
            prev = deployments.get(name)
            if (prev.package, prev.version, prev.signal, prev.entity,
                    getattr(prev, "detect", None), prev.rank,
                    prev.user_params, getattr(prev, "flow", None)) \
                    != (package, version, signal, ent.name, detect, rank,
                        dict(user_params or {}), "detection"):
                raise ValueError(
                    f"deployment {name} already registered with a "
                    f"different configuration; re-apply the identical "
                    "rule, or use a different name_prefix")
            continue
        dep = DetectionDeployment(
            name=name, package=package, version=version, signal=signal,
            entity=ent.name, detect=detect,
            user_params=dict(user_params or {}), rank=rank)
        out.append(deployments.register(dep))
    return out
