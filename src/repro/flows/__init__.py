"""Flow-typed deployments (ROADMAP item 2).

A *flow* is a traffic class over the same semantic graph, store and
scheduler. Two kinds exist today:

* **forecast** — the original hourly train/score flow: every plain
  ``ModelDeployment`` (``flow="forecast"``) behaves exactly as before.
* **detection** — a minutely, read-mostly flow (``DetectionDeployment``)
  that compares live readings against the q10/q90 prediction band of the
  forecast flow's output and writes anomaly scores back as a derived
  signal registered through the ``SemanticGraph``.

Flows share the executors (detection bins are fleet-vectorized like
score bins), the serverless path (DetectionRecords ride the invocation
payload protocol with the same exactly-once guarantees), and the
idempotent persistence layer.
"""
from .detection import (DetectionDeployment, DetectionRecord,
                        DetectionStore, deploy_detections_for_all)

__all__ = [
    "DetectionDeployment",
    "DetectionRecord",
    "DetectionStore",
    "deploy_detections_for_all",
]
