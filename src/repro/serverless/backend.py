"""Invocation backends: where a serverless action physically runs.

``InvocationBackend`` is the protocol the invoker drives; two
implementations ship:

* ``InlineBackend`` — deterministic, in-process: each worker slot is a
  warm ``Worker`` over the SHARED system (persistence happens directly
  through the executor, artifacts never cross a wire). This is the
  test/reference path and the one the Table-3 sweep uses at tens of
  thousands of tasks — invocation machinery without OS-process cost.
* ``ProcessBackend`` — real OS containers: spawned worker processes, each
  building its own system replica from a picklable factory at cold start
  (spawn, not fork — a forked child of a jax-initialized parent inherits
  dead XLA threads). Payloads/results cross as JSON strings, proving the
  stateless-payload contract; artifacts (trained versions, forecasts)
  ship back for the invoker to persist idempotently.

Both serialize invocations PER WORKER (a warm container runs one action
at a time); cross-worker parallelism is the invoker's in-flight bound.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

from .payload import InvocationPayload, InvocationResult
from .worker import Worker, _process_worker_main


class InvocationError(RuntimeError):
    """An invocation failed at the backend level (worker died, transport
    error) — the whole action is retriable on another worker."""


class InvocationBackend:
    """Protocol: ``invoke`` blocks until the action completes on the given
    worker (the invoker provides cross-invocation concurrency)."""

    #: worker artifacts must ship back for the invoker to persist (False
    #: when workers write straight into the shared stores)
    wants_artifacts: bool = False

    def worker_ids(self) -> List[str]:
        raise NotImplementedError

    def invoke(self, payload: InvocationPayload,
               worker_id: str) -> InvocationResult:
        raise NotImplementedError

    def close(self) -> None:
        pass


class InlineBackend(InvocationBackend):
    wants_artifacts = False

    def __init__(self, system, *, n_workers: int = 4):
        self.system = system
        self.n_workers = max(1, int(n_workers))
        self._ids = [f"w{i}" for i in range(self.n_workers)]
        self._workers: Dict[str, Worker] = {}
        self._locks = {w: threading.Lock() for w in self._ids}
        self._guard = threading.Lock()

    def worker_ids(self) -> List[str]:
        return list(self._ids)

    def _worker(self, worker_id: str) -> Worker:
        with self._guard:
            w = self._workers.get(worker_id)
            if w is None:                      # cold start: build the slot
                w = self._workers[worker_id] = Worker(
                    worker_id, self.system, collect_artifacts=False)
            return w

    def invoke(self, payload: InvocationPayload,
               worker_id: str) -> InvocationResult:
        w = self._worker(worker_id)
        with self._locks[worker_id]:           # one action at a time
            return w.execute(payload)


class ProcessBackend(InvocationBackend):
    wants_artifacts = True

    def __init__(self, system_factory: Callable[[], object], *,
                 n_workers: int = 2, env: Optional[Dict[str, str]] = None,
                 invoke_timeout_s: float = 600.0,
                 spawn_timeout_s: float = 300.0):
        self.system_factory = system_factory
        self.n_workers = max(1, int(n_workers))
        self.env = dict(env or {})
        self.invoke_timeout_s = invoke_timeout_s
        self.spawn_timeout_s = spawn_timeout_s
        self._ids = [f"p{i}" for i in range(self.n_workers)]
        self._procs: Dict[str, tuple] = {}     # id -> (proc, task_q, result_q)
        self._locks = {w: threading.Lock() for w in self._ids}
        self._guard = threading.Lock()

    def worker_ids(self) -> List[str]:
        return list(self._ids)

    def _spawn(self, worker_id: str) -> tuple:
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        task_q: "mp.Queue" = ctx.Queue()
        result_q: "mp.Queue" = ctx.Queue()
        proc = ctx.Process(
            target=_process_worker_main,
            args=(task_q, result_q, self.system_factory, worker_id,
                  self.env),
            daemon=True, name=f"serverless-{worker_id}")
        proc.start()
        import queue as _q
        deadline = time.time() + self.spawn_timeout_s
        while True:
            try:
                tag, info = result_q.get(timeout=1.0)
                break
            except _q.Empty:
                # a child that dies during interpreter bootstrap (before
                # our handshake code runs) never posts anything: detect
                # the corpse instead of burning the whole spawn timeout
                if not proc.is_alive():
                    raise InvocationError(
                        f"{worker_id}: worker process died during cold "
                        f"start (exit {proc.exitcode})")
                if time.time() > deadline:
                    proc.kill()
                    raise InvocationError(
                        f"{worker_id}: cold start timed out")
        if tag != "ready":
            raise InvocationError(f"{worker_id}: cold start failed: {info}")
        return proc, task_q, result_q

    def _worker(self, worker_id: str) -> tuple:
        with self._guard:
            entry = self._procs.get(worker_id)
            if entry is None or not entry[0].is_alive():
                entry = self._procs[worker_id] = self._spawn(worker_id)
            return entry

    def invoke(self, payload: InvocationPayload,
               worker_id: str) -> InvocationResult:
        import queue as _q
        proc, task_q, result_q = self._worker(worker_id)
        with self._locks[worker_id]:
            task_q.put(payload.to_json())
            deadline = time.time() + self.invoke_timeout_s
            while True:
                try:
                    tag, iid, body = result_q.get(timeout=min(
                        1.0, max(0.05, deadline - time.time())))
                except _q.Empty:
                    if not proc.is_alive():
                        with self._guard:
                            self._procs.pop(worker_id, None)
                        raise InvocationError(
                            f"{worker_id} died mid-invocation "
                            f"(exit {proc.exitcode})")
                    if time.time() > deadline:
                        raise InvocationError(
                            f"{worker_id}: invocation timed out")
                    continue
                # a predecessor that timed out here may deliver late:
                # drop stale messages (result OR error) until OUR
                # invocation's answer arrives — the stale one's effects
                # are idempotent, and its error must not be attributed to
                # (and burn the retry budget of) the current invocation.
                # An empty id means the worker could not even parse the
                # payload; that can only be the head-of-line message, i.e.
                # ours, since the queue is FIFO per worker.
                if iid and iid != payload.invocation_id:
                    continue
                if tag != "result":
                    raise InvocationError(f"{worker_id}: {body}")
                return InvocationResult.from_json(body)

    def close(self) -> None:
        with self._guard:
            procs, self._procs = dict(self._procs), {}
        for _, (proc, task_q, _rq) in procs.items():
            try:
                task_q.put(None)
            except Exception:  # noqa: BLE001
                pass
        for _, (proc, _tq, _rq) in procs.items():
            proc.join(timeout=5.0)
            if proc.is_alive():
                proc.kill()
