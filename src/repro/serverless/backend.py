"""Invocation backends: where a serverless action physically runs.

``InvocationBackend`` is the protocol the invoker drives; two
implementations ship:

* ``InlineBackend`` — deterministic, in-process: each worker slot is a
  warm ``Worker`` over the SHARED system (persistence happens directly
  through the executor, artifacts never cross a wire). This is the
  test/reference path and the one the Table-3 sweep uses at tens of
  thousands of tasks — invocation machinery without OS-process cost. It
  is also where ``ChaosPolicy`` faults inject (deterministic in-process
  reproduction of kill/drop/duplicate/delay), and it can optionally
  round-trip payloads/results through a ``StorageBackend`` to prove the
  store-mediated path without process cost.
* ``ProcessBackend`` — real OS containers: spawned worker processes, each
  building its own system replica from a picklable factory at cold start
  (spawn, not fork — a forked child of a jax-initialized parent inherits
  dead XLA threads). By default payloads/results travel through a shared
  ``FilesystemStorage`` bucket and the mp queues carry only object KEYS
  (the Lithops storage-mediated path — an aggregation-128 action no
  longer serializes through one JSON pipe); ``storage_dir=None`` falls
  back to raw JSON strings over the wire. Artifacts (trained versions,
  forecasts) ship back for the invoker to persist idempotently.

Both backends are ELASTIC: ``add_worker``/``remove_worker`` grow and reap
the warm pool at runtime (worker ids are never reused), which is what the
autoscaler drives. Both serialize invocations PER WORKER (a warm
container runs one action at a time); cross-worker parallelism is the
invoker's in-flight bound.

``ProcessBackend`` reaps its spawned workers via a ``weakref.finalize``
teardown (GC of a leaked backend — e.g. a test that failed mid-run — and
interpreter exit both kill the children), plus context-manager support
for explicit scoping.
"""
from __future__ import annotations

import threading
import time
import weakref
from typing import Callable, Dict, List, Optional

from .chaos import ChaosPolicy
from .payload import InvocationPayload, InvocationResult
from .storage import (FilesystemStorage, StorageBackend, get_payload,
                      get_result, put_payload, put_result)
from .worker import Worker, _process_worker_main


class InvocationError(RuntimeError):
    """An invocation failed at the backend level (worker died, transport
    error) — the whole action is retriable on another worker."""


class InvocationBackend:
    """Protocol: ``invoke`` blocks until the action completes on the given
    worker (the invoker provides cross-invocation concurrency)."""

    #: worker artifacts must ship back for the invoker to persist (False
    #: when workers write straight into the shared stores)
    wants_artifacts: bool = False

    def worker_ids(self) -> List[str]:
        raise NotImplementedError

    def invoke(self, payload: InvocationPayload,
               worker_id: str) -> InvocationResult:
        raise NotImplementedError

    # ------------------------------------------------------- elasticity
    def add_worker(self) -> str:
        """Provision one more warm-container slot; returns its id (never
        a reused one)."""
        raise NotImplementedError

    def remove_worker(self, worker_id: str) -> bool:
        """Reap a container (discarding its warmth). Returns False when
        the worker is unknown or currently executing an action."""
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class InlineBackend(InvocationBackend):
    wants_artifacts = False

    def __init__(self, system, *, n_workers: int = 4,
                 storage: Optional[StorageBackend] = None,
                 chaos: Optional[ChaosPolicy] = None):
        self.system = system
        self.n_workers = max(1, int(n_workers))
        self.storage = storage
        self.chaos = chaos
        self._ids = [f"w{i}" for i in range(self.n_workers)]
        self._next_id = self.n_workers
        self._workers: Dict[str, Worker] = {}
        self._locks = {w: threading.Lock() for w in self._ids}
        self._guard = threading.Lock()

    def worker_ids(self) -> List[str]:
        with self._guard:
            return list(self._ids)

    def add_worker(self) -> str:
        with self._guard:
            w = f"w{self._next_id}"
            self._next_id += 1
            self._ids.append(w)
            self._locks[w] = threading.Lock()
            return w

    def remove_worker(self, worker_id: str) -> bool:
        with self._guard:
            lock = self._locks.get(worker_id)
            if lock is None:
                return False
            if not lock.acquire(blocking=False):
                return False               # mid-action: not reapable now
            try:
                self._ids.remove(worker_id)
                del self._locks[worker_id]
                self._workers.pop(worker_id, None)
            finally:
                lock.release()
            return True

    def _worker(self, worker_id: str) -> Worker:
        with self._guard:
            if worker_id not in self._locks:
                raise InvocationError(f"{worker_id} is not a live worker")
            w = self._workers.get(worker_id)
            if w is None:                      # cold start: build the slot
                w = self._workers[worker_id] = Worker(
                    worker_id, self.system, collect_artifacts=False)
            return w, self._locks[worker_id]

    def invoke(self, payload: InvocationPayload,
               worker_id: str) -> InvocationResult:
        if self.storage is not None:
            # store-mediated path: the "wire" carries only the key; what
            # the worker executes is what came back OUT of the store
            key = put_payload(self.storage, payload)
            payload = get_payload(self.storage, key)
        w, lock = self._worker(worker_id)
        chaos = self.chaos
        duplicate = chaos is not None and chaos.should_duplicate(payload)
        with lock:                             # one action at a time
            if duplicate:
                # at-least-once delivery: the first copy executes with
                # full effects; the SECOND copy's result is what returns
                w.execute(payload)
            result = w.execute(payload, chaos=chaos)
        if self.storage is not None:
            rkey = put_result(self.storage, result, payload.attempt)
            result = get_result(self.storage, rkey)
        if chaos is not None and chaos.should_drop(payload):
            # the action ran — its effects are persisted — but the result
            # never makes it back: the canonical at-least-once retry case
            raise InvocationError(
                f"chaos: result of {payload.invocation_id} dropped")
        return result


def _reap_processes(procs: Dict[str, tuple]) -> None:
    """Best-effort teardown shared by ``close()``, GC finalization and
    interpreter exit: without it, a crashed invoker (a test failing
    mid-run) leaked its spawned workers for the rest of the session."""
    items = list(procs.items())
    procs.clear()
    for _, (proc, task_q, _rq) in items:
        try:
            task_q.put_nowait(None)
        except Exception:  # noqa: BLE001
            pass
    for _, (proc, _tq, _rq) in items:
        try:
            proc.join(timeout=5.0)
            if proc.is_alive():
                proc.kill()
        except Exception:  # noqa: BLE001
            pass


class ProcessBackend(InvocationBackend):
    wants_artifacts = True

    def __init__(self, system_factory: Callable[[], object], *,
                 n_workers: int = 2, env: Optional[Dict[str, str]] = None,
                 invoke_timeout_s: float = 600.0,
                 spawn_timeout_s: float = 300.0,
                 storage_dir: Optional[str] = "auto"):
        self.system_factory = system_factory
        self.n_workers = max(1, int(n_workers))
        self.env = dict(env or {})
        self.invoke_timeout_s = invoke_timeout_s
        self.spawn_timeout_s = spawn_timeout_s
        # "auto": a fresh owned tempdir bucket; a path: a shared bucket;
        # None: legacy raw-JSON-over-the-pipe transport
        if storage_dir == "auto":
            self.storage: Optional[FilesystemStorage] = FilesystemStorage()
        elif storage_dir is not None:
            self.storage = FilesystemStorage(storage_dir)
        else:
            self.storage = None
        self._ids = [f"p{i}" for i in range(self.n_workers)]
        self._next_id = self.n_workers
        self._procs: Dict[str, tuple] = {}     # id -> (proc, task_q, result_q)
        self._locks = {w: threading.Lock() for w in self._ids}
        self._guard = threading.Lock()
        # reap spawned children when this backend is GC'd (crashed
        # invoker, failed test) or the interpreter exits — the finalizer
        # must not hold a reference to self, only to the procs dict
        self._finalizer = weakref.finalize(self, _reap_processes,
                                           self._procs)

    def worker_ids(self) -> List[str]:
        with self._guard:
            return list(self._ids)

    def add_worker(self) -> str:
        with self._guard:
            w = f"p{self._next_id}"
            self._next_id += 1
            self._ids.append(w)
            self._locks[w] = threading.Lock()
            return w                           # process spawns lazily

    def remove_worker(self, worker_id: str) -> bool:
        with self._guard:
            lock = self._locks.get(worker_id)
            if lock is None:
                return False
            if not lock.acquire(blocking=False):
                return False
            try:
                self._ids.remove(worker_id)
                del self._locks[worker_id]
                entry = self._procs.pop(worker_id, None)
            finally:
                lock.release()
        if entry is not None:
            _reap_processes({worker_id: entry})
        return True

    def _spawn(self, worker_id: str) -> tuple:
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        task_q: "mp.Queue" = ctx.Queue()
        result_q: "mp.Queue" = ctx.Queue()
        proc = ctx.Process(
            target=_process_worker_main,
            args=(task_q, result_q, self.system_factory, worker_id,
                  self.env,
                  self.storage.root if self.storage is not None else None),
            daemon=True, name=f"serverless-{worker_id}")
        proc.start()
        import queue as _q
        deadline = time.time() + self.spawn_timeout_s
        while True:
            try:
                tag, info = result_q.get(timeout=1.0)
                break
            except _q.Empty:
                # a child that dies during interpreter bootstrap (before
                # our handshake code runs) never posts anything: detect
                # the corpse instead of burning the whole spawn timeout
                if not proc.is_alive():
                    raise InvocationError(
                        f"{worker_id}: worker process died during cold "
                        f"start (exit {proc.exitcode})")
                if time.time() > deadline:
                    proc.kill()
                    raise InvocationError(
                        f"{worker_id}: cold start timed out")
        if tag != "ready":
            raise InvocationError(f"{worker_id}: cold start failed: {info}")
        return proc, task_q, result_q

    def _worker(self, worker_id: str) -> tuple:
        with self._guard:
            if worker_id not in self._locks:
                raise InvocationError(f"{worker_id} is not a live worker")
            entry = self._procs.get(worker_id)
            if entry is None or not entry[0].is_alive():
                entry = self._procs[worker_id] = self._spawn(worker_id)
            return entry, self._locks[worker_id]

    def invoke(self, payload: InvocationPayload,
               worker_id: str) -> InvocationResult:
        import queue as _q
        (proc, task_q, result_q), lock = self._worker(worker_id)
        with lock:
            if self.storage is not None:
                # storage-mediated: bytes go through the shared bucket,
                # the pipe carries a ~100-byte key reference
                key = put_payload(self.storage, payload)
                task_q.put(("ref", key))
            else:
                task_q.put(payload.to_json())
            deadline = time.time() + self.invoke_timeout_s
            while True:
                try:
                    tag, iid, body = result_q.get(timeout=min(
                        1.0, max(0.05, deadline - time.time())))
                except _q.Empty:
                    if not proc.is_alive():
                        with self._guard:
                            self._procs.pop(worker_id, None)
                        raise InvocationError(
                            f"{worker_id} died mid-invocation "
                            f"(exit {proc.exitcode})")
                    if time.time() > deadline:
                        raise InvocationError(
                            f"{worker_id}: invocation timed out")
                    continue
                # a predecessor that timed out here may deliver late:
                # drop stale messages (result OR error) until OUR
                # invocation's answer arrives — the stale one's effects
                # are idempotent, and its error must not be attributed to
                # (and burn the retry budget of) the current invocation.
                # An empty id means the worker could not even parse the
                # payload; that can only be the head-of-line message, i.e.
                # ours, since the queue is FIFO per worker.
                if iid and iid != payload.invocation_id:
                    continue
                if tag == "result-ref":
                    return get_result(self.storage, body)
                if tag != "result":
                    raise InvocationError(f"{worker_id}: {body}")
                return InvocationResult.from_json(body)

    def close(self) -> None:
        with self._guard:
            procs = dict(self._procs)
            self._procs.clear()
        _reap_processes(procs)
        if self.storage is not None:
            self.storage.close()
        self._finalizer.detach()
