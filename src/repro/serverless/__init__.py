"""Serverless invocation subsystem (paper §2 step 8 + Table 3).

The paper executes tens of thousands of modelling tasks per cycle by
fanning them out as serverless actions. This package reproduces that
pipeline — stateless payloads, an aggregating invoker with bounded
in-flight concurrency/retries/straggler backups, warm-container-sticky
workers, and invocation telemetry — behind the same ``run(jobs)``
executor protocol as ``LocalPoolExecutor``/``FleetExecutor``:

* ``payload``   — serializable invocation payloads (refs, never live objects)
* ``storage``   — the object store mediating payloads/results (in-memory
  + filesystem backends; the Lithops storage path)
* ``futures``   — ``ResponseFuture`` + ``wait(ANY|ALL|ALWAYS)`` streaming
* ``invoker``   — ``ServerlessInvoker`` + the ``ServerlessExecutor`` facade
* ``worker``    — the warm container: payload -> private FleetExecutor
* ``backend``   — ``InlineBackend`` (deterministic, in-process) and
  ``ProcessBackend`` (spawned OS workers, storage-mediated wire)
* ``monitor``   — cold/warm starts, queue + execution latency
* ``autoscale`` — telemetry-driven elastic pool (scale out / reap idle)
* ``chaos``     — deterministic fault injection (kill/drop/duplicate/delay)

Use ``Castor.tick(now, executor="serverless")`` or construct
``ServerlessExecutor`` directly for custom backends.
"""
from .autoscale import AutoscalePolicy, Autoscaler
from .backend import (InlineBackend, InvocationBackend, InvocationError,
                      ProcessBackend)
from .chaos import ChaosKill, ChaosPolicy
from .futures import (ALL_COMPLETED, ALWAYS, ANY_COMPLETED, CancelledError,
                      FuturesTimeoutError, ResponseFuture, wait)
from .invoker import ServerlessExecutor, ServerlessInvoker
from .monitor import InvocationMonitor
from .payload import InvocationPayload, InvocationResult, JobRef
from .storage import (FilesystemStorage, InMemoryStorage, StorageBackend,
                      StorageKeyError)

__all__ = ["InlineBackend", "InvocationBackend", "InvocationError",
           "ProcessBackend", "ServerlessExecutor", "ServerlessInvoker",
           "InvocationMonitor", "InvocationPayload", "InvocationResult",
           "JobRef", "StorageBackend", "InMemoryStorage",
           "FilesystemStorage", "StorageKeyError", "ResponseFuture",
           "wait", "ANY_COMPLETED", "ALL_COMPLETED", "ALWAYS",
           "FuturesTimeoutError", "CancelledError", "ChaosPolicy",
           "ChaosKill", "AutoscalePolicy", "Autoscaler"]
