"""Serverless invocation subsystem (paper §2 step 8 + Table 3).

The paper executes tens of thousands of modelling tasks per cycle by
fanning them out as serverless actions. This package reproduces that
pipeline — stateless payloads, an aggregating invoker with bounded
in-flight concurrency/retries/straggler backups, warm-container-sticky
workers, and invocation telemetry — behind the same ``run(jobs)``
executor protocol as ``LocalPoolExecutor``/``FleetExecutor``:

* ``payload``  — serializable invocation payloads (refs, never live objects)
* ``invoker``  — ``ServerlessInvoker`` + the ``ServerlessExecutor`` facade
* ``worker``   — the warm container: payload -> private FleetExecutor
* ``backend``  — ``InlineBackend`` (deterministic, in-process) and
  ``ProcessBackend`` (spawned OS workers, JSON wire)
* ``monitor``  — cold/warm starts, queue + execution latency

Use ``Castor.tick(now, executor="serverless")`` or construct
``ServerlessExecutor`` directly for custom backends.
"""
from .backend import InlineBackend, InvocationBackend, ProcessBackend
from .invoker import ServerlessExecutor, ServerlessInvoker
from .monitor import InvocationMonitor
from .payload import InvocationPayload, InvocationResult, JobRef

__all__ = ["InlineBackend", "InvocationBackend", "ProcessBackend",
           "ServerlessExecutor", "ServerlessInvoker", "InvocationMonitor",
           "InvocationPayload", "InvocationResult", "JobRef"]
