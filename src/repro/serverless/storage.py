"""Object-store-mediated payload/result transport (the Lithops
``storage/backends`` role, adapted).

Real serverless frameworks do not push megabyte payloads through the
invocation API: the invoker *puts* the job payload into an object store,
the worker *gets* it by key, and results travel the same way — the
invocation channel carries only small references. This module provides
that mediation layer so an aggregation-128 action (and its worker-shipped
forecasts) no longer serializes through one JSON pipe per action:

* ``StorageBackend`` — the ``put/get/list/delete`` protocol, bytes-valued.
* ``InMemoryStorage`` — dict-backed; deterministic, the inline/test path.
* ``FilesystemStorage`` — files under a root directory with atomic
  (write-temp-then-rename) puts, so a reader in ANOTHER PROCESS can never
  observe a partially written object. This is what ``ProcessBackend``
  uses by default: the mp queue carries only keys, payload/result bytes
  go through the shared filesystem "bucket".

Key layout mirrors Lithops' ``lithops.jobs/<job>/...`` convention, with
the attempt number in the key so duplicate deliveries and stale retries
write distinct objects instead of racing on one:

    jobs/<invocation_id>/a<attempt>.json      (payload)
    results/<invocation_id>/a<attempt>.json   (result)

Everything stored is the bitwise JSON encoding from ``payload.py`` —
round-tripping through a storage backend is covered by property tests in
``tests/test_serverless_chaos.py``.
"""
from __future__ import annotations

import os
import re
import shutil
import tempfile
import threading
from typing import Dict, List, Optional

from .payload import InvocationPayload, InvocationResult

_KEY_RE = re.compile(r"^[A-Za-z0-9._\-/]+$")


class StorageKeyError(KeyError):
    """Requested object does not exist in the storage backend."""


class StorageBackend:
    """Bytes-valued object store protocol. Implementations must be safe
    for concurrent use from multiple threads (and, for the filesystem
    backend, multiple processes)."""

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def list(self, prefix: str = "") -> List[str]:
        """All keys under ``prefix``, sorted (deterministic)."""
        raise NotImplementedError

    def delete(self, key: str) -> bool:
        raise NotImplementedError

    def clear(self) -> None:
        for k in self.list():
            self.delete(k)

    def stats(self) -> Dict[str, int]:
        raise NotImplementedError

    def close(self) -> None:
        pass


def _check_key(key: str) -> str:
    if not key or not _KEY_RE.match(key) or ".." in key.split("/"):
        raise ValueError(f"invalid storage key {key!r}")
    return key


class _Counters:
    """Thread-safe put/get byte counters shared by both backends."""

    def __init__(self):
        self._lock = threading.Lock()
        self.puts = 0
        self.gets = 0
        self.deletes = 0
        self.bytes_in = 0
        self.bytes_out = 0

    def on_put(self, n: int) -> None:
        with self._lock:
            self.puts += 1
            self.bytes_in += n

    def on_get(self, n: int) -> None:
        with self._lock:
            self.gets += 1
            self.bytes_out += n

    def on_delete(self) -> None:
        with self._lock:
            self.deletes += 1

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {"puts": self.puts, "gets": self.gets,
                    "deletes": self.deletes, "bytes_in": self.bytes_in,
                    "bytes_out": self.bytes_out}


class InMemoryStorage(StorageBackend):
    """Deterministic in-process object store (the inline/test path)."""

    def __init__(self):
        self._objects: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self._counters = _Counters()

    def put(self, key: str, data: bytes) -> None:
        _check_key(key)
        data = bytes(data)
        with self._lock:
            self._objects[key] = data
        self._counters.on_put(len(data))

    def get(self, key: str) -> bytes:
        with self._lock:
            data = self._objects.get(key)
        if data is None:
            raise StorageKeyError(key)
        self._counters.on_get(len(data))
        return data

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    def delete(self, key: str) -> bool:
        with self._lock:
            hit = self._objects.pop(key, None) is not None
        if hit:
            self._counters.on_delete()
        return hit

    def stats(self) -> Dict[str, int]:
        out = self._counters.snapshot()
        with self._lock:
            out["objects"] = len(self._objects)
        return out


class FilesystemStorage(StorageBackend):
    """Object store over a directory tree — the cross-process backend.

    Puts are atomic (temp file in the same directory, then ``os.replace``)
    so a concurrent reader in another process either misses the key or
    sees the complete object, never a torn one. ``owned`` roots (the
    default when ``root`` is omitted: a fresh tempdir) are deleted on
    ``close()``.

    ``fsync=True`` (what ``Castor.open`` uses for its WAL) additionally
    fsyncs the temp file before the rename and the directory after it,
    so a completed ``put`` survives power loss, not just process death.
    """

    def __init__(self, root: Optional[str] = None, *, fsync: bool = False):
        self._owned = root is None
        self.root = root or tempfile.mkdtemp(prefix="repro-objstore-")
        self.fsync = fsync
        os.makedirs(self.root, exist_ok=True)
        self._counters = _Counters()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, _check_key(key))

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())
            os.replace(tmp, path)          # atomic publish
            if self.fsync:                 # persist the rename itself
                dfd = os.open(os.path.dirname(path), os.O_RDONLY)
                try:
                    os.fsync(dfd)
                finally:
                    os.close(dfd)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._counters.on_put(len(data))

    def get(self, key: str) -> bytes:
        try:
            with open(self._path(key), "rb") as f:
                data = f.read()
        except FileNotFoundError:
            raise StorageKeyError(key) from None
        self._counters.on_get(len(data))
        return data

    def list(self, prefix: str = "") -> List[str]:
        out = []
        for dirpath, dirs, files in os.walk(self.root):
            # os.walk surfaces entries in os.listdir order, which is
            # filesystem-dependent; sort the traversal itself so the
            # result is deterministic on every platform even before the
            # final sort (and any future early-exit iteration stays so)
            dirs.sort()
            for name in sorted(files):
                if name.startswith(".tmp-"):
                    continue               # in-flight atomic put
                key = os.path.relpath(os.path.join(dirpath, name),
                                      self.root).replace(os.sep, "/")
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key: str) -> bool:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            return False
        self._counters.on_delete()
        return True

    def stats(self) -> Dict[str, int]:
        out = self._counters.snapshot()
        out["objects"] = len(self.list())
        return out

    def close(self) -> None:
        if self._owned:
            shutil.rmtree(self.root, ignore_errors=True)


# ------------------------------------------------------- payload helpers
#
# One key scheme shared by every backend, attempt-qualified so duplicate
# deliveries / stale retries never collide on an object.


def payload_key(invocation_id: str, attempt: int) -> str:
    return f"jobs/{invocation_id}/a{int(attempt):03d}.json"


def result_key(invocation_id: str, attempt: int) -> str:
    return f"results/{invocation_id}/a{int(attempt):03d}.json"


def put_payload(storage: StorageBackend, payload: InvocationPayload) -> str:
    key = payload_key(payload.invocation_id, payload.attempt)
    storage.put(key, payload.to_json().encode("utf-8"))
    return key


def get_payload(storage: StorageBackend, key: str) -> InvocationPayload:
    return InvocationPayload.from_json(storage.get(key).decode("utf-8"))


def put_result(storage: StorageBackend, result: InvocationResult,
               attempt: int) -> str:
    key = result_key(result.invocation_id, attempt)
    storage.put(key, result.to_json().encode("utf-8"))
    return key


def get_result(storage: StorageBackend, key: str) -> InvocationResult:
    return InvocationResult.from_json(storage.get(key).decode("utf-8"))
