"""Stateless-payload workers (the Lithops worker/handler split adapted).

A worker owns NOTHING the payload doesn't reference: it reconstructs its
slice of work from the stores alone (``JobRef.to_job`` + the system's
deployment/registry/series stores) and executes it through a private
``FleetExecutor``. What it DOES keep between invocations is warmth — its
``FleetRuntime`` (device rings, compile caches, train->score param
handoff) persists for the worker's lifetime, which is why the invoker's
sticky routing pays: the second invocation of a bin on the same worker is
an O(delta) warm poll, on a different worker a cold rebuild.

``Worker.execute`` is shared by both backends and is where execution-side
chaos injects: an injected *delay* stalls before execution (straggler),
an injected *kill* executes a strict prefix of the action's bins — their
effects persist — and then raises ``ChaosKill``, modelling a container
preempted mid-action. ``_process_worker_main`` is the long-lived loop a
spawned container runs; with a storage root it resolves payload KEYS
against the shared ``FilesystemStorage`` bucket and ships results back
the same way (JSON over the pipe otherwise).
"""
from __future__ import annotations

import os
import time
from dataclasses import replace
from typing import Any, Dict, List, Optional

import numpy as np

from ..obs.trace import get_tracer
from .chaos import ChaosKill, ChaosPolicy
from .payload import (DetectionBlob, ForecastBlob, InvocationPayload,
                      InvocationResult, JobOutcome, JobRef, VersionRef)


class Worker:
    """One warm container: a private ``FleetExecutor`` (own FleetRuntime,
    own fallback pool) over a system handle. For the inline backend the
    system IS the invoker's; for the process backend it is the worker's
    own replica built from a factory at cold start."""

    def __init__(self, worker_id: str, system, *, collect_artifacts: bool,
                 max_parallel: int = 8):
        from ..core.executor import FleetExecutor, LocalPoolExecutor
        self.worker_id = worker_id
        self.system = system
        self.collect_artifacts = collect_artifacts
        self.executor = FleetExecutor(
            system, fallback=LocalPoolExecutor(system,
                                               max_parallel=max_parallel))
        self.invocations = 0

    def execute(self, payload: InvocationPayload,
                chaos: Optional[ChaosPolicy] = None) -> InvocationResult:
        # stitch this worker's spans under the invoker's trace: the
        # payload carries the invoker's (trace_id, invoke-span id); for
        # the inline backend the spans land directly in the shared
        # tracer, for the process backend they ship back on the result
        tracer = get_tracer()
        with tracer.adopt(payload.trace):
            with tracer.span("worker.execute",
                             invocation_id=payload.invocation_id,
                             worker=self.worker_id,
                             jobs=payload.n_jobs):
                return self._execute(payload, chaos)

    def _execute(self, payload: InvocationPayload,
                 chaos: Optional[ChaosPolicy] = None) -> InvocationResult:
        started = time.time()
        cold = self.invocations == 0
        self.invocations += 1
        # "download" the artifacts a scoring action needs: idempotent on
        # (model_id, trained_at), so re-delivery (retries, sticky re-use
        # after a local train of the same occurrence) is a no-op
        for vr in payload.versions:
            self.system.versions.save(vr.deployment_name, vr.model_object,
                                      trained_at=vr.trained_at,
                                      metadata={"delivered": True})
        # likewise the banded forecasts a detect action compares against:
        # idempotent on (deployment, created_at), so a replica that scored
        # the band itself (or a re-delivery) no-ops
        if payload.bands:
            from ..core.lineage import Forecast
            self.system.predictions.save_many([
                Forecast(deployment_name=fb.deployment_name,
                         signal=fb.signal, entity=fb.entity,
                         created_at=fb.created_at,
                         times=np.asarray(fb.times),
                         values=np.asarray(fb.values),
                         model_version=fb.model_version, rank=fb.rank,
                         lower=(None if fb.lower is None
                                else np.asarray(fb.lower)),
                         upper=(None if fb.upper is None
                                else np.asarray(fb.upper)))
                for fb in payload.bands])
        jobs = [r.to_job() for r in payload.jobs]
        if chaos is not None:
            chaos.maybe_delay(payload)
            kill_after = chaos.kill_point(payload)
            if kill_after is not None:
                # execute a strict PREFIX of the action's bins, persist
                # their effects, then die: the retry re-runs the whole
                # action and the persisted prefix must no-op at the
                # idempotent stores (the exactly-once invariant's
                # hardest case)
                groups: Dict[tuple, List] = {}
                for j in jobs:
                    groups.setdefault(j.bin_key, []).append(j)
                for bin_jobs_ in list(groups.values())[:kill_after]:
                    self.executor.run(bin_jobs_)
                raise ChaosKill(
                    f"chaos: {self.worker_id} killed after "
                    f"{kill_after}/{len(groups)} bins of "
                    f"{payload.invocation_id}")
        results = self.executor.run(jobs)
        outcomes = tuple(
            JobOutcome(ref=JobRef.from_job(r.job), ok=r.ok,
                       duration_s=r.duration_s, error=r.error,
                       attempts=r.attempts)
            for r in results)
        versions: List[VersionRef] = []
        forecasts: List[ForecastBlob] = []
        detections: List[DetectionBlob] = []
        if self.collect_artifacts:
            for r in results:
                if not r.ok:
                    continue
                if r.job.task == "train":
                    mv = self.system.versions.get(r.job.deployment_name,
                                                  at=r.job.scheduled_at)
                    versions.append(VersionRef(
                        deployment_name=r.job.deployment_name,
                        version=mv.version, trained_at=mv.trained_at,
                        model_object=mv.params))
                elif r.job.task == "detect":
                    for dr in reversed(self.system.detections.history(
                            r.job.deployment_name)):
                        if dr.scheduled_at == r.job.scheduled_at:
                            detections.append(DetectionBlob(
                                deployment_name=dr.deployment_name,
                                signal=dr.signal, entity=dr.entity,
                                scheduled_at=dr.scheduled_at,
                                score=dr.score, n_readings=dr.n_readings,
                                n_anomalies=dr.n_anomalies,
                                band_misses=dr.band_misses,
                                model_version=dr.model_version,
                                derived_signal=dr.derived_signal))
                            break
                else:
                    # newest-first: the forecast for this occurrence was
                    # just appended at the tail, so a long-lived warm
                    # worker's ship-back stays O(1) per job instead of
                    # rescanning its whole replica history every poll
                    for fc in reversed(self.system.predictions.history(
                            r.job.deployment_name)):
                        if fc.created_at == r.job.scheduled_at:
                            forecasts.append(ForecastBlob(
                                deployment_name=fc.deployment_name,
                                signal=fc.signal, entity=fc.entity,
                                created_at=fc.created_at, times=fc.times,
                                values=fc.values,
                                model_version=fc.model_version,
                                rank=fc.rank, lower=fc.lower,
                                upper=fc.upper))
                            break
        return InvocationResult(
            invocation_id=payload.invocation_id, worker_id=self.worker_id,
            cold_start=cold, started_at=started, finished_at=time.time(),
            outcomes=outcomes, versions=tuple(versions),
            forecasts=tuple(forecasts), detections=tuple(detections))


def _process_worker_main(task_q, result_q, factory, worker_id: str,
                         env: Optional[Dict[str, str]] = None,
                         storage_root: Optional[str] = None) -> None:
    """Entry point of a spawned worker container. ``factory`` is a
    picklable zero-arg callable reconstructing the worker's system replica
    (its 'connection to shared storage'): spawned processes share no
    memory, so determinism of the factory is what stands in for a real
    shared backend. ``storage_root`` names the shared filesystem bucket
    for storage-mediated transport (payload keys in, result keys out);
    without it, raw JSON strings cross the pipe. ``None`` is the shutdown
    sentinel either way."""
    for k, v in (env or {}).items():
        os.environ[k] = v
    try:
        from .storage import (FilesystemStorage, get_payload, put_result)
        storage = (FilesystemStorage(storage_root)
                   if storage_root is not None else None)
        system = factory()
        worker = Worker(worker_id, system, collect_artifacts=True)
        result_q.put(("ready", worker_id))
    except BaseException as e:  # noqa: BLE001 — report cold-start failure
        result_q.put(("fatal", f"{type(e).__name__}: {e}"))
        return
    while True:
        msg = task_q.get()
        if msg is None:
            return
        iid = ""
        try:
            if isinstance(msg, tuple) and msg[0] == "ref":
                payload = get_payload(storage, msg[1])
            else:
                payload = InvocationPayload.from_json(msg)
            iid = payload.invocation_id
            # ship the spans this invocation finished back with the
            # result: the invoker's tracer absorbs them (re-iding onto
            # its own counter) so the cross-process trace stitches
            tracer = get_tracer()
            mark = tracer.mark()
            result = worker.execute(payload)
            spans = tracer.export_since(mark)
            if spans:
                result = replace(result, spans=tuple(spans))
            if storage is not None:
                key = put_result(storage, result, payload.attempt)
                result_q.put(("result-ref", iid, key))
            else:
                result_q.put(("result", iid, result.to_json()))
        except BaseException as e:  # noqa: BLE001 — ship the error back,
            # tagged with the invocation it belongs to so the backend can
            # never attribute a stale predecessor's error to a later call
            result_q.put(("error", iid, f"{type(e).__name__}: {e}"))
