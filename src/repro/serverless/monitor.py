"""Invocation telemetry (the Lithops monitor role, in-process).

Every invocation — including retries and speculative backups — lands one
record: which worker ran it, whether the container was cold or warm,
queue latency (enqueue -> worker pickup; on a cold process worker this
includes the container spawn, which is exactly what cold start means),
and execution latency. ``summary()`` aggregates what the Table-3 sweep
and ``Castor.stats()`` surface: cold/warm counts, sticky-routing warm
reuse, aggregation factor actually achieved, latency percentiles.

Per-invocation records live in a bounded ring (``max_records`` deep,
ISSUE 10 satellite 1 — the old unbounded list was a slow leak at
million-invocation scale): once full, each new record evicts the oldest
and bumps ``dropped``. Percentile summaries therefore describe the most
*recent* window, which is also what ``recent_queue_p95`` — the
autoscaler's scale-out signal — wants; the running aggregates
(``invocations``/``cold_starts``/...) remain exact lifetime totals.

Each ``record()`` also lands in the global metrics registry
(``serverless.*`` counters + queue/exec latency histograms), so the
observability plane's Prometheus/JSON exports see invocation telemetry
without touching the ring.
"""
from __future__ import annotations

import threading
from collections import deque
from itertools import islice
from typing import Any, Dict, List

from ..obs.metrics import get_metrics


class InvocationMonitor:
    def __init__(self, max_records: int = 100_000):
        self.max_records = int(max_records)
        self._lock = threading.Lock()
        self.records: deque = deque(maxlen=self.max_records)
        self.dropped = 0                 # records evicted from the ring
        # running aggregates (exact even after the ring wraps)
        self.invocations = 0
        self.cold_starts = 0
        self.warm_starts = 0
        self.retries = 0                 # re-submissions after failure
        self.speculative = 0             # straggler backup copies
        self.jobs = 0
        self.failed_invocations = 0
        # registry mirrors, resolved once (zero lookups per record)
        m = get_metrics()
        self._m_invocations = m.counter("serverless.invocations")
        self._m_cold = m.counter("serverless.cold_starts")
        self._m_warm = m.counter("serverless.warm_starts")
        self._m_retries = m.counter("serverless.retries")
        self._m_speculative = m.counter("serverless.speculative")
        self._m_failed = m.counter("serverless.failed_invocations")
        self._m_jobs = m.counter("serverless.jobs")
        self._m_queue = m.histogram("serverless.queue_s")
        self._m_exec_cold = m.histogram("serverless.exec_s.cold")
        self._m_exec_warm = m.histogram("serverless.exec_s.warm")

    def record(self, *, payload, result=None, worker_id: str,
               error: str = "", retried: bool = False,
               speculative: bool = False) -> None:
        rec = {
            "invocation_id": payload.invocation_id,
            "worker": worker_id,
            "jobs": payload.n_jobs,
            "bins": payload.n_bins,
            "attempt": payload.attempt,
            "speculative": speculative,
        }
        if result is not None:
            rec.update(
                cold=result.cold_start,
                queue_s=max(0.0, result.started_at - payload.created_at),
                exec_s=max(0.0, result.finished_at - result.started_at),
                ok=all(o.ok for o in result.outcomes))
        else:
            rec.update(cold=False, queue_s=0.0, exec_s=0.0, ok=False,
                       error=error)
        with self._lock:
            self.invocations += 1
            self.jobs += payload.n_jobs
            self._m_invocations.inc()
            self._m_jobs.inc(payload.n_jobs)
            if retried:
                self.retries += 1
                self._m_retries.inc()
            if speculative:
                self.speculative += 1
                self._m_speculative.inc()
            if result is None:
                self.failed_invocations += 1
                self._m_failed.inc()
            elif result.cold_start:
                self.cold_starts += 1
                self._m_cold.inc()
                self._m_queue.observe(rec["queue_s"])
                self._m_exec_cold.observe(rec["exec_s"])
            else:
                self.warm_starts += 1
                self._m_warm.inc()
                self._m_queue.observe(rec["queue_s"])
                self._m_exec_warm.observe(rec["exec_s"])
            if len(self.records) == self.max_records:
                self.dropped += 1      # ring full: oldest record evicts
            self.records.append(rec)

    def _tail(self, window: int) -> List[Dict[str, Any]]:
        """Last ``window`` records (lock held by caller)."""
        n = len(self.records)
        if window >= n:
            return list(self.records)
        return list(islice(self.records, n - window, n))

    def recent_queue_p95(self, window: int = 64) -> float:
        """p95 queue latency (enqueue -> worker pickup) over the last
        ``window`` successful invocations — the autoscaler's scale-out
        signal (``repro.serverless.autoscale``)."""
        with self._lock:
            recs = self._tail(window)
        return self._pctl([r["queue_s"] for r in recs if r.get("ok")], 0.95)

    @staticmethod
    def _pctl(xs: List[float], q: float) -> float:
        if not xs:
            return 0.0
        xs = sorted(xs)
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            recs = list(self.records)
            out = {
                "invocations": self.invocations,
                "cold_starts": self.cold_starts,
                "warm_starts": self.warm_starts,
                "retries": self.retries,
                "speculative": self.speculative,
                "failed_invocations": self.failed_invocations,
                "jobs": self.jobs,
                "records_dropped": self.dropped,
            }
        # derived ratios come from the SNAPSHOT, not the live counters —
        # a concurrent record() between here and the with-block above
        # must not produce a torn summary
        out["warm_frac"] = (out["warm_starts"] / out["invocations"]
                            if out["invocations"] else 0.0)
        out["mean_aggregation"] = (out["jobs"] / out["invocations"]
                                   if out["invocations"] else 0.0)
        ok = [r for r in recs if r.get("ok")]
        warm = [r for r in ok if not r["cold"]]
        cold = [r for r in ok if r["cold"]]
        out["queue_s_p50"] = self._pctl([r["queue_s"] for r in ok], 0.5)
        out["queue_s_p95"] = self._pctl([r["queue_s"] for r in ok], 0.95)
        out["exec_s_p50"] = self._pctl([r["exec_s"] for r in ok], 0.5)
        out["cold_exec_s_mean"] = (sum(r["exec_s"] for r in cold) / len(cold)
                                   if cold else 0.0)
        out["warm_exec_s_mean"] = (sum(r["exec_s"] for r in warm) / len(warm)
                                   if warm else 0.0)
        workers: Dict[str, int] = {}
        for r in recs:
            workers[r["worker"]] = workers.get(r["worker"], 0) + 1
        out["per_worker"] = dict(sorted(workers.items()))
        return out
