"""Invocation telemetry (the Lithops monitor role, in-process).

Every invocation — including retries and speculative backups — lands one
record: which worker ran it, whether the container was cold or warm,
queue latency (enqueue -> worker pickup; on a cold process worker this
includes the container spawn, which is exactly what cold start means),
and execution latency. ``summary()`` aggregates what the Table-3 sweep
and ``Castor.stats()`` surface: cold/warm counts, sticky-routing warm
reuse, aggregation factor actually achieved, latency percentiles.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional


class InvocationMonitor:
    def __init__(self, max_records: int = 100_000):
        self.max_records = int(max_records)
        self._lock = threading.Lock()
        self.records: List[Dict[str, Any]] = []
        self.dropped = 0
        # running aggregates (cheap even when records overflow)
        self.invocations = 0
        self.cold_starts = 0
        self.warm_starts = 0
        self.retries = 0                 # re-submissions after failure
        self.speculative = 0             # straggler backup copies
        self.jobs = 0
        self.failed_invocations = 0

    def record(self, *, payload, result=None, worker_id: str,
               error: str = "", retried: bool = False,
               speculative: bool = False) -> None:
        rec = {
            "invocation_id": payload.invocation_id,
            "worker": worker_id,
            "jobs": payload.n_jobs,
            "bins": payload.n_bins,
            "attempt": payload.attempt,
            "speculative": speculative,
        }
        if result is not None:
            rec.update(
                cold=result.cold_start,
                queue_s=max(0.0, result.started_at - payload.created_at),
                exec_s=max(0.0, result.finished_at - result.started_at),
                ok=all(o.ok for o in result.outcomes))
        else:
            rec.update(cold=False, queue_s=0.0, exec_s=0.0, ok=False,
                       error=error)
        with self._lock:
            self.invocations += 1
            self.jobs += payload.n_jobs
            if retried:
                self.retries += 1
            if speculative:
                self.speculative += 1
            if result is None:
                self.failed_invocations += 1
            elif result.cold_start:
                self.cold_starts += 1
            else:
                self.warm_starts += 1
            if len(self.records) < self.max_records:
                self.records.append(rec)
            else:
                self.dropped += 1

    def recent_queue_p95(self, window: int = 64) -> float:
        """p95 queue latency (enqueue -> worker pickup) over the last
        ``window`` successful invocations — the autoscaler's scale-out
        signal (``repro.serverless.autoscale``)."""
        with self._lock:
            recs = self.records[-window:]
        return self._pctl([r["queue_s"] for r in recs if r.get("ok")], 0.95)

    @staticmethod
    def _pctl(xs: List[float], q: float) -> float:
        if not xs:
            return 0.0
        xs = sorted(xs)
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            recs = list(self.records)
            out = {
                "invocations": self.invocations,
                "cold_starts": self.cold_starts,
                "warm_starts": self.warm_starts,
                "retries": self.retries,
                "speculative": self.speculative,
                "failed_invocations": self.failed_invocations,
                "jobs": self.jobs,
            }
        # derived ratios come from the SNAPSHOT, not the live counters —
        # a concurrent record() between here and the with-block above
        # must not produce a torn summary
        out["warm_frac"] = (out["warm_starts"] / out["invocations"]
                            if out["invocations"] else 0.0)
        out["mean_aggregation"] = (out["jobs"] / out["invocations"]
                                   if out["invocations"] else 0.0)
        ok = [r for r in recs if r.get("ok")]
        warm = [r for r in ok if not r["cold"]]
        cold = [r for r in ok if r["cold"]]
        out["queue_s_p50"] = self._pctl([r["queue_s"] for r in ok], 0.5)
        out["queue_s_p95"] = self._pctl([r["queue_s"] for r in ok], 0.95)
        out["exec_s_p50"] = self._pctl([r["exec_s"] for r in ok], 0.5)
        out["cold_exec_s_mean"] = (sum(r["exec_s"] for r in cold) / len(cold)
                                   if cold else 0.0)
        out["warm_exec_s_mean"] = (sum(r["exec_s"] for r in warm) / len(warm)
                                   if warm else 0.0)
        workers: Dict[str, int] = {}
        for r in recs:
            workers[r["worker"]] = workers.get(r["worker"], 0) + 1
        out["per_worker"] = dict(sorted(workers.items()))
        return out
