"""Stateless, serializable invocation payloads (paper §2 step 8; the
Lithops/IBM-Cloud-Functions invocation pipeline adapted to this repro).

A serverless action must be reconstructable by a worker that shares
NOTHING with the invoker but the stores: payloads therefore carry only
*references* — deployment names, resolved implementation versions, the
occurrence's ``scheduled_at`` stamp, bin keys — plus (for backends whose
workers do not share the invoker's memory) the model-version artifacts a
scoring action needs, encoded as plain arrays. Never live objects: no
model instances, no executors, no store handles.

Everything here round-trips through JSON (``to_json``/``from_json``), and
the process backend ships payloads/results as JSON strings over the wire,
which *proves* statelessness — an object that survives the JSON boundary
cannot be secretly sharing state with the invoker. Arrays are encoded as
(dtype, shape, base64-of-bytes) so the round-trip is bitwise.
"""
from __future__ import annotations

import base64
import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.interning import InternTable
from ..core.scheduler import Job

# ---------------------------------------------------------------- arrays


def _enc(obj: Any) -> Any:
    """Recursively encode numpy arrays/scalars into JSON-able structures."""
    if isinstance(obj, np.ndarray):
        a = np.ascontiguousarray(obj)
        return {"__nd__": [str(a.dtype), list(a.shape),
                           base64.b64encode(a.tobytes()).decode("ascii")]}
    if isinstance(obj, (np.floating, np.integer, np.bool_)):
        return {"__np__": [str(obj.dtype),
                           base64.b64encode(
                               np.asarray(obj).tobytes()).decode("ascii")]}
    if isinstance(obj, dict):
        return {k: _enc(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_enc(v) for v in obj]
    return obj


def _dec(obj: Any) -> Any:
    if isinstance(obj, dict):
        if "__nd__" in obj:
            dtype, shape, b64 = obj["__nd__"]
            a = np.frombuffer(base64.b64decode(b64), dtype=np.dtype(dtype))
            return a.reshape([int(s) for s in shape]).copy()
        if "__np__" in obj:
            dtype, b64 = obj["__np__"]
            return np.frombuffer(base64.b64decode(b64),
                                 dtype=np.dtype(dtype))[0]
        return {k: _dec(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_dec(v) for v in obj]
    return obj


# ---------------------------------------------------------------- refs


@dataclass(frozen=True)
class JobRef:
    """A scheduled occurrence by reference — the serializable twin of
    ``core.scheduler.Job`` (which is already pure primitives)."""
    deployment_name: str
    package: str
    version: str
    task: str
    scheduled_at: float
    signal: str
    entity: str
    user_params_key: str = ""

    @classmethod
    def from_job(cls, job: Job) -> "JobRef":
        return cls(job.deployment_name, job.package, job.version, job.task,
                   job.scheduled_at, job.signal, job.entity,
                   job.user_params_key)

    def to_job(self) -> Job:
        return Job(deployment_name=self.deployment_name, package=self.package,
                   version=self.version, task=self.task,
                   scheduled_at=self.scheduled_at, signal=self.signal,
                   entity=self.entity, user_params_key=self.user_params_key)


@dataclass(frozen=True)
class VersionRef:
    """A model-version artifact: what a scoring worker 'downloads' from the
    artifact store. ``model_object`` is the persisted params pytree (plain
    numpy — data, not a live object)."""
    deployment_name: str
    version: int                      # the INVOKER store's version number
    trained_at: float
    model_object: Any = None


@dataclass(frozen=True)
class ForecastBlob:
    """A worker-produced rolling-horizon forecast, shipped back for the
    invoker to persist (idempotent on (deployment, created_at))."""
    deployment_name: str
    signal: str
    entity: str
    created_at: float
    times: np.ndarray
    values: np.ndarray
    model_version: int
    rank: int = 0
    # q10/q90 prediction band (None for band-less models) — also what a
    # detection payload ships TO workers as the band to compare against
    lower: Optional[np.ndarray] = None
    upper: Optional[np.ndarray] = None


@dataclass(frozen=True)
class DetectionBlob:
    """A worker-produced detection occurrence, shipped back for the
    invoker to persist (idempotent on (deployment, scheduled_at)) — the
    detection flow's twin of ``ForecastBlob``. Fields mirror
    ``flows.detection.DetectionRecord``; all primitives, so the JSON
    round-trip is trivially bitwise."""
    deployment_name: str
    signal: str
    entity: str
    scheduled_at: float
    score: float
    n_readings: int
    n_anomalies: int
    band_misses: int
    model_version: int
    derived_signal: str


# ---------------------------------------------------------------- payload


@dataclass(frozen=True)
class InvocationPayload:
    """One serverless action: an *aggregate* of whole job bins (the paper
    groups many modelling tasks into one invocation). Bins are never split
    across payloads — a fleet bin is one megabatched computation, and
    splitting it would change batch shapes and thus f32 numerics."""
    invocation_id: str
    jobs: Tuple[JobRef, ...]
    versions: Tuple[VersionRef, ...] = ()      # score-phase artifacts
    bands: Tuple[ForecastBlob, ...] = ()       # detect-phase artifacts
    created_at: float = 0.0                    # wall-clock enqueue time
    attempt: int = 1
    # trace context ({"trace_id", "parent_id"}) riding the payload so a
    # share-nothing worker's spans stitch under the invoker's trace —
    # the cross-process half of the observability plane (obs/trace.py)
    trace: Optional[Dict[str, int]] = None

    @property
    def n_jobs(self) -> int:
        return len(self.jobs)

    @property
    def n_bins(self) -> int:
        return len({r.to_job().bin_key for r in self.jobs})

    def to_json(self) -> str:
        return json.dumps(_enc(asdict(self)))

    @classmethod
    def from_json(cls, s: str) -> "InvocationPayload":
        d = _dec(json.loads(s))
        return cls(invocation_id=d["invocation_id"],
                   jobs=tuple(JobRef(**j) for j in d["jobs"]),
                   versions=tuple(VersionRef(**v) for v in d["versions"]),
                   bands=tuple(ForecastBlob(**b) for b in d.get("bands", ())),
                   created_at=d["created_at"], attempt=d["attempt"],
                   trace=d.get("trace"))


@dataclass(frozen=True)
class JobOutcome:
    ref: JobRef
    ok: bool
    duration_s: float
    error: str = ""
    attempts: int = 1


@dataclass(frozen=True)
class InvocationResult:
    """What comes back over the wire: per-job outcomes, artifacts produced
    by the action (versions from train jobs, forecasts from score jobs —
    empty for backends that persist directly into the shared stores), and
    the telemetry the monitor aggregates."""
    invocation_id: str
    worker_id: str
    cold_start: bool
    started_at: float                 # wall clock: queue latency = started - created
    finished_at: float
    outcomes: Tuple[JobOutcome, ...]
    versions: Tuple[VersionRef, ...] = ()
    forecasts: Tuple[ForecastBlob, ...] = ()
    detections: Tuple[DetectionBlob, ...] = ()
    # spans the worker process finished while executing this invocation
    # (plain dicts from Tracer.export_since) — the invoker absorbs them
    # into its own tracer to stitch one cross-process trace; empty for
    # backends whose workers share the invoker's tracer (inline)
    spans: Tuple[Dict[str, Any], ...] = ()

    def to_json(self) -> str:
        return json.dumps(_enc(asdict(self)))

    @classmethod
    def from_json(cls, s: str) -> "InvocationResult":
        d = _dec(json.loads(s))
        return cls(
            invocation_id=d["invocation_id"], worker_id=d["worker_id"],
            cold_start=d["cold_start"], started_at=d["started_at"],
            finished_at=d["finished_at"],
            outcomes=tuple(JobOutcome(ref=JobRef(**o.pop("ref")), **o)
                           for o in d["outcomes"]),
            versions=tuple(VersionRef(**v) for v in d["versions"]),
            forecasts=tuple(ForecastBlob(**f) for f in d["forecasts"]),
            detections=tuple(DetectionBlob(**x)
                             for x in d.get("detections", ())),
            spans=tuple(d.get("spans", ())))


#: process-wide intern table for affinity keys: the invoker's routing
#: dict is keyed by these dense ints, so steady-state routing of a bin
#: it has seen before is one tuple hash (here) + one int lookup — no
#: per-poll digesting of member-name strings
AFFINITY_KEYS = InternTable()


def affinity_key(bin_jobs: List[Job]) -> int:
    """Sticky-routing key for one bin — an INTERNED dense int — deciding
    which warm container its work should land on. The interned value
    excludes ``scheduled_at`` and ``task`` (unlike ``Job.bin_key``) so
    catch-up occurrences, successive polls, and the train/score halves of
    ONE logical bin all map to the same int — the worker's warm
    ``FleetRuntime`` state and its train->score device-param handoff are
    keyed by exactly (deployment set, params), which is what the sorted
    member tuple pins. Ids never cross processes; payloads ship names."""
    j0 = bin_jobs[0]
    return AFFINITY_KEYS.intern(
        (j0.package, j0.version, j0.user_params_key,
         tuple(sorted(j.deployment_name for j in bin_jobs))))
