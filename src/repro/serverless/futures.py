"""Futures API for the serverless invoker (the Lithops ``ResponseFuture``
+ ``wait()`` surface, adapted).

One ``ResponseFuture`` tracks one logical invocation across its whole
at-least-once lifecycle — retries, backoff, speculative backup copies are
all the SAME future; it completes once, with the first winning
``InvocationResult`` (after the invoker has absorbed/persisted its
effects) or with the terminal error after every copy burned its budget.

``wait(fs, return_when=ANY_COMPLETED | ALL_COMPLETED | ALWAYS)`` mirrors
Lithops semantics:

* ``ANY_COMPLETED`` — block until at least one future is done; the
  returned ``done`` list is in COMPLETION order, so streaming consumers
  can absorb results as workers finish instead of at a phase barrier.
* ``ALL_COMPLETED`` — block until every future is done.
* ``ALWAYS`` — never block; partition by current state.

On ``timeout`` expiry ``wait`` raises ``FuturesTimeoutError`` carrying the
still-pending futures, after CANCELLING them: the invoker observes the
cancellation, stops retrying that invocation, and marks its jobs failed so
the scheduler re-fires each occurrence at its original boundary — a timed
out action's late effects stay consistent because all persistence is
idempotent on the occurrence stamp.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple

ANY_COMPLETED = "ANY_COMPLETED"
ALL_COMPLETED = "ALL_COMPLETED"
ALWAYS = "ALWAYS"


class FuturesTimeoutError(TimeoutError):
    """``wait`` timed out; ``pending`` holds the (now cancelled) futures
    that had not completed when the deadline expired."""

    def __init__(self, msg: str, pending: Sequence["ResponseFuture"]):
        super().__init__(msg)
        self.pending = list(pending)


class ResponseFuture:
    """State machine: pending -> (success | error | cancelled), one
    transition, observable via ``done``/``result()`` and done-callbacks.
    The invoker owns the setter side (``_set_result``/``_set_error``);
    consumers own ``result``/``cancel``/``wait``."""

    def __init__(self, invocation_id: str = "", payload=None):
        self.invocation_id = invocation_id
        self.payload = payload
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._callbacks: List[Callable[["ResponseFuture"], None]] = []
        self._result = None
        self._error: Optional[BaseException] = None
        self._cancelled = False

    # ---------------------------------------------------------- state
    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def success(self) -> bool:
        return self.done and self._error is None and not self._cancelled

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    # ---------------------------------------------------------- consumer
    def result(self, timeout: Optional[float] = None, *,
               throw_except: bool = True):
        """Block until done; return the ``InvocationResult`` of the
        winning copy. Raises the terminal error / ``CancelledError`` when
        ``throw_except`` (default), else returns None."""
        if not self._event.wait(timeout):
            raise FuturesTimeoutError(
                f"invocation {self.invocation_id or '?'} not done "
                f"after {timeout}s", [self])
        if self._cancelled:
            if throw_except:
                raise CancelledError(
                    f"invocation {self.invocation_id or '?'} cancelled")
            return None
        if self._error is not None:
            if throw_except:
                raise self._error
            return None
        return self._result

    def cancel(self) -> bool:
        """Cancel if not yet done. The action itself cannot be interrupted
        mid-flight — cancellation means the invoker stops retrying and the
        jobs re-fire via the scheduler; late effects of an already-running
        copy are absorbed by store idempotency."""
        with self._lock:
            if self._event.is_set():
                return False
            self._cancelled = True
            cbs = self._finish_locked()
        self._fire(cbs)
        return True

    # ---------------------------------------------------------- producer
    def _set_result(self, result) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._result = result
            cbs = self._finish_locked()
        self._fire(cbs)
        return True

    def _set_error(self, exc: BaseException) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._error = exc
            cbs = self._finish_locked()
        self._fire(cbs)
        return True

    def _finish_locked(self):
        cbs, self._callbacks = self._callbacks, []
        self._event.set()
        return cbs

    def _fire(self, cbs) -> None:
        for cb in cbs:
            cb(self)

    def _on_done(self, cb: Callable[["ResponseFuture"], None]) -> None:
        """Register a completion callback; fired immediately if already
        done (from the completing thread otherwise)."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(cb)
                return
        cb(self)

    def __repr__(self) -> str:
        state = ("cancelled" if self._cancelled else
                 "error" if self._error is not None else
                 "success" if self.done else "pending")
        return f"ResponseFuture({self.invocation_id!r}, {state})"


class CancelledError(RuntimeError):
    pass


def wait(fs: Sequence[ResponseFuture], *,
         return_when: str = ALL_COMPLETED,
         timeout: Optional[float] = None,
         throw_except: bool = True,
         ) -> Tuple[List[ResponseFuture], List[ResponseFuture]]:
    """Partition ``fs`` into ``(done, pending)``.

    ``done`` lists futures in completion order (futures already done at
    entry first, in input order). With ``return_when=ANY_COMPLETED`` the
    call returns as soon as one future is done; ``ALL_COMPLETED`` waits
    for every one; ``ALWAYS`` never blocks. A ``timeout`` expiry cancels
    the pending futures and raises ``FuturesTimeoutError`` when
    ``throw_except`` (default), else returns the partition as-is.
    """
    if return_when not in (ANY_COMPLETED, ALL_COMPLETED, ALWAYS):
        raise ValueError(f"unknown return_when {return_when!r}")
    fs = list(fs)
    done: List[ResponseFuture] = [f for f in fs if f.done]
    if return_when == ALWAYS or not fs:
        return done, [f for f in fs if not f.done]

    cond = threading.Condition()
    order: List[ResponseFuture] = []

    def _cb(f: ResponseFuture) -> None:
        with cond:
            if f not in done and f not in order:
                order.append(f)
            cond.notify_all()

    for f in fs:
        if f not in done:
            f._on_done(_cb)

    need = 1 if return_when == ANY_COMPLETED else len(fs)
    deadline = None if timeout is None else time.monotonic() + timeout
    with cond:
        while len(done) + len(order) < need:
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
            cond.wait(remaining)
        done = done + list(order)
    pending = [f for f in fs if f not in done]
    if pending and len(done) < need:
        for f in pending:
            f.cancel()
        if throw_except:
            raise FuturesTimeoutError(
                f"{len(pending)} of {len(fs)} invocations not done after "
                f"{timeout}s (cancelled)", pending)
    return done, pending
