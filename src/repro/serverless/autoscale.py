"""Telemetry-driven autoscaling of the warm-container pool.

The invoker's backends are elastic (``add_worker``/``remove_worker``);
the ``Autoscaler`` decides WHEN, driven by the same monitor telemetry the
Table-3 sweep surfaces:

* **Scale out** while dispatchable work is backlogged and either every
  live container is busy or the recent p95 queue latency (enqueue ->
  worker pickup, the signal ``InvocationMonitor`` already records) exceeds
  ``target_queue_p95_s`` — bounded by ``max_workers`` and a per-decision
  cooldown so one congested wait-loop iteration cannot stampede to max.
* **Reap** warm containers idle past ``idle_ttl_s`` (no in-flight action,
  nothing dispatched to them recently), down to ``min_workers`` — the
  Lithops "expire idle runtime" behavior. Reaping deliberately discards
  the container's FleetRuntime warmth; sticky routes pointing at a reaped
  worker fall back to the least-busy live worker and re-pin on success.

Every decision lands in ``events`` (and ``summary()``), which the elastic
bench section persists so the worker-count trajectory under load is an
artifact, not a log line.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass(frozen=True)
class AutoscalePolicy:
    min_workers: int = 1
    max_workers: int = 8
    target_queue_p95_s: float = 0.5   # scale out above this queue latency
    idle_ttl_s: float = 30.0          # reap containers idle this long
    scale_step: int = 1               # workers added per decision
    cooldown_s: float = 0.0           # min seconds between scale-outs
    window: int = 64                  # recent invocations for the p95

    def __post_init__(self):
        if self.min_workers < 1 or self.max_workers < self.min_workers:
            raise ValueError(
                f"need 1 <= min_workers <= max_workers, got "
                f"{self.min_workers}..{self.max_workers}")


class Autoscaler:
    """Owns the scale decisions for one backend. Driven by the invoker:
    ``note_dispatch``/``note_done`` maintain per-worker last-use,
    ``observe`` runs in the wait loop, ``reap_idle`` additionally at
    phase end (and on demand, e.g. after a quiet period)."""

    def __init__(self, backend, policy: AutoscalePolicy, monitor):
        self.backend = backend
        self.policy = policy
        self.monitor = monitor
        self.events: List[dict] = []
        self.scale_outs = 0
        self.reaps = 0
        self._lock = threading.Lock()
        self._last_scale = -1e18
        self._t0 = time.perf_counter()
        self._last_used: Dict[str, float] = {
            w: self._t0 for w in backend.worker_ids()}
        # converge the starting pool into the policy band
        while len(self.backend.worker_ids()) < policy.min_workers:
            self._add("init")

    # ------------------------------------------------------------ notes
    def note_dispatch(self, worker_id: str,
                      now: Optional[float] = None) -> None:
        self._last_used[worker_id] = (time.perf_counter()
                                      if now is None else now)

    note_done = note_dispatch

    # ------------------------------------------------------- decisions
    def observe(self, *, backlog: int, busy: Dict[str, int],
                now: Optional[float] = None) -> None:
        """One wait-loop heartbeat: ``backlog`` not-yet-dispatched
        invocations, ``busy`` in-flight count per worker."""
        now = time.perf_counter() if now is None else now
        with self._lock:
            live = self.backend.worker_ids()
            if (backlog > 0 and len(live) < self.policy.max_workers
                    and now - self._last_scale >= self.policy.cooldown_s):
                all_busy = all(busy.get(w, 0) > 0 for w in live)
                p95 = self.monitor.recent_queue_p95(self.policy.window)
                if all_busy or p95 > self.policy.target_queue_p95_s:
                    room = self.policy.max_workers - len(live)
                    for _ in range(min(self.policy.scale_step, room)):
                        self._add("backlog" if all_busy else "queue_p95",
                                  now=now, backlog=backlog, p95=p95)
                    self._last_scale = now
        self.reap_idle(busy=busy, now=now)

    def reap_idle(self, *, busy: Optional[Dict[str, int]] = None,
                  now: Optional[float] = None) -> List[str]:
        """Remove containers idle past the TTL (never below min_workers,
        never one with an in-flight action)."""
        now = time.perf_counter() if now is None else now
        reaped: List[str] = []
        with self._lock:
            for w in list(self.backend.worker_ids()):
                live = self.backend.worker_ids()
                if len(live) <= self.policy.min_workers:
                    break
                if busy is not None and busy.get(w, 0) > 0:
                    continue
                idle_s = now - self._last_used.get(w, self._t0)
                if idle_s <= self.policy.idle_ttl_s:
                    continue
                if self.backend.remove_worker(w):
                    self._last_used.pop(w, None)
                    self.reaps += 1
                    reaped.append(w)
                    self.events.append({
                        "t": now - self._t0, "action": "reap",
                        "worker": w, "idle_s": idle_s,
                        "workers": len(self.backend.worker_ids())})
        return reaped

    def _add(self, reason: str, *, now: Optional[float] = None,
             **info) -> str:
        now = time.perf_counter() if now is None else now
        w = self.backend.add_worker()
        self._last_used[w] = now
        self.scale_outs += 1
        self.events.append({"t": now - self._t0, "action": "scale_out",
                            "worker": w, "reason": reason,
                            "workers": len(self.backend.worker_ids()),
                            **info})
        return w

    # ------------------------------------------------------------ stats
    def summary(self) -> dict:
        with self._lock:
            workers = self.backend.worker_ids()
            return {"workers": len(workers),
                    "min_workers": self.policy.min_workers,
                    "max_workers": self.policy.max_workers,
                    "scale_outs": self.scale_outs,
                    "reaps": self.reaps,
                    "peak_workers": max(
                        [e["workers"] for e in self.events
                         if e["action"] == "scale_out"] + [len(workers)]),
                    "events": list(self.events)}
