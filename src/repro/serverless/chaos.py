"""Deterministic fault injection for the serverless subsystem.

``ChaosPolicy`` makes every failure mode of a real serverless platform
injectable IN-PROCESS and reproducible by seed. Decisions are pure
functions of ``(seed, kind, invocation_id, attempt)`` — never of thread
timing — so a chaos run injects the identical fault set no matter how the
scheduler interleaves workers, and a failing seed replays exactly.

The four faults and where they bite (threaded through ``backend.py`` /
``worker.py``):

* **kill-mid-action** — the worker executes a strict PREFIX of the
  action's bins (their effects persist!) and then dies. The retry
  re-executes the WHOLE action on another worker; the already-persisted
  prefix must no-op at the idempotent stores.
* **drop-result** — the action executes to completion but its result
  never reaches the invoker (transport loss). The invoker retries a
  fully-persisted action; every effect must dedupe.
* **duplicate** — the payload is delivered (and executed) twice, the
  at-least-once delivery case.
* **delay** — the worker stalls before executing: stragglers, which with
  speculation enabled also provoke backup copies (another duplicate
  path).

``max_attempt`` bounds injection to early delivery attempts (default: the
first), so with fault probability 1.0 every invocation fails exactly once
and its retry proceeds cleanly — chaos that never lets work finish proves
nothing. The exactly-once invariant under all of this is pinned bitwise
by ``tests/test_serverless_chaos.py``.
"""
from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, Optional


class ChaosKill(RuntimeError):
    """Injected worker death (possibly after partial persisted effects).
    Backend-level: the whole action is retriable on another worker."""


def _u01(seed: int, kind: str, invocation_id: str, attempt: int) -> float:
    """Deterministic uniform [0, 1) from the fault coordinates."""
    h = zlib.crc32(f"{seed}|{kind}|{invocation_id}|{attempt}"
                   .encode("utf-8"))
    return h / 4294967296.0


@dataclass
class ChaosPolicy:
    """Seeded fault probabilities, applied per (invocation, attempt).

    Probabilities are evaluated independently per fault kind; an
    invocation can draw delay AND kill. Injection only happens while
    ``payload.attempt <= max_attempt`` (default 1: first delivery only),
    which keeps at-least-once convergent by construction.
    """
    seed: int = 0
    kill_mid_action: float = 0.0   # P(worker dies after a prefix of bins)
    drop_result: float = 0.0       # P(result lost after full execution)
    duplicate: float = 0.0         # P(payload delivered twice)
    delay: float = 0.0             # P(straggler stall before execution)
    delay_s: float = 0.2           # stall duration when delay fires
    max_attempt: int = 1           # inject only on attempts <= this
    injected: Dict[str, int] = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    # ------------------------------------------------------------ draws
    def _fires(self, kind: str, prob: float, payload) -> bool:
        if prob <= 0.0 or payload.attempt > self.max_attempt:
            return False
        if _u01(self.seed, kind, payload.invocation_id,
                payload.attempt) >= prob:
            return False
        with self._lock:
            self.injected[kind] = self.injected.get(kind, 0) + 1
        return True

    def kill_point(self, payload) -> Optional[int]:
        """None, or how many whole bins the worker completes before
        dying — a deterministic draw in [0, n_bins-1], so a multi-bin
        action can die with PARTIAL effects persisted."""
        if not self._fires("kill", self.kill_mid_action, payload):
            return None
        u = _u01(self.seed, "kill_point", payload.invocation_id,
                 payload.attempt)
        return int(u * max(1, payload.n_bins))

    def should_drop(self, payload) -> bool:
        return self._fires("drop", self.drop_result, payload)

    def should_duplicate(self, payload) -> bool:
        return self._fires("duplicate", self.duplicate, payload)

    def maybe_delay(self, payload) -> float:
        """Sleep the injected stall (returns the seconds slept)."""
        if not self._fires("delay", self.delay, payload):
            return 0.0
        time.sleep(self.delay_s)
        return self.delay_s

    # ------------------------------------------------------------ stats
    def summary(self) -> Dict[str, int]:
        with self._lock:
            out = dict(self.injected)
        out["total"] = sum(out.values())
        return out
