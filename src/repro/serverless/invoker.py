"""The serverless invoker (paper §2 step 8: "deployed models are
automatically executed in parallel leveraging a serverless cloud
computing framework"; architecture adapted from the Lithops invoker).

Responsibilities, in the order they happen each phase:

* **Phase barrier.** All due TRAIN work completes before any SCORE
  invocation is submitted — a scoring action may consume a version
  trained this cycle on a *different* worker, so the barrier is global,
  not per-invocation (each backend worker only sees its own slice).
* **Action aggregation.** Due jobs are binned exactly as the fleet
  executor bins them, and WHOLE bins are packed into invocations up to
  ``aggregation`` jobs per action (the paper groups its tens of
  thousands of modelling tasks into far fewer serverless actions). Bins
  are never split: a fleet bin is one megabatched computation whose f32
  numerics depend on the batch composition — splitting would break the
  bitwise inline == fleet contract.
* **Warm-container affinity.** Each logical bin (``payload.affinity_key``:
  deployment set + params, across polls and across train/score) routes
  stickily to the worker that last ran it, so that worker's
  ``FleetRuntime`` — device rings, compile caches, train->score param
  handoff — stays warm. Affinity follows success: a bin that completes
  on a different worker (retry, speculation) re-pins there.
* **Bounded in-flight concurrency + retries + stragglers.** At most
  ``max_in_flight`` invocations run concurrently; a failed invocation
  retries with jittered exponential backoff on a DIFFERENT worker, and a
  straggler (running ``straggler_factor``x the median of completed
  invocations) gets one speculative backup copy. All of this is safe
  because persistence (``ModelVersionStore``/``PredictionStore``) is
  idempotent on (deployment, occurrence stamp): at-least-once invocation
  yields exactly-once effects, duplicates no-op at the store.
"""
from __future__ import annotations

import random
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.executor import Executor, JobResult
from ..core.lineage import Forecast
from ..core.scheduler import Job, bin_jobs
from .backend import InlineBackend, InvocationBackend
from .monitor import InvocationMonitor
from .payload import (InvocationPayload, InvocationResult, JobRef,
                      VersionRef, affinity_key)


class ServerlessInvoker:
    def __init__(self, system, backend: InvocationBackend, *,
                 aggregation: int = 32, max_in_flight: int = 8,
                 max_retries: int = 2, backoff_base_s: float = 0.05,
                 straggler_factor: float = 4.0, straggler_min_s: float = 2.0,
                 speculative: bool = True, seed: int = 0,
                 monitor: Optional[InvocationMonitor] = None):
        self.system = system
        self.backend = backend
        self.aggregation = max(1, int(aggregation))
        self.max_in_flight = max(1, int(max_in_flight))
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.straggler_factor = float(straggler_factor)
        self.straggler_min_s = float(straggler_min_s)
        self.speculative = speculative
        self.monitor = monitor or InvocationMonitor()
        self._rng = random.Random(seed)
        self._affinity: Dict[tuple, str] = {}
        self._rr = 0
        self._seq = 0

    # ------------------------------------------------ public entry
    def run(self, jobs: List[Job]) -> List[JobResult]:
        out: List[JobResult] = []
        trains = [j for j in jobs if j.task == "train"]
        scores = [j for j in jobs if j.task != "train"]
        for phase in (trains, scores):        # global train->score barrier
            out.extend(self._run_phase(phase))
        return out

    # ------------------------------------------------ planning
    def _plan(self, jobs: List[Job], results: List[JobResult]
              ) -> List[dict]:
        """Bins -> worker routing -> aggregated invocations. Also resolves
        score-phase model versions (a never-trained deployment fails ALONE
        here, mirroring FleetExecutor's partial-bin semantics) and records
        the invoker-store version numbers so shipped-back forecasts can be
        persisted with the invoker's lineage numbering."""
        jobs = sorted(jobs, key=lambda j: j.scheduled_at)
        routed: Dict[str, List[dict]] = {w: [] for w in
                                         self.backend.worker_ids()}
        workers = list(routed)
        for key, bjs in bin_jobs(jobs).items():
            resolved: Dict[Tuple[str, float], object] = {}
            if key[2] != "train":
                present = []
                for j in bjs:
                    mv = self.system.versions.get(j.deployment_name,
                                                  at=j.scheduled_at)
                    if mv is None:
                        self.system.scheduler.mark_failed(j)
                        results.append(JobResult(
                            j, False, 0.0,
                            error=f"no trained version for "
                                  f"{j.deployment_name}"))
                    else:
                        present.append(j)
                        resolved[(j.deployment_name, j.scheduled_at)] = mv
                bjs = present
                if not bjs:
                    continue
            ak = affinity_key(bjs)
            w = self._affinity.get(ak)
            if w is None or w not in routed:
                w = workers[self._rr % len(workers)]
                self._rr += 1
                self._affinity[ak] = w
            routed[w].append({"jobs": bjs, "ak": ak, "resolved": resolved})
        invocations: List[dict] = []

        def cut(worker: str, bins: List[dict]) -> None:
            self._seq += 1
            jobs_ = [j for b in bins for j in b["jobs"]]
            resolved = {k: mv for b in bins
                        for k, mv in b["resolved"].items()}
            versions: Tuple[VersionRef, ...] = ()
            if self.backend.wants_artifacts and resolved:
                versions = tuple(
                    VersionRef(deployment_name=name, version=mv.version,
                               trained_at=mv.trained_at,
                               model_object=mv.params)
                    for (name, _at), mv in resolved.items())
            payload = InvocationPayload(
                invocation_id=f"inv-{self._seq:06d}",
                jobs=tuple(JobRef.from_job(j) for j in jobs_),
                versions=versions, created_at=time.time())
            invocations.append({"payload": payload, "worker": worker,
                                "aks": [b["ak"] for b in bins],
                                "resolved": resolved})

        for w, bins in routed.items():
            cur: List[dict] = []
            n = 0
            for b in bins:
                if cur and n + len(b["jobs"]) > self.aggregation:
                    cut(w, cur)
                    cur, n = [], 0
                cur.append(b)
                n += len(b["jobs"])
            if cur:
                cut(w, cur)
        return invocations

    # ------------------------------------------------ execution
    def _run_phase(self, jobs: List[Job]) -> List[JobResult]:
        if not jobs:
            return []
        results: List[JobResult] = []
        invocations = self._plan(jobs, results)
        if not invocations:
            return results
        workers = self.backend.worker_ids()
        done_ids: set = set()
        durations: List[float] = []
        started: Dict[int, float] = {}        # token -> actual start time
        attempts: Dict[str, int] = {}         # invocation_id -> submissions
        inflight: Dict[str, int] = {}
        backups: Dict[str, bool] = {}
        deferred: List[tuple] = []            # (ready_at, inv) backoff queue
        tokens = iter(range(1 << 30))

        def attempt(inv: dict, token: int):
            started[token] = time.perf_counter()
            return self.backend.invoke(inv["payload"], inv["worker"])

        def submit(pool, pending, inv, *, delay_s=0.0):
            """Attempt accounting happens HERE (including deferred
            retries: a deferred copy still counts against the budget and
            against in-flight-copies, so a concurrently failing sibling
            can neither overspend retries nor declare final failure while
            a retry is waiting out its backoff). The backoff itself is
            served from the main wait loop — a sleeping retry must not
            occupy one of the max_in_flight pool slots."""
            iid = inv["payload"].invocation_id
            attempts[iid] = attempts.get(iid, 0) + 1
            inflight[iid] = inflight.get(iid, 0) + 1
            if delay_s > 0:
                deferred.append((time.perf_counter() + delay_s, inv))
                return
            token = next(tokens)
            inv = {**inv, "token": token}
            f = pool.submit(attempt, inv, token)
            pending[f] = inv

        def other_worker(cur: str) -> str:
            if len(workers) == 1:
                return cur
            pick = workers[self._rr % len(workers)]
            self._rr += 1
            if pick == cur:
                pick = workers[self._rr % len(workers)]
                self._rr += 1
            return pick

        with ThreadPoolExecutor(max_workers=self.max_in_flight) as pool:
            pending: Dict[object, dict] = {}
            for inv in invocations:
                submit(pool, pending, inv)
            while pending or deferred:
                if deferred:              # release retries whose backoff
                    now_d = time.perf_counter()    # elapsed
                    due = [d for d in deferred if d[0] <= now_d]
                    deferred = [d for d in deferred if d[0] > now_d]
                    for _, inv in due:
                        iid_d = inv["payload"].invocation_id
                        if iid_d in done_ids:
                            # a sibling copy won while this retry was
                            # backing off: drop it (and its in-flight
                            # claim) instead of re-running the action
                            inflight[iid_d] -= 1
                            continue
                        token = next(tokens)
                        inv = {**inv, "token": token}
                        f = pool.submit(attempt, inv, token)
                        pending[f] = inv
                    if not pending:       # all runnable work is backing off
                        if deferred:      # (or was just dropped as won)
                            time.sleep(max(0.0, min(t for t, _ in deferred)
                                           - time.perf_counter()))
                        continue
                timeout = self.straggler_min_s
                if deferred:
                    timeout = max(0.005, min(
                        timeout, min(t for t, _ in deferred)
                        - time.perf_counter()))
                done, _ = wait(list(pending), timeout=timeout,
                               return_when=FIRST_COMPLETED)
                for f in done:
                    inv = pending.pop(f)
                    payload = inv["payload"]
                    iid = payload.invocation_id
                    inflight[iid] -= 1
                    try:
                        result = f.result()
                    except Exception as e:  # noqa: BLE001
                        self.monitor.record(
                            payload=payload, worker_id=inv["worker"],
                            error=f"{type(e).__name__}: {e}",
                            retried=inv.get("retried", False),
                            speculative=inv.get("speculative", False))
                        if iid in done_ids:
                            continue          # a sibling copy already won
                        if attempts[iid] <= self.max_retries:
                            retry = dict(inv)
                            retry["worker"] = other_worker(inv["worker"])
                            retry["retried"] = True
                            retry["payload"] = replace(
                                payload, attempt=attempts[iid] + 1,
                                created_at=time.time())
                            delay = (self.backoff_base_s
                                     * (2 ** (attempts[iid] - 1))
                                     * (1.0 + self._rng.random()))
                            submit(pool, pending, retry, delay_s=delay)
                        elif inflight[iid] == 0:
                            # every copy burned: the whole action fails,
                            # each job re-fires at its own boundary
                            for ref in payload.jobs:
                                job = ref.to_job()
                                self.system.scheduler.mark_failed(job)
                                results.append(JobResult(
                                    job, False, 0.0,
                                    attempts=attempts[iid],
                                    error=f"invocation failed: "
                                          f"{type(e).__name__}: {e}"))
                        continue
                    self.monitor.record(
                        payload=payload, result=result,
                        worker_id=result.worker_id,
                        retried=inv.get("retried", False),
                        speculative=inv.get("speculative", False))
                    if iid in done_ids:
                        continue              # speculation loser: effects
                    done_ids.add(iid)         # already deduped by stores
                    dur = result.finished_at - result.started_at
                    durations.append(dur)
                    for ak in inv["aks"]:     # affinity follows success
                        self._affinity[ak] = result.worker_id
                    results.extend(self._absorb(inv, result,
                                                attempts[iid]))
                # straggler resubmission (MapReduce-style backup copies).
                # Pointless with a single worker: backends run one action
                # per worker at a time, so a backup would just queue
                # behind the very straggler it is meant to outrun.
                if not self.speculative or not durations \
                        or len(workers) == 1:
                    continue
                med = float(np.median(durations))
                thresh = max(self.straggler_min_s,
                             self.straggler_factor * med)
                now = time.perf_counter()
                for f, inv in list(pending.items()):
                    iid = inv["payload"].invocation_id
                    t0 = started.get(inv["token"])
                    if t0 is None or iid in done_ids or backups.get(iid) \
                            or attempts[iid] > self.max_retries \
                            or now - t0 <= thresh:
                        continue
                    backups[iid] = True
                    backup = dict(inv)
                    backup["worker"] = other_worker(inv["worker"])
                    backup["speculative"] = True
                    backup["payload"] = replace(inv["payload"],
                                                created_at=time.time())
                    submit(pool, pending, backup)
        return results

    # ------------------------------------------------ absorption
    def _absorb(self, inv: dict, result: InvocationResult,
                n_attempts: int) -> List[JobResult]:
        """Turn one completed invocation into persisted effects +
        JobResults. Backends whose workers share the invoker's stores
        (inline) have already persisted; artifact-shipping backends
        (process) persist here — idempotently, so replayed or speculative
        duplicates of the same occurrence no-op."""
        if self.backend.wants_artifacts:
            for vr in result.versions:
                self.system.versions.save(
                    vr.deployment_name, vr.model_object,
                    trained_at=vr.trained_at,
                    metadata={"serverless": True,
                              "worker": result.worker_id})
            fcs = []
            for fb in result.forecasts:
                mv = inv["resolved"].get((fb.deployment_name, fb.created_at))
                dep = self.system.deployments.get(fb.deployment_name)
                fcs.append(Forecast(
                    deployment_name=fb.deployment_name, signal=fb.signal,
                    entity=fb.entity, created_at=fb.created_at,
                    times=np.asarray(fb.times),
                    values=np.asarray(fb.values),
                    # the invoker's OWN lineage numbering, not the worker
                    # replica's (their histories can differ)
                    model_version=(mv.version if mv is not None
                                   else fb.model_version),
                    rank=dep.rank))
            if fcs:
                self.system.predictions.save_many(fcs)
        out = []
        for o in result.outcomes:
            job = o.ref.to_job()
            if not o.ok:
                # inline workers marked the shared scheduler already
                # (idempotent set); process workers only marked their own
                self.system.scheduler.mark_failed(job)
            out.append(JobResult(job, o.ok, o.duration_s,
                                 attempts=max(o.attempts, n_attempts),
                                 error=o.error))
        return out


class ServerlessExecutor(Executor):
    """Executor-protocol facade: ``run(jobs) -> List[JobResult]`` like
    LocalPool/Fleet, but through the serverless invocation pipeline.
    Default backend is the deterministic in-process ``InlineBackend``;
    pass a ``ProcessBackend`` for real OS-level containers. Long-lived:
    keep ONE instance across polls so warm-container affinity pays
    (``Castor.serverless_executor()`` does this)."""

    def __init__(self, system, *, backend: Optional[InvocationBackend] = None,
                 n_workers: int = 4,
                 monitor: Optional[InvocationMonitor] = None, **invoker_kw):
        self.backend = backend or InlineBackend(system, n_workers=n_workers)
        self.monitor = monitor or InvocationMonitor()
        self.invoker = ServerlessInvoker(system, self.backend,
                                         monitor=self.monitor, **invoker_kw)

    def run(self, jobs: List[Job]) -> List[JobResult]:
        return self.invoker.run(jobs)

    def stats(self) -> dict:
        return self.monitor.summary()

    def close(self) -> None:
        self.backend.close()
