"""The serverless invoker (paper §2 step 8: "deployed models are
automatically executed in parallel leveraging a serverless cloud
computing framework"; architecture adapted from the Lithops invoker).

Responsibilities, in the order they happen each phase:

* **Phase barrier.** All due TRAIN work completes before any SCORE
  invocation is submitted — a scoring action may consume a version
  trained this cycle on a *different* worker, so the barrier is global,
  not per-invocation (each backend worker only sees its own slice).
  ``submit()`` exposes the async single-phase surface underneath the
  barrier: it returns one ``ResponseFuture`` per invocation and streams
  each action's effects into the stores the moment it completes, so a
  consumer ``wait()``-ing with ``ANY_COMPLETED`` can read an
  early-finishing bin's forecasts while the slowest bin is still running.
* **Action aggregation.** Due jobs are binned exactly as the fleet
  executor bins them, and WHOLE bins are packed into invocations up to
  ``aggregation`` jobs per action (the paper groups its tens of
  thousands of modelling tasks into far fewer serverless actions). Bins
  are never split: a fleet bin is one megabatched computation whose f32
  numerics depend on the batch composition — splitting would break the
  bitwise inline == fleet contract.
* **Warm-container affinity + late-bound dispatch.** Each logical bin
  (``payload.affinity_key``: an interned int for deployment set + params,
  stable across polls and across train/score) routes stickily to the
  worker that last ran it, so
  that worker's ``FleetRuntime`` — device rings, compile caches,
  train->score param handoff — stays warm. Affinity follows success: a
  bin that completes on a different worker (retry, speculation) re-pins
  there. Planning only records a PREFERENCE; the actual worker is chosen
  at dispatch time from the live pool, which is what makes the pool
  elastic — an action queued behind a busy container can land on a
  worker the autoscaler provisioned after the phase was planned. With a
  fixed fleet (no autoscaler) dispatch waits for the preferred worker,
  preserving deterministic sticky routing.
* **Autoscaling.** With an ``AutoscalePolicy`` the invoker drives an
  ``Autoscaler`` from its wait loop: scale out while ready work is
  backlogged and the pool is saturated (or recent queue p95 exceeds
  target), reap containers idle past the TTL — and dispatch steals
  across workers instead of waiting on the preferred one.
* **Bounded in-flight concurrency + retries + stragglers.** At most
  ``max_in_flight`` invocations run concurrently; a failed invocation
  retries with jittered exponential backoff on a DIFFERENT worker, and a
  straggler (running ``straggler_factor``x the median of completed
  invocations) gets one speculative backup copy. All of this is safe
  because persistence (``ModelVersionStore``/``PredictionStore``) is
  idempotent on (deployment, occurrence stamp): at-least-once invocation
  yields exactly-once effects, duplicates no-op at the store.
"""
from __future__ import annotations

import random
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.executor import Executor, JobResult
from ..core.lineage import Forecast
from ..core.scheduler import Job, bin_jobs
from ..obs.trace import get_tracer
from .autoscale import AutoscalePolicy, Autoscaler
from .backend import InlineBackend, InvocationBackend
from .futures import ResponseFuture
from .monitor import InvocationMonitor
from .payload import (ForecastBlob, InvocationPayload, InvocationResult,
                      JobRef, VersionRef, affinity_key)


class _Phase:
    """All mutable state of one phase in flight: the ready queue of
    not-yet-dispatched invocation copies, the backoff queue, the pool
    futures actually executing, and the exactly-once bookkeeping
    (attempts / in-flight copies / winners)."""

    def __init__(self, invocations: List[dict], results: List[JobResult]):
        self.results = results
        self.ready: List[dict] = []
        self.deferred: List[tuple] = []    # (ready_at, inv) backoff queue
        self.pending: Dict[object, dict] = {}   # pool future -> inv
        self.attempts: Dict[str, int] = {}      # iid -> copies created
        self.inflight: Dict[str, int] = {}      # iid -> copies not settled
        self.done_ids: set = set()
        self.durations: List[float] = []
        self.started: Dict[int, float] = {}     # token -> dispatch time
        self.backups: Dict[str, bool] = {}
        self.busy: Dict[str, int] = {}          # worker -> in-flight count
        self.span_done: set = set()   # iids whose pre-allocated invoke
        #                               span id has been recorded
        self.futures: Dict[str, ResponseFuture] = {
            inv["payload"].invocation_id:
                ResponseFuture(inv["payload"].invocation_id,
                               payload=inv["payload"])
            for inv in invocations}
        self.tokens = iter(range(1 << 30))


class ServerlessInvoker:
    def __init__(self, system, backend: InvocationBackend, *,
                 aggregation: int = 32, max_in_flight: int = 8,
                 max_retries: int = 2, backoff_base_s: float = 0.05,
                 straggler_factor: float = 4.0, straggler_min_s: float = 2.0,
                 speculative: bool = True, seed: int = 0,
                 autoscale: Optional[AutoscalePolicy] = None,
                 monitor: Optional[InvocationMonitor] = None):
        self.system = system
        self.backend = backend
        self.aggregation = max(1, int(aggregation))
        self.max_in_flight = max(1, int(max_in_flight))
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.straggler_factor = float(straggler_factor)
        self.straggler_min_s = float(straggler_min_s)
        self.speculative = speculative
        self.monitor = monitor or InvocationMonitor()
        self.autoscaler = (Autoscaler(backend, autoscale, self.monitor)
                           if autoscale is not None else None)
        self._rng = random.Random(seed)
        self._affinity: Dict[int, str] = {}     # interned affinity_key -> worker
        self._rr = 0
        self._seq = 0

    # ------------------------------------------------ public entry
    def run(self, jobs: List[Job]) -> List[JobResult]:
        out: List[JobResult] = []
        trains = [j for j in jobs if j.task == "train"]
        detects = [j for j in jobs if j.task == "detect"]
        scores = [j for j in jobs if j.task not in ("train", "detect")]
        # global train->score->detect barriers: a scoring action may
        # consume a version trained this cycle on a different worker, and
        # a detection compares against a band scored this cycle
        tracer = get_tracer()
        for task, phase in (("train", trains), ("score", scores),
                            ("detect", detects)):
            if not phase:
                continue
            with tracer.span("serverless.phase", task=task,
                             jobs=len(phase)):
                out.extend(self._run_phase(phase))
        if self.autoscaler is not None:
            self.autoscaler.reap_idle()
        return out

    def submit(self, jobs: List[Job]) -> List[ResponseFuture]:
        """Async single-phase submission: one ``ResponseFuture`` per
        aggregated invocation, driven by a daemon thread. Each future
        completes AFTER the invoker has absorbed that action's effects,
        so a completed future's forecasts/versions are already queryable
        — the streaming surface ``futures.wait(..., ANY_COMPLETED)``
        consumes. Jobs that fail planning (score with no trained version)
        are marked failed at the scheduler and re-fire there; mixing
        task kinds in one submission is rejected because the
        train->score->detect barriers cannot be enforced
        asynchronously."""
        tasks = {j.task for j in jobs}
        if len(tasks) > 1:
            raise ValueError(
                "submit() is single-phase: jobs of different tasks "
                f"({sorted(tasks)}) cannot share one async submission "
                "(train->score->detect barriers); use run() or one "
                "submit() call per task")
        results: List[JobResult] = []
        invocations = self._plan(jobs, results)
        state = _Phase(invocations, results)
        state.ready.extend(self._enqueue_all(state, invocations))
        futures = [state.futures[inv["payload"].invocation_id]
                   for inv in invocations]
        t = threading.Thread(target=self._drive, args=(state,),
                             name="serverless-invoker-drive", daemon=True)
        t.start()
        return futures

    # ------------------------------------------------ planning
    def _plan(self, jobs: List[Job], results: List[JobResult]
              ) -> List[dict]:
        """Bins -> worker routing -> aggregated invocations. Also resolves
        score-phase model versions (a never-trained deployment fails ALONE
        here, mirroring FleetExecutor's partial-bin semantics) and records
        the invoker-store version numbers so shipped-back forecasts can be
        persisted with the invoker's lineage numbering."""
        jobs = sorted(jobs, key=lambda j: j.scheduled_at)
        routed: Dict[str, List[dict]] = {w: [] for w in
                                         self.backend.worker_ids()}
        workers = list(routed)
        for key, bjs in bin_jobs(jobs).items():
            resolved: Dict[Tuple[str, float], object] = {}
            bands: Dict[Tuple[str, float], object] = {}
            if key[2] == "detect":
                # a detection needs the banded forecast a live poller
                # would have had at its boundary; a context with no band
                # yet fails ALONE (mirrors FleetExecutor's partial bin)
                present = []
                for j in bjs:
                    fc = self.system.predictions.latest(
                        j.signal, j.entity, at=j.scheduled_at)
                    if fc is None or fc.lower is None:
                        self.system.scheduler.mark_failed(j)
                        results.append(JobResult(
                            j, False, 0.0,
                            error=f"no banded forecast for "
                                  f"{j.signal}@{j.entity}"))
                    else:
                        present.append(j)
                        bands[(j.deployment_name, j.scheduled_at)] = fc
                bjs = present
                if not bjs:
                    continue
            elif key[2] != "train":
                present = []
                for j in bjs:
                    mv = self.system.versions.get(j.deployment_name,
                                                  at=j.scheduled_at)
                    if mv is None:
                        self.system.scheduler.mark_failed(j)
                        results.append(JobResult(
                            j, False, 0.0,
                            error=f"no trained version for "
                                  f"{j.deployment_name}"))
                    else:
                        present.append(j)
                        resolved[(j.deployment_name, j.scheduled_at)] = mv
                bjs = present
                if not bjs:
                    continue
            ak = affinity_key(bjs)
            w = self._affinity.get(ak)
            if w is None or w not in routed:
                w = workers[self._rr % len(workers)]
                self._rr += 1
                self._affinity[ak] = w
            routed[w].append({"jobs": bjs, "ak": ak, "resolved": resolved,
                              "bands": bands})
        invocations: List[dict] = []
        tracer = get_tracer()
        # trace context of the enclosing phase/tick span: each invocation
        # gets a PRE-ALLOCATED invoke-span id that rides the payload, so
        # worker spans can parent under it before it is recorded (the
        # span itself is recorded at settle time, when both endpoints of
        # the dispatch->result interval are known)
        tctx = tracer.current() if tracer.enabled else None

        def cut(worker: str, bins: List[dict]) -> None:
            self._seq += 1
            jobs_ = [j for b in bins for j in b["jobs"]]
            resolved = {k: mv for b in bins
                        for k, mv in b["resolved"].items()}
            bands_ = {k: fc for b in bins for k, fc in b["bands"].items()}
            versions: Tuple[VersionRef, ...] = ()
            band_blobs: Tuple[ForecastBlob, ...] = ()
            if self.backend.wants_artifacts and resolved:
                versions = tuple(
                    VersionRef(deployment_name=name, version=mv.version,
                               trained_at=mv.trained_at,
                               model_object=mv.params)
                    for (name, _at), mv in resolved.items())
            if self.backend.wants_artifacts and bands_:
                # the banded forecasts a detect action compares against:
                # shipped as data so a share-nothing worker replays the
                # invoker's ``at=`` resolution bitwise
                band_blobs = tuple(
                    ForecastBlob(deployment_name=fc.deployment_name,
                                 signal=fc.signal, entity=fc.entity,
                                 created_at=fc.created_at, times=fc.times,
                                 values=fc.values,
                                 model_version=fc.model_version,
                                 rank=fc.rank, lower=fc.lower,
                                 upper=fc.upper)
                    for fc in bands_.values())
            span_id = trace_id = None
            trace = None
            if tracer.enabled:
                span_id = tracer.allocate_id()
                trace_id = (tctx["trace_id"] if tctx is not None
                            else tracer.new_trace_id())
                trace = {"trace_id": trace_id, "parent_id": span_id}
            payload = InvocationPayload(
                invocation_id=f"inv-{self._seq:06d}",
                jobs=tuple(JobRef.from_job(j) for j in jobs_),
                versions=versions, bands=band_blobs,
                created_at=time.time(), trace=trace)
            invocations.append({"payload": payload, "worker": worker,
                                "aks": [b["ak"] for b in bins],
                                "resolved": resolved,
                                "span_id": span_id, "trace_id": trace_id,
                                "parent_id": (tctx["parent_id"]
                                              if tctx is not None else 0)})

        for w, bins in routed.items():
            cur: List[dict] = []
            n = 0
            for b in bins:
                if cur and n + len(b["jobs"]) > self.aggregation:
                    cut(w, cur)
                    cur, n = [], 0
                cur.append(b)
                n += len(b["jobs"])
            if cur:
                cut(w, cur)
        return invocations

    # ------------------------------------------------ dispatch
    def _enqueue_all(self, state: _Phase,
                     invocations: List[dict]) -> List[dict]:
        for inv in invocations:
            iid = inv["payload"].invocation_id
            state.attempts[iid] = state.attempts.get(iid, 0) + 1
            state.inflight[iid] = state.inflight.get(iid, 0) + 1
        return list(invocations)

    def _enqueue(self, state: _Phase, inv: dict, *,
                 delay_s: float = 0.0) -> None:
        """Create one more copy of an invocation (initial, retry or
        backup). Attempt accounting happens HERE — a copy waiting out its
        backoff still counts against the budget and against in-flight
        copies, so a concurrently failing sibling can neither overspend
        retries nor declare final failure while a retry is pending."""
        iid = inv["payload"].invocation_id
        state.attempts[iid] = state.attempts.get(iid, 0) + 1
        state.inflight[iid] = state.inflight.get(iid, 0) + 1
        if delay_s > 0:
            state.deferred.append((time.perf_counter() + delay_s, inv))
        else:
            state.ready.append(inv)

    def _pick_worker(self, state: _Phase, inv: dict, live: List[str],
                     idle: List[str]) -> Optional[str]:
        """Late-bound routing: the planned worker if it is live and idle;
        with an autoscaler (or when the planned worker was reaped) any
        idle live worker — work-stealing is what lets a freshly
        provisioned container drain the backlog. With a fixed fleet,
        dispatch WAITS for the preferred worker instead, keeping sticky
        routing (and its warm FleetRuntime reuse) deterministic."""
        pref = inv.get("worker")
        if pref in idle:
            return pref
        if pref in live and self.autoscaler is None:
            return None
        cands = [w for w in idle if w != inv.get("avoid")] or idle
        pick = cands[self._rr % len(cands)]
        self._rr += 1
        return pick

    def _dispatch(self, state: _Phase, pool: ThreadPoolExecutor) -> None:
        """One forward pass over the ready queue. Dispatching only
        CONSUMES capacity (workers get busier, pending fills), so
        re-scanning after a dispatch can never unlock an earlier-stuck
        item — a single pass reaches the same fixed point as a restart
        loop without the O(ready^2) rescans a 10k-invocation agg=1
        sweep would otherwise pay on every settle."""
        live = self.backend.worker_ids()
        keep: List[dict] = []
        for k, inv in enumerate(state.ready):
            iid = inv["payload"].invocation_id
            if iid in state.done_ids:          # a sibling copy already won
                state.inflight[iid] -= 1
                continue
            fut = state.futures.get(iid)
            if fut is not None and fut.cancelled:
                state.inflight[iid] -= 1
                self._finalize_cancel(state, inv)
                continue
            idle = [w for w in live if state.busy.get(w, 0) == 0]
            if not idle or len(state.pending) >= self.max_in_flight:
                keep.extend(state.ready[k:])   # nothing can dispatch now
                break
            w = self._pick_worker(state, inv, live, idle)
            if w is None:
                keep.append(inv)               # stuck on a busy preferred
                continue                       # worker; later items may go
            token = next(state.tokens)
            tr = get_tracer()
            inv = {**inv, "worker": w, "token": token,
                   "t_disp": tr.clock() if tr.enabled else 0.0}
            state.busy[w] = state.busy.get(w, 0) + 1
            state.started[token] = time.perf_counter()
            if self.autoscaler is not None:
                self.autoscaler.note_dispatch(w)
            f = pool.submit(self.backend.invoke, inv["payload"], w)
            state.pending[f] = inv
        state.ready[:] = keep

    def _finalize_cancel(self, state: _Phase, inv: dict) -> None:
        """A cancelled invocation stops consuming budget: no more copies,
        jobs marked failed so the scheduler re-fires each occurrence at
        its own boundary. Late effects of a copy that already ran are
        absorbed by store idempotency."""
        iid = inv["payload"].invocation_id
        if iid in state.done_ids:
            return
        state.done_ids.add(iid)
        for ref in inv["payload"].jobs:
            job = ref.to_job()
            self.system.scheduler.mark_failed(job)
            state.results.append(JobResult(
                job, False, 0.0, attempts=state.attempts.get(iid, 0),
                error="invocation cancelled"))

    # ------------------------------------------------ execution
    def _run_phase(self, jobs: List[Job]) -> List[JobResult]:
        if not jobs:
            return []
        results: List[JobResult] = []
        invocations = self._plan(jobs, results)
        if not invocations:
            return results
        state = _Phase(invocations, results)
        state.ready.extend(self._enqueue_all(state, invocations))
        self._drive(state)
        return results

    def _other_worker(self, cur: str) -> str:
        workers = self.backend.worker_ids()
        if len(workers) <= 1:
            return cur
        pick = workers[self._rr % len(workers)]
        self._rr += 1
        if pick == cur:
            pick = workers[self._rr % len(workers)]
            self._rr += 1
        return pick

    def _drive(self, state: _Phase) -> None:
        with ThreadPoolExecutor(max_workers=self.max_in_flight) as pool:
            while state.ready or state.deferred or state.pending:
                if state.deferred:    # release retries whose backoff
                    now_d = time.perf_counter()         # elapsed
                    due = [d for d in state.deferred if d[0] <= now_d]
                    state.deferred = [d for d in state.deferred
                                      if d[0] > now_d]
                    for _, inv in due:
                        iid_d = inv["payload"].invocation_id
                        if iid_d in state.done_ids:
                            # a sibling copy won while this retry was
                            # backing off: drop it (and its in-flight
                            # claim) instead of re-running the action
                            state.inflight[iid_d] -= 1
                            continue
                        state.ready.append(inv)
                self._dispatch(state, pool)
                if self.autoscaler is not None:
                    self.autoscaler.observe(backlog=len(state.ready),
                                            busy=dict(state.busy))
                    if state.ready:    # a scale-out makes new slots idle
                        self._dispatch(state, pool)
                if not state.pending:
                    if state.deferred:  # all runnable work is backing off
                        time.sleep(max(0.0, min(
                            t for t, _ in state.deferred)
                            - time.perf_counter()))
                    elif state.ready:   # no live idle worker to take it
                        time.sleep(0.005)
                    continue
                timeout = self.straggler_min_s
                if self.autoscaler is not None and state.ready:
                    # keep the scale-out decision loop responsive while
                    # work is backlogged
                    timeout = min(timeout, 0.05)
                if state.deferred:
                    timeout = max(0.005, min(
                        timeout, min(t for t, _ in state.deferred)
                        - time.perf_counter()))
                done, _ = wait(list(state.pending), timeout=timeout,
                               return_when=FIRST_COMPLETED)
                for f in done:
                    self._settle(state, f)
                self._maybe_backup(state)

    def _trace_invoke(self, state: _Phase, inv: dict, *, ok: bool,
                      worker: str, error: str = "") -> None:
        """Record one ``serverless.invoke`` span per settled copy — the
        1:1 twin of ``monitor.record`` (span counts == invocation
        counts). The FIRST settled copy of an invocation claims the
        pre-allocated span id the payload's trace context points at, so
        worker spans stitch under it; later copies (retries, backups)
        record fresh sibling ids under the same phase span."""
        tracer = get_tracer()
        if not tracer.enabled:
            return
        payload = inv["payload"]
        iid = payload.invocation_id
        span_id = None
        if iid not in state.span_done and inv.get("span_id") is not None:
            state.span_done.add(iid)
            span_id = inv["span_id"]
        args = {"invocation_id": iid, "worker": worker, "ok": ok,
                "jobs": payload.n_jobs, "attempt": payload.attempt}
        if error:
            args["error"] = error
        tracer.record("serverless.invoke", inv.get("t_disp", 0.0),
                      tracer.clock(), span_id=span_id,
                      parent_id=inv.get("parent_id", 0) or 0,
                      trace_id=inv.get("trace_id"), args=args)

    def _settle(self, state: _Phase, f) -> None:
        inv = state.pending.pop(f)
        payload = inv["payload"]
        iid = payload.invocation_id
        state.inflight[iid] -= 1
        state.busy[inv["worker"]] = max(0, state.busy.get(inv["worker"], 1)
                                        - 1)
        if self.autoscaler is not None:
            self.autoscaler.note_done(inv["worker"])
        fut = state.futures.get(iid)
        try:
            result = f.result()
        except Exception as e:  # noqa: BLE001
            self.monitor.record(
                payload=payload, worker_id=inv["worker"],
                error=f"{type(e).__name__}: {e}",
                retried=inv.get("retried", False),
                speculative=inv.get("speculative", False))
            self._trace_invoke(state, inv, ok=False, worker=inv["worker"],
                               error=f"{type(e).__name__}: {e}")
            if iid in state.done_ids:
                return                # a sibling copy already won
            if fut is not None and fut.cancelled:
                self._finalize_cancel(state, inv)
                return
            if state.attempts[iid] <= self.max_retries:
                retry = dict(inv)
                retry["avoid"] = inv["worker"]
                retry["worker"] = self._other_worker(inv["worker"])
                retry["retried"] = True
                retry["payload"] = replace(
                    payload, attempt=state.attempts[iid] + 1,
                    created_at=time.time())
                delay = (self.backoff_base_s
                         * (2 ** (state.attempts[iid] - 1))
                         * (1.0 + self._rng.random()))
                self._enqueue(state, retry, delay_s=delay)
            elif state.inflight[iid] == 0:
                # every copy burned: the whole action fails, each job
                # re-fires at its own boundary
                state.done_ids.add(iid)
                for ref in payload.jobs:
                    job = ref.to_job()
                    self.system.scheduler.mark_failed(job)
                    state.results.append(JobResult(
                        job, False, 0.0, attempts=state.attempts[iid],
                        error=f"invocation failed: "
                              f"{type(e).__name__}: {e}"))
                if fut is not None:
                    fut._set_error(e)
            return
        self.monitor.record(
            payload=payload, result=result, worker_id=result.worker_id,
            retried=inv.get("retried", False),
            speculative=inv.get("speculative", False))
        self._trace_invoke(state, inv, ok=True, worker=result.worker_id)
        if iid in state.done_ids:
            return                    # speculation loser: effects already
        if fut is not None and fut.cancelled:   # deduped by stores
            self._finalize_cancel(state, inv)
            return
        state.done_ids.add(iid)
        if result.spans:
            # stitch the (process) worker's shipped spans under this
            # invocation's pre-allocated invoke span; re-based onto this
            # process's clock at the dispatch instant (worker and invoker
            # monotonic clocks are not comparable)
            get_tracer().absorb(list(result.spans),
                                t_base=inv.get("t_disp"))
        state.durations.append(result.finished_at - result.started_at)
        for ak in inv["aks"]:         # affinity follows success
            self._affinity[ak] = result.worker_id
        state.results.extend(self._absorb(inv, result,
                                          state.attempts[iid]))
        if fut is not None:           # effects are persisted BEFORE the
            fut._set_result(result)   # future completes: streaming reads
            # of a done future's forecasts/versions always hit the stores

    def _maybe_backup(self, state: _Phase) -> None:
        """Straggler resubmission (MapReduce-style backup copies).
        Pointless with a single worker: backends run one action per
        worker at a time, so a backup would just queue behind the very
        straggler it is meant to outrun."""
        if not self.speculative or not state.durations \
                or len(self.backend.worker_ids()) <= 1:
            return
        med = float(np.median(state.durations))
        thresh = max(self.straggler_min_s, self.straggler_factor * med)
        now = time.perf_counter()
        for f, inv in list(state.pending.items()):
            iid = inv["payload"].invocation_id
            t0 = state.started.get(inv["token"])
            if t0 is None or iid in state.done_ids \
                    or state.backups.get(iid) \
                    or state.attempts[iid] > self.max_retries \
                    or now - t0 <= thresh:
                continue
            state.backups[iid] = True
            backup = dict(inv)
            backup["avoid"] = inv["worker"]
            backup["worker"] = self._other_worker(inv["worker"])
            backup["speculative"] = True
            backup["payload"] = replace(inv["payload"],
                                        created_at=time.time())
            self._enqueue(state, backup)

    # ------------------------------------------------ absorption
    def _absorb(self, inv: dict, result: InvocationResult,
                n_attempts: int) -> List[JobResult]:
        """Turn one completed invocation into persisted effects +
        JobResults. Backends whose workers share the invoker's stores
        (inline) have already persisted; artifact-shipping backends
        (process) persist here — idempotently, so replayed or speculative
        duplicates of the same occurrence no-op."""
        if self.backend.wants_artifacts:
            for vr in result.versions:
                self.system.versions.save(
                    vr.deployment_name, vr.model_object,
                    trained_at=vr.trained_at,
                    metadata={"serverless": True,
                              "worker": result.worker_id})
            fcs = []
            for fb in result.forecasts:
                mv = inv["resolved"].get((fb.deployment_name, fb.created_at))
                dep = self.system.deployments.get(fb.deployment_name)
                fcs.append(Forecast(
                    deployment_name=fb.deployment_name, signal=fb.signal,
                    entity=fb.entity, created_at=fb.created_at,
                    times=np.asarray(fb.times),
                    values=np.asarray(fb.values),
                    # the invoker's OWN lineage numbering, not the worker
                    # replica's (their histories can differ)
                    model_version=(mv.version if mv is not None
                                   else fb.model_version),
                    rank=dep.rank,
                    lower=(None if fb.lower is None
                           else np.asarray(fb.lower)),
                    upper=(None if fb.upper is None
                           else np.asarray(fb.upper))))
            if fcs:
                self.system.predictions.save_many(fcs)
            if result.detections:
                from ..flows.detection import DetectionRecord
                self.system.detections.save_many([
                    DetectionRecord(
                        deployment_name=db.deployment_name,
                        signal=db.signal, entity=db.entity,
                        scheduled_at=db.scheduled_at, score=db.score,
                        n_readings=db.n_readings,
                        n_anomalies=db.n_anomalies,
                        band_misses=db.band_misses,
                        model_version=db.model_version,
                        derived_signal=db.derived_signal)
                    for db in result.detections])
        out = []
        for o in result.outcomes:
            job = o.ref.to_job()
            if not o.ok:
                # inline workers marked the shared scheduler already
                # (idempotent set); process workers only marked their own
                self.system.scheduler.mark_failed(job)
            out.append(JobResult(job, o.ok, o.duration_s,
                                 attempts=max(o.attempts, n_attempts),
                                 error=o.error))
        return out


class ServerlessExecutor(Executor):
    """Executor-protocol facade: ``run(jobs) -> List[JobResult]`` like
    LocalPool/Fleet, but through the serverless invocation pipeline.
    Default backend is the deterministic in-process ``InlineBackend``
    (optionally storage-mediated and/or chaos-injected); pass a
    ``ProcessBackend`` for real OS-level containers. ``run_async`` is the
    futures surface; with an ``AutoscalePolicy`` the pool is elastic.
    Long-lived: keep ONE instance across polls so warm-container affinity
    pays (``Castor.serverless_executor()`` does this)."""

    def __init__(self, system, *, backend: Optional[InvocationBackend] = None,
                 n_workers: int = 4, storage=None, chaos=None,
                 autoscale: Optional[AutoscalePolicy] = None,
                 monitor: Optional[InvocationMonitor] = None, **invoker_kw):
        if backend is None:
            backend = InlineBackend(system, n_workers=n_workers,
                                    storage=storage, chaos=chaos)
        elif storage is not None or chaos is not None:
            raise ValueError(
                "storage/chaos apply to the default InlineBackend; "
                "configure an explicit backend directly")
        self.backend = backend
        self.monitor = monitor or InvocationMonitor()
        self.invoker = ServerlessInvoker(system, self.backend,
                                         monitor=self.monitor,
                                         autoscale=autoscale, **invoker_kw)

    def run(self, jobs: List[Job]) -> List[JobResult]:
        return self.invoker.run(jobs)

    def run_async(self, jobs: List[Job]) -> List[ResponseFuture]:
        """Single-phase async submission; see ``ServerlessInvoker.submit``
        and ``repro.serverless.futures.wait``."""
        return self.invoker.submit(jobs)

    def reap_idle(self) -> List[str]:
        """Reap idle-past-TTL containers now (autoscaled executors only;
        no-op otherwise). The invoker also reaps at the end of ``run``."""
        a = self.invoker.autoscaler
        return a.reap_idle() if a is not None else []

    def stats(self) -> dict:
        out = self.monitor.summary()
        out["workers"] = len(self.backend.worker_ids())
        if self.invoker.autoscaler is not None:
            out["autoscale"] = self.invoker.autoscaler.summary()
        chaos = getattr(self.backend, "chaos", None)
        if chaos is not None:
            out["chaos"] = chaos.summary()
        storage = getattr(self.backend, "storage", None)
        if storage is not None:
            out["storage"] = storage.stats()
        return out

    def close(self) -> None:
        self.backend.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
