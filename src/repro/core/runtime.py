"""FleetRuntime: persistent device-resident per-bin state for the
steady-state poll hot path.

The paper's workload is rolling-horizon operation: thousands of deployed
models re-scored every cycle against a window that slides by a handful of
rows per poll. The cold fleet path re-reads the whole train window from
the store, realigns it, rebuilds lag/design matrices row-by-row in host
numpy and re-uploads everything — O(history) work for O(1) new data.

``FleetRuntime`` makes the warm poll O(delta) with three coordinated
layers (one object per ``FleetExecutor``; opt out per deployment with
``user_params["runtime"] = "off"`` or executor-wide with
``FleetExecutor(system, runtime="off")``):

* **Watermark-delta loads.** Per bin, the aligned target history lives in
  a device ring buffer ``(N_bucket, cap)`` next to a boolean *filled*
  mask. A poll reads only ``[watermark, now)`` from the store
  (``read_many(since=..., prior_counts=True)`` — O(log n + delta), no
  consolidation pass) and rolls the new rows in with ONE jitted update
  (ring buffers donated, so the update is in-place off-CPU). The
  ``prior_counts`` handshake proves no out-of-order append landed behind
  the watermark; if one did, the bin cold-rebuilds.
* **On-device feature assembly.** Warm train polls assemble the
  lag/weather/calendar design matrix, per-instance standardization
  included, in one jitted program over the ring — the host numpy
  row-stacking of ``design_matrix``/``transform`` disappears from the
  loop. The numpy path remains the cold/reference path, same contract as
  the scoring rollout's host fallback.
* **Shape-bucketed programs.** The ring's instance axis is padded to its
  power-of-two bucket (edge replication), so the update/assembly/rollout
  programs are shared by nearby bin sizes: a bin that loses a job (failed
  deployment, removed sensor) re-uses every warm compilation.

Window-relative fill semantics are preserved EXACTLY: the cold aligner
forward-fills gaps only from inside ``[t0, now)`` and zero-fills before
the first in-window point, while the ring's fill chain may reach back
before ``t0``. The *filled* mask restores cold semantics at read time
(``y = where(any fill in window so far, ring, 0)``), so a sensor going
silent across the window boundary cannot diverge the two paths.

A cached bin is invalidated (cold-rebuilt) when: the deployment set /
spec / window length changes (different state key), ``now`` regresses or
is not a whole number of steps past the watermark, a late append lands
behind the watermark, or the delta spans the whole window.

History weather rides in a third ring: history features use OBSERVED
temperatures (deterministic per site/time — see the fleet_load note in
forecast/base.py), so a warm poll computes only the ``d`` new columns
with one vectorized ``temperature_many`` call. Horizon weather is a
forecast issued at scoring time and is the single per-poll weather call
that cannot be cached (``forecast_many``, one call per bin).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..forecast.features import (FeatureSpec, align_delta, bucket_n,
                                 edge_pad, fleet_window, note_trace)
from ..timeseries.transforms import DAY, calendar_features, regular_grid

#: jitted ring updates / assemblies, keyed by static config (shapes key
#: the underlying jit cache); LRU-bounded like the rollout cache — a
#: long-lived server cycling many specs must not pin every compilation
from ..forecast.base import _LRUCache

_UPDATE_FNS = _LRUCache(cap=64)
_ASSEMBLE_FNS = _LRUCache(cap=64)


def _cached_program(cache: _LRUCache, key, build):
    fn = cache.get(key)
    if fn is None:
        fn = cache.put(key, build())
    return fn


def _make_update(d: int, T: int, warm_s: int):
    """One jitted program per (delta steps, window length, score warmup):
    roll the target/filled/temperature rings left by ``d``, forward-fill
    the new target columns from the previous ring column (the value the
    cold aligner would have propagated), and emit the window-masked
    target matrix plus the trailing score windows — a warm score poll
    reads the update's outputs directly, with no further device ops
    before the rollout dispatch. Ring buffers are donated — the
    steady-state poll updates in place instead of doubling residency."""
    import jax
    import jax.numpy as jnp

    def upd(ring, filled, ring_t, vals, mask, tvals):
        note_trace("ring_update")    # Python body runs only while tracing

        def ff(prev, xs):
            v, m = xs
            cur = jnp.where(m, v, prev)
            return cur, cur

        _, new = jax.lax.scan(ff, ring[:, -1], (vals.T, mask.T))
        ring = jnp.concatenate([ring[:, d:], new.T], axis=1)
        filled = jnp.concatenate([filled[:, d:], mask], axis=1)
        ring_t = jnp.concatenate([ring_t[:, d:], tvals], axis=1)
        win_f = filled[:, -T:]
        seen = jnp.cumsum(win_f, axis=1) > 0
        y_win = jnp.where(seen, ring[:, -T:], jnp.float32(0.0))
        return (ring, filled, ring_t, y_win,
                y_win[:, -warm_s:], ring_t[:, -warm_s:])

    donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
    return jax.jit(upd, donate_argnums=donate)


def _make_assemble(spec: FeatureSpec, T: int):
    """Jitted twin of ``design_matrix`` + ``transform`` over a whole bin:
    lag stacking is pure gathering (bitwise the host values), calendar
    features arrive precomputed on the host (float64 reduction, then one
    f32 cast — the same cast point as the cold path), and per-instance
    standardization runs in f32 on device (the one place warm and cold
    differ, at f32 epsilon)."""
    import jax
    import jax.numpy as jnp

    tl, wl = spec.target_lags, spec.weather_lags
    warm = max(tl, wl if spec.use_weather else 0)

    def asm(y_win, temps, cal):      # (N,T) f32, (N,T) f32, (T,5) f32
        note_trace("assemble")
        cols = [y_win[:, warm - L: T - L] for L in range(1, tl + 1)]
        if spec.use_weather:
            cols.append(temps[:, warm:])
            cols.extend(temps[:, warm - L: T - L] for L in range(1, wl + 1))
        parts = [jnp.stack(cols, axis=-1)]
        if spec.use_calendar:
            parts.append(jnp.broadcast_to(
                cal[warm:], (y_win.shape[0], T - warm, 5)))
        X = jnp.concatenate(parts, axis=-1)
        y = y_win[:, warm:]
        mu = X.mean(axis=1)
        sd = X.std(axis=1) + 1e-8
        Xs = (X - mu[:, None, :]) / sd[:, None, :]
        return Xs, y, mu, sd

    return jax.jit(asm)


@dataclass
class _BinState:
    key: tuple
    ids: Tuple[str, ...]
    sites: Any                       # weather SiteBatch (fixed per bin)
    spec: FeatureSpec
    T: int                           # window length in steps
    cap: int                         # ring capacity (bucketed >= T)
    n: int
    n_pad: int
    t0: float                        # window start (now - train_window)
    t_hi: float                      # watermark: end of aligned history
    prior: np.ndarray                # per-series store count < t_hi
    ring: Any = None                 # device (n_pad, cap) f32 targets
    filled: Any = None               # device (n_pad, cap) bool
    ring_t: Any = None               # device (n_pad, cap) f32 temperatures
    y_win: Any = None                # device (n_pad, T) f32, window-masked
    y_tail: Any = None               # device (n_pad, warm_s) score window
    t_tail: Any = None               # device (n_pad, warm_s) temp window
    targets_host: Optional[np.ndarray] = None   # f64 rows (cold train path)
    temps_host: Optional[np.ndarray] = None     # f64 rows (cold train path)
    #: (ids(mo), stacked_dev, mu_dev, sd_dev, refs) — refs keep the
    #: matched dicts alive so the id tuple cannot alias recycled objects
    trained: Optional[tuple] = None
    param_cache: Optional[tuple] = None


class FleetRuntime:
    """Owns per-bin device state across polls; created by ``FleetExecutor``
    and threaded into ``fleet_train`` / ``fleet_score`` of models that set
    ``SUPPORTS_RUNTIME``. Every public entry returns None to send the
    caller down the unchanged cold path."""

    def __init__(self, system, *, max_states: int = 32,
                 max_delta_steps: int = 512):
        self.system = system
        self.max_states = int(max_states)
        self.max_delta_steps = int(max_delta_steps)
        self._states: "OrderedDict[tuple, _BinState]" = OrderedDict()
        self._no_rollout: set = set()    # (cls, spec) with no device predictor
        self.last_stats: Dict[str, Any] = {}
        # lifetime counters (benchmarks/tests)
        self.cold_loads = 0
        self.warm_loads = 0
        self.invalidations = 0

    # ------------- telemetry -------------
    def _note(self, mode: str, delta_rows: int, reason: str = "") -> None:
        self.last_stats = {"runtime": mode, "cache_hit": mode == "warm",
                           "delta_rows": delta_rows}
        if reason:
            self.last_stats["runtime_reason"] = reason

    def pop_stats(self) -> Dict[str, Any]:
        out, self.last_stats = self.last_stats, {}
        return out

    # ------------- bin loading -------------
    @staticmethod
    def _merged(cls, instances) -> dict:
        return {**cls.DEFAULTS, **instances[0].user_params}

    def _load(self, cls, instances, up) -> Optional[_BinState]:
        if str(up.get("runtime", "on")).lower() == "off":
            self._note("off", 0)
            return None
        spec = FeatureSpec.from_params(up)
        now = float(up.get("now", 0.0))
        # a bin shares ONE window (executor bins share user_params_key, so
        # the dicts are equal); direct callers mixing nows/params fall
        # back to the cold path (which groups / fails loudly as designed)
        first = instances[0].user_params
        for inst in instances[1:]:
            if inst.user_params != first:
                self._note("cold", 0, "mixed bin params")
                return None
        step = spec.step
        t0 = now - float(up["train_window_days"]) * DAY
        T = regular_grid(t0, now, step).size
        if abs(T * step - (now - t0)) > 1e-6 * step:
            # a window that is not a whole number of steps makes the cold
            # grid origin and the ring watermark live on different bin
            # lattices — stay on the cold path rather than risk off-by-eps
            # bin assignment for boundary points
            self._note("cold", 0, "fractional window")
            return None
        ids = tuple(inst.context.ts_id for inst in instances)
        key = (ids, spec, T)
        state = self._states.get(key)
        if state is not None:
            self._states.move_to_end(key)
            if now == state.t_hi:                       # same-poll re-use
                self._note("warm", 0)
                return state
            if now > state.t_hi:
                k = (now - state.t_hi) / step
                d = int(round(k))
                aligned = d >= 1 and abs(k - d) < 1e-9 * max(1.0, abs(k))
                if aligned and d < min(T, self.max_delta_steps):
                    got = self._advance(state, d, t0, now)
                    if got is not None:
                        self._note("warm", d)
                        return got
                    reason = "late data behind watermark"
                elif aligned:
                    reason = "delta spans window"
                else:
                    reason = "misaligned now"
            else:
                reason = "now regression"
            self.invalidations += 1
            del self._states[key]
        else:
            reason = "first load"
        state = self._build(key, ids, instances, spec, t0, now, T)
        self._note("cold", T, reason)
        return state

    def _advance(self, state: _BinState, d: int, t0: float, now: float
                 ) -> Optional[_BinState]:
        """Watermark-delta poll: one O(log n + delta) store read, one
        jitted ring update. Returns None when a late append invalidates."""
        raw, prior = self.system.store.read_many(
            state.ids, end=now, since=state.t_hi, prior_counts=True)
        if not np.array_equal(prior, state.prior):
            return None                 # out-of-order append behind watermark
        vals, mask = align_delta(raw, state.t_hi, now, state.spec.step)
        pad = state.n_pad - state.n
        vals32 = edge_pad(vals.astype(np.float32), pad)
        mask_p = edge_pad(mask, pad)
        if state.spec.use_weather:      # observed temps at the d new steps
            tnew = state.sites.temperature(
                state.t_hi + state.spec.step * np.arange(d))
            tnew = edge_pad(tnew.astype(np.float32), pad)
        else:
            tnew = np.zeros((state.n_pad, d), np.float32)
        warm_s = max(state.spec.target_lags, state.spec.weather_lags) + 1
        upd = _cached_program(_UPDATE_FNS, (d, state.T, warm_s),
                              partial(_make_update, d, state.T, warm_s))
        (state.ring, state.filled, state.ring_t, state.y_win,
         state.y_tail, state.t_tail) = upd(
            state.ring, state.filled, state.ring_t, vals32, mask_p, tnew)
        state.prior = prior + np.asarray([t.size for t, _ in raw], np.int64)
        state.t0, state.t_hi = t0, now
        state.targets_host = state.temps_host = None   # cold-build only
        self.warm_loads += 1
        return state

    def _build(self, key, ids, instances, spec: FeatureSpec, t0: float,
               now: float, T: int) -> _BinState:
        """Cold build: one full-window batched read (the same one the cold
        path issues) plus one vectorized observed-temperature call;
        host-aligned rows kept in f64 for the cold train path, rings
        uploaded once."""
        from ..obs.trace import get_tracer
        with get_tracer().span("runtime.build", n=len(ids)):
            return self._build_inner(key, ids, instances, spec, t0, now, T)

    def _build_inner(self, key, ids, instances, spec: FeatureSpec,
                     t0: float, now: float, T: int) -> _BinState:
        import jax.numpy as jnp
        ctxs = [inst.context for inst in instances]
        grid, targets, mask, prior = fleet_window(
            self.system, ctxs, t0, now, spec.step)
        ents = [c.entity for c in ctxs]
        sites = self.system.weather.sites([e.lat for e in ents],
                                          [e.lon for e in ents])
        n = len(ids)
        temps = sites.temperature(grid) if spec.use_weather \
            else np.zeros((n, T))
        n_pad = bucket_n(n)
        cap = bucket_n(T)
        ring_h = np.zeros((n, cap), np.float32)
        fill_h = np.zeros((n, cap), bool)
        temp_h = np.zeros((n, cap), np.float32)
        ring_h[:, cap - T:] = targets.astype(np.float32)
        fill_h[:, cap - T:] = mask
        temp_h[:, cap - T:] = temps.astype(np.float32)
        ring = jnp.asarray(edge_pad(ring_h, n_pad - n))
        filled = jnp.asarray(edge_pad(fill_h, n_pad - n))
        ring_t = jnp.asarray(edge_pad(temp_h, n_pad - n))
        warm_s = max(spec.target_lags, spec.weather_lags) + 1
        state = _BinState(key=key, ids=ids, sites=sites, spec=spec, T=T,
                          cap=cap, n=n, n_pad=n_pad, t0=t0, t_hi=now,
                          prior=prior, ring=ring, filled=filled,
                          ring_t=ring_t, y_win=ring[:, cap - T:],
                          y_tail=ring[:, cap - warm_s:],
                          t_tail=ring_t[:, cap - warm_s:],
                          targets_host=targets, temps_host=temps)
        self._states[key] = state
        while len(self._states) > self.max_states:
            self._states.popitem(last=False)
        self.cold_loads += 1
        return state

    # ------------- training -------------
    def fleet_xy(self, cls, instances) -> Optional[tuple]:
        """Replacement for ``ForecastModelBase._fleet_xy``: returns
        ``(X, y, mu, sd, state)`` or None (cold path). A freshly built
        state answers with the EXACT host-f64 design-matrix path (single
        polls stay bitwise-identical to the pre-runtime executor); warm
        states assemble on device from the ring."""
        up = self._merged(cls, instances)
        state = self._load(cls, instances, up)
        if state is None:
            return None
        spec, T, n = state.spec, state.T, state.n
        if state.targets_host is not None:      # cold build this poll
            from ..forecast.features import design_matrix
            grid = regular_grid(state.t0, state.t_hi, spec.step)
            Xs, ys, mus, sds = [], [], [], []
            for i in range(n):
                X, y = design_matrix(spec, grid, state.targets_host[i],
                                     state.temps_host[i])
                mu, sd = X.mean(0), X.std(0) + 1e-8
                Xs.append((X - mu) / sd)
                ys.append(y), mus.append(mu), sds.append(sd)
            return (np.stack(Xs), np.stack(ys), np.stack(mus),
                    np.stack(sds), state)
        import jax.numpy as jnp
        grid = regular_grid(state.t0, state.t_hi, spec.step)
        cal = calendar_features(grid).astype(np.float32) \
            if spec.use_calendar else np.zeros((T, 5), np.float32)
        asm = _cached_program(_ASSEMBLE_FNS, (spec, T),
                              partial(_make_assemble, spec, T))
        X, y, mu, sd = asm(state.y_win, state.ring_t[:, state.cap - T:],
                           jnp.asarray(cal))
        return X[:n], y[:n], mu[:n], sd[:n], state

    def note_trained(self, state: _BinState, params, mu, sd, out) -> None:
        """Train->score handoff: remember the stacked DEVICE params against
        the identity of the per-instance model objects just persisted, so
        a same-cycle (or any later) score poll of those versions never
        re-uploads or re-stacks them. The dicts themselves ride along in
        the tuple: identity matching is only sound while the matched
        objects are provably alive (a deduplicated retrain discards the
        fresh dicts, and a recycled address must never alias them)."""
        state.trained = (tuple(id(mo) for mo in out), params, mu, sd, out)
        state.param_cache = None

    # ------------- scoring -------------
    def _stacked(self, state: _BinState, model_objects) -> tuple:
        import jax.numpy as jnp
        key = tuple(id(mo) for mo in model_objects)
        # id-tuple matching is sound because both caches hold the matched
        # dicts alive (last element), so an id cannot be recycled to a
        # different live object
        if state.param_cache is not None and state.param_cache[0] == key:
            _, stacked, mu, sd, _ = state.param_cache
            return stacked, mu, sd
        if state.trained is not None and state.trained[0] == key:
            _, stacked, mu, sd, _ = state.trained
        else:                            # stack once, then cache
            stacked = {k: np.stack([m["params"][k] for m in model_objects])
                       for k in model_objects[0]["params"]}
            mu = np.stack([m["mu"] for m in model_objects])
            sd = np.stack([m["sd"] for m in model_objects])
        # device-resident AND bucket-padded from here on: later warm polls
        # dispatch the rollout without re-uploading or re-padding a single
        # parameter
        pad = state.n_pad - state.n
        stacked = {k: edge_pad(jnp.asarray(v), pad)
                   for k, v in stacked.items()}
        mu = edge_pad(jnp.asarray(mu, jnp.float32), pad)
        sd = edge_pad(jnp.asarray(sd, jnp.float32), pad)
        state.param_cache = (key, stacked, mu, sd, list(model_objects))
        return stacked, mu, sd

    def fleet_score(self, cls, instances, model_objects, *,
                    mesh=None) -> Optional[list]:
        """Device-resident scoring: trailing windows come from the ring
        (no store read, no host stacking), params from the train handoff
        or a once-per-version stacking. Returns None to fall back to the
        cold path (runtime off, host rollout requested, no traceable
        predictor, or a bin the runtime cannot key)."""
        up = self._merged(cls, instances)
        if up.get("rollout", "device") == "host":
            self._note("off", 0, "host rollout requested")
            return None
        if len(model_objects) != len(instances):
            return None
        spec0 = FeatureSpec.from_params(up)
        if (cls, spec0) in self._no_rollout:
            # a host-only model (no traceable predictor) must not pay ring
            # maintenance AND the cold path every poll
            self._note("off", 0, "no device predictor")
            return None
        state = self._load(cls, instances, up)
        if state is None:
            return None
        spec, n = state.spec, state.n
        H = int(up["horizon"])
        now = state.t_hi
        stacked, mu, sd = self._stacked(state, model_objects)
        # all inputs pre-padded to the shape bucket: the rollout's own
        # bucketing becomes a no-op and the only per-poll host work left
        # is the horizon weather
        fut_t = now + spec.step * np.arange(0, H)
        temps_future = edge_pad(state.sites.forecast(now, fut_t),
                                state.n_pad - n)
        vals = cls._device_rollout(spec, up, stacked, mu, sd, state.y_tail,
                                   state.t_tail, temps_future,
                                   float(fut_t[0]), H, mesh=mesh)
        if vals is None:                 # no traceable predictor: remember
            self._no_rollout.add((cls, spec0))
            return None
        return [(fut_t, vals[i]) for i in range(n)]
