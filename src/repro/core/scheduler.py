"""Model scheduling (paper §2 step (7)): periodically load registered
deployments, decide which are due for training/scoring, and emit jobs.

Jobs carry a *bin key* so the fleet executor can megabatch identical
(implementation, task) work — the TPU-native analogue of launching
thousands of serverless containers (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Schedule:
    """Start time + repeat interval, both in epoch seconds."""
    start: float
    every: float

    def occurrences_due(self, last_run: Optional[float], now: float) -> int:
        """How many firings are due in (last_run, now]."""
        if now < self.start:
            return 0
        k_now = int((now - self.start) // self.every)       # latest index due
        if last_run is None:
            return 1                                        # fire once, catch up
        if last_run < self.start:
            return k_now + 1
        k_last = int((last_run - self.start) // self.every)
        return max(0, k_now - k_last)

    def boundaries_due(self, last_run: Optional[float], now: float,
                       limit: Optional[int] = None) -> List[float]:
        """The due occurrences' scheduled boundary timestamps
        (start + k*every), oldest first; with ``limit``, the most recent
        ones. Count and stamps come from the SAME flooring arithmetic, so
        they cannot disagree."""
        due = self.occurrences_due(last_run, now)
        if due <= 0:
            return []
        if limit:
            due = min(due, limit)
        k_now = int((now - self.start) // self.every)
        return [self.start + k * self.every
                for k in range(k_now - due + 1, k_now + 1)]


@dataclass(frozen=True)
class Job:
    deployment_name: str
    package: str
    version: str                    # RESOLVED version (registry pinned at poll)
    task: str                       # "train" | "score"
    scheduled_at: float
    signal: str
    entity: str
    user_params_key: str = ""       # part of the bin key (same config batches)

    @property
    def bin_key(self) -> Tuple[str, str, str, str, float]:
        # scheduled_at is part of the key: a fleet score bin shares ONE
        # execution time axis (ForecastModelBase._require_one_window), so
        # catch-up occurrences stamped at different boundaries must land in
        # different bins instead of poisoning one megabatch
        return (self.package, self.version, self.task, self.user_params_key,
                self.scheduled_at)


class ModelScheduler:
    """Tracks last-run state per (deployment, task) and emits due jobs.

    ``max_catchup`` bounds how many occurrences ONE poll may emit per
    (deployment, task) — queued failure retries and newly missed
    boundaries combined: a live poller that stalled for weeks, or a
    permanently failing deployment whose every occurrence re-queues, must
    not turn polling into an unbounded replay storm (each occurrence is a
    full megabatch bin). The most recent boundaries win; older ones are
    dropped. Set it falsy for unlimited replay."""

    def __init__(self, deployments, registry, *,
                 max_catchup: Optional[int] = 168):
        self.deployments = deployments
        self.registry = registry
        self.max_catchup = max_catchup
        self._last: Dict[Tuple[str, str], float] = {}
        self._failed: Dict[Tuple[str, str], set] = {}   # scheduled_at stamps
        # next boundary due, memoized WITH the schedule that computed it:
        # a redeployed/edited schedule (Schedule is a frozen value type)
        # fails the equality check and falls back to the full boundary
        # arithmetic, so the fast path can never suppress a changed cadence
        self._next: Dict[Tuple[str, str], Tuple[Schedule, float]] = {}
        # params-key memo per user_params dict identity: repr-ing every
        # deployment's params dict on every poll was measurable on the
        # steady-state hot path. The memo holds a snapshot COPY and
        # re-validates with a (cheap) dict equality, so both a swapped
        # dict (new id) and an in-place mutation recompute the key.
        self._pk: Dict[int, Tuple[dict, str]] = {}

    def _params_key(self, params: dict) -> str:
        hit = self._pk.get(id(params))
        if hit is not None and hit[0] == params:
            return hit[1]
        if len(self._pk) > 4096:
            self._pk.clear()
        k = _params_key(params)
        self._pk[id(params)] = (dict(params), k)
        return k

    def poll(self, now: float) -> List[Job]:
        """The poll is ATOMIC: watermarks advance and queued retries clear
        only after every due deployment's registry lookup has succeeded —
        a raising lookup (e.g. a deployment of a never-published package)
        leaves ALL per-deployment state untouched, so no occurrence can be
        emitted into a poll that then throws the jobs away."""
        jobs: List[Job] = []
        planned: List[tuple] = []        # (dep, task, key, stamps, advance, version)
        for dep in self.deployments.all():
            for task in ("train", "score"):
                sched: Optional[Schedule] = getattr(dep, task)
                if sched is None:
                    continue
                key = (dep.name, task)
                # steady-state fast path: nothing due and nothing queued
                # for retry — skip the boundary arithmetic entirely (a
                # large fleet walks every (deployment, task) per poll).
                # Only valid while the schedule that computed the memoized
                # boundary is still the deployment's schedule.
                nxt = self._next.get(key)
                if nxt is not None and nxt[0] == sched and now < nxt[1] \
                        and key not in self._failed:
                    continue
                # one job PER missed occurrence, stamped at its scheduled
                # boundary — forecasts and model versions must carry
                # lineage timestamps of when the work was DUE, not
                # whenever the poll happened to run (Castor persists
                # rolling-horizon predictions at their scheduled times) —
                # plus failed occurrences re-firing at their ORIGINAL
                # boundaries
                new = sched.boundaries_due(self._last.get(key), now,
                                           self.max_catchup)
                stamps = sorted(self._failed.get(key, ())) + new
                if not stamps:
                    continue
                if self.max_catchup:
                    # retries + new boundaries share the cap (stamps are
                    # chronological: queued retries predate new ones)
                    stamps = stamps[-self.max_catchup:]
                version = self.registry.resolve_version(dep.package, dep.version)
                planned.append((dep, task, key, sched, stamps, bool(new),
                                version))
        # every lookup succeeded: commit state and emit
        for dep, task, key, sched, stamps, advance, version in planned:
            self._failed.pop(key, None)
            if advance:
                self._last[key] = now
                k_now = int((now - sched.start) // sched.every)
                self._next[key] = (sched,
                                   sched.start + (k_now + 1) * sched.every)
            for ts in dict.fromkeys(stamps):
                jobs.append(Job(
                    deployment_name=dep.name, package=dep.package,
                    version=version, task=task, scheduled_at=ts,
                    signal=dep.signal, entity=dep.entity,
                    user_params_key=self._params_key(dep.user_params)))
        # deterministic order: training before scoring, then chronological
        # (catch-up occurrences execute oldest first), then by name
        jobs.sort(key=lambda j: (j.task != "train", j.scheduled_at,
                                 j.deployment_name))
        return jobs

    def mark_failed(self, job: Job):
        """The failed job re-fires on the next poll at its ORIGINAL
        occurrence boundary (at-least-once per occurrence). Queuing the
        stamp — rather than resetting the deployment's whole watermark —
        means one failed catch-up occurrence cannot be collapsed away by
        its siblings' success and then silently deduplicated against the
        idempotent version/prediction stores."""
        self._failed.setdefault((job.deployment_name, job.task),
                                set()).add(job.scheduled_at)


def _params_key(params: dict) -> str:
    return repr(sorted(params.items()))


def bin_jobs(jobs: List[Job]) -> Dict[Tuple, List[Job]]:
    bins: Dict[Tuple, List[Job]] = {}
    for j in jobs:
        bins.setdefault(j.bin_key, []).append(j)
    return bins
