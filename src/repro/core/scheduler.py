"""Model scheduling (paper §2 step (7)): periodically load registered
deployments, decide which are due for training/scoring, and emit jobs.

Jobs carry a *bin key* so the fleet executor can megabatch identical
(implementation, task) work — the TPU-native analogue of launching
thousands of serverless containers (DESIGN.md §2).

Scale architecture (million-deployment control plane): the scheduler is
a **calendar queue** — a heap of wake-up entries ``(due_time, generation,
name, task)`` — not a fleet scanner. ``poll(now)`` pops only entries with
``due <= now``, so a steady-state poll costs O(due · log fleet), flat in
fleet size. Invariants:

* each live ``(deployment, task)`` owns one *boundary* entry armed at its
  next not-yet-emitted occurrence; it is re-armed on every emit;
* ``mark_failed`` pushes a transient *retry* entry at the failed stamp
  (<= now, so the very next poll wakes the deployment up);
* entries are invalidated lazily through a per-name generation counter:
  ``DeploymentStore.remove`` bumps it (via the store's listener protocol,
  which also eagerly clears watermarks and queued retries), so a
  re-registered same-name deployment starts from scratch instead of
  inheriting stale wake-ups — and a schedule edit (remove + re-register
  with a new ``Schedule``) re-keys the calendar entry;
* duplicate entries are benign: poll de-duplicates per (name, task) at
  pop time, and all of one key's stale duplicates collapse when they pop.

Bin keys are additionally interned to dense ints (``Job.bin_id``) so
``bin_jobs`` groups with one numpy argsort over an integer axis instead
of hashing tuples of strings per job.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .interning import InternTable


@dataclass(frozen=True)
class Schedule:
    """Start time + repeat interval, both in epoch seconds."""
    start: float
    every: float

    def index_at(self, t: float) -> int:
        """Largest occurrence index ``k >= -1`` with
        ``start + k*every <= t``.

        The f64 quotient ``(t - start) / every`` can floor one step high
        or low for large ``t`` / small ``every`` (its rounding error
        exceeds the gap to the next integer), which skipped or
        double-fired boundaries — the same drift class PR 5 fixed in
        ``Castor.run_until``. The estimate is therefore corrected against
        the boundary lattice *itself*: the returned index is exact for
        the float values ``start + k*every``, the very expression
        ``boundaries_due`` stamps jobs with, so count, stamps, and the
        next-due wake-up can never disagree."""
        if t < self.start:
            return -1
        k = int((t - self.start) // self.every)
        # each loop runs O(1) times: the estimate is within a few ULPs
        while self.start + (k + 1) * self.every <= t:
            k += 1
        while k > 0 and self.start + k * self.every > t:
            k -= 1
        return k

    def occurrences_due(self, last_run: Optional[float], now: float) -> int:
        """How many firings are due in (last_run, now]."""
        k_now = self.index_at(now)
        if k_now < 0:
            return 0
        if last_run is None:
            return 1                                    # fire once, catch up
        if last_run < self.start:
            return k_now + 1
        return max(0, k_now - self.index_at(last_run))

    def boundaries_due(self, last_run: Optional[float], now: float,
                       limit: Optional[int] = None) -> List[float]:
        """The due occurrences' scheduled boundary timestamps
        (start + k*every), oldest first; with ``limit``, the most recent
        ones. Count and stamps come from the SAME lattice-corrected
        arithmetic (``index_at``), so they cannot disagree."""
        due = self.occurrences_due(last_run, now)
        if due <= 0:
            return []
        if limit:
            due = min(due, limit)
        k_now = self.index_at(now)
        return [self.start + k * self.every
                for k in range(k_now - due + 1, k_now + 1)]

    def next_boundary_after(self, t: float) -> float:
        """The first boundary strictly after ``t`` (``start`` when
        ``t < start``) — what the calendar queue arms wake-ups at."""
        return self.start + (self.index_at(t) + 1) * self.every


# ------------------------------------------------------------------ jobs

#: every schedulable task name, in dependency order: trains feed scores
#: (a scoring job may consume the version trained this cycle) and scores
#: feed detects (a detection compares against the band scored this cycle)
TASKS = ("train", "score", "detect")
_TASK_ORDER = {t: i for i, t in enumerate(TASKS)}

#: process-wide intern table for bin keys; ids are what the executors,
#: the serverless invoker and the vectorized grouping below operate on
BIN_KEYS = InternTable()


def intern_bin_key(key: Tuple) -> int:
    return BIN_KEYS.intern(key)


def bin_key_of(bin_id: int) -> Tuple:
    """The human-readable bin-key tuple behind an interned id."""
    return BIN_KEYS.value(bin_id)


@dataclass(frozen=True)
class Job:
    deployment_name: str
    package: str
    version: str                    # RESOLVED version (registry pinned at poll)
    task: str                       # "train" | "score" | "detect"
    scheduled_at: float
    signal: str
    entity: str
    user_params_key: str = ""       # part of the bin key (same config batches)

    @property
    def bin_key(self) -> Tuple[str, str, str, str, float]:
        # scheduled_at is part of the key: a fleet score bin shares ONE
        # execution time axis (ForecastModelBase._require_one_window), so
        # catch-up occurrences stamped at different boundaries must land in
        # different bins instead of poisoning one megabatch
        return (self.package, self.version, self.task, self.user_params_key,
                self.scheduled_at)

    @property
    def bin_id(self) -> int:
        """Interned dense-int twin of ``bin_key`` (memoized per job):
        equal bin keys <=> equal ints, for this process's lifetime."""
        bid = self.__dict__.get("_bin_id")
        if bid is None:
            bid = BIN_KEYS.intern(self.bin_key)
            object.__setattr__(self, "_bin_id", bid)
        return bid


class ModelScheduler:
    """Calendar-queue scheduler: tracks last-run state per
    (deployment, task) and emits due jobs by popping the wake-up heap
    (see the module docstring for the queue invariants).

    ``max_catchup`` bounds how many occurrences ONE poll may emit per
    (deployment, task) — queued failure retries and newly missed
    boundaries combined: a live poller that stalled for weeks, or a
    permanently failing deployment whose every occurrence re-queues, must
    not turn polling into an unbounded replay storm (each occurrence is a
    full megabatch bin). The most recent boundaries win; older ones are
    dropped. Set it falsy for unlimited replay."""

    def __init__(self, deployments, registry, *,
                 max_catchup: Optional[int] = 168):
        self.deployments = deployments
        self.registry = registry
        self.max_catchup = max_catchup
        self._last: Dict[Tuple[str, str], float] = {}
        self._failed: Dict[Tuple[str, str], set] = {}   # scheduled_at stamps
        self._heap: List[Tuple[float, int, str, str]] = []
        self._gen: Dict[str, int] = {}      # name -> live entry generation
        # (name, task) keys whose watermark/retry state changed since the
        # last drain — what the durability journal persists per tick as
        # ONE atomic "sched" record (see drain_dirty)
        self._dirty: set = set()
        # params-key memo per user_params dict identity: repr-ing every
        # deployment's params dict on every poll was measurable on the
        # steady-state hot path. The memo holds a snapshot COPY and
        # re-validates with a (cheap) dict equality, so both a swapped
        # dict (new id) and an in-place mutation recompute the key.
        self._pk: Dict[int, Tuple[dict, str]] = {}
        # the store pushes register/remove events at us so the queue stays
        # incremental; a pre-populated store seeds the queue here
        subscribe = getattr(deployments, "subscribe", None)
        if subscribe is not None:
            subscribe(self)
        for dep in deployments.all():
            self.on_register(dep)

    # ------------------- deployment-store listener protocol -------------
    def on_register(self, dep) -> None:
        """Arm a wake-up at each schedule's start: ``occurrences_due(None,
        now)`` fires exactly when ``now >= start``, which is exactly when
        the entry pops."""
        for task in TASKS:
            sched: Optional[Schedule] = getattr(dep, task, None)
            if sched is not None:
                self._push(sched.start, dep.name, task)

    def on_remove(self, name: str) -> None:
        """Clear ALL scheduler state keyed by the removed deployment:
        watermarks, queued failure stamps, and (lazily, via the generation
        bump) heap entries. Without this, re-registering a same-name
        deployment inherited the old watermark — so it never caught up
        from scratch — and replayed the removed deployment's queued
        retries against the new one's schedules."""
        self._gen[name] = self._gen.get(name, 0) + 1
        for task in TASKS:
            self._last.pop((name, task), None)
            self._failed.pop((name, task), None)
            self._dirty.discard((name, task))   # "rmdep" subsumes the delta

    def _push(self, due: float, name: str, task: str) -> None:
        heapq.heappush(self._heap,
                       (due, self._gen.get(name, 0), name, task))

    def _params_key(self, params: dict) -> str:
        hit = self._pk.get(id(params))
        if hit is not None and hit[0] == params:
            return hit[1]
        if len(self._pk) > 4096:
            self._pk.clear()
        k = _params_key(params)
        self._pk[id(params)] = (dict(params), k)
        return k

    def poll(self, now: float) -> List[Job]:
        """The poll is ATOMIC: watermarks advance, queued retries clear
        and wake-ups re-arm only after every due deployment's registry
        lookup has succeeded — a raising lookup (e.g. a deployment of a
        never-published package) pushes every popped entry back and
        leaves ALL per-deployment state untouched, so no occurrence can
        be emitted into a poll that then throws the jobs away."""
        from ..obs.trace import get_tracer
        tracer = get_tracer()
        if not tracer.enabled:
            return self._poll(now)
        with tracer.span("scheduler.poll", now=now) as sp:
            jobs = self._poll(now)
            sp.set(jobs=len(jobs))
            return jobs

    def _poll(self, now: float) -> List[Job]:
        heap = self._heap
        popped: List[Tuple[float, int, str, str]] = []  # for atomic restore
        keys: Dict[Tuple[str, str], bool] = {}          # de-dup, pop order
        while heap and heap[0][0] <= now:
            entry = heapq.heappop(heap)
            _due, gen, name, task = entry
            if gen != self._gen.get(name, 0) \
                    or name not in self.deployments:
                continue                    # stale entry: drop forever
            popped.append(entry)
            keys[(name, task)] = True
        jobs: List[Job] = []
        planned: List[tuple] = []   # (dep, task, key, sched, stamps, adv, ver)
        try:
            for name, task in keys:
                dep = self.deployments.get(name)
                sched: Optional[Schedule] = getattr(dep, task, None)
                key = (name, task)
                if sched is None:           # schedule dropped since arming
                    continue
                # one job PER missed occurrence, stamped at its scheduled
                # boundary — forecasts and model versions must carry
                # lineage timestamps of when the work was DUE, not
                # whenever the poll happened to run (Castor persists
                # rolling-horizon predictions at their scheduled times) —
                # plus failed occurrences re-firing at their ORIGINAL
                # boundaries
                new = sched.boundaries_due(self._last.get(key), now,
                                           self.max_catchup)
                stamps = sorted(self._failed.get(key, ())) + new
                if self.max_catchup:
                    # retries + new boundaries share the cap (stamps are
                    # chronological: queued retries predate new ones)
                    stamps = stamps[-self.max_catchup:]
                if not stamps:
                    # spurious wake-up (duplicate retry entry whose stamps
                    # were already emitted): just re-arm the boundary
                    planned.append((dep, task, key, sched, [], False, None))
                    continue
                version = self.registry.resolve_version(dep.package,
                                                        dep.version)
                planned.append((dep, task, key, sched, stamps, bool(new),
                                version))
        except Exception:
            for entry in popped:            # atomic: restore the queue
                heapq.heappush(heap, entry)
            raise
        # every lookup succeeded: commit state, re-arm wake-ups, and emit
        for dep, task, key, sched, stamps, advance, version in planned:
            if self._failed.pop(key, None) is not None or advance:
                self._dirty.add(key)
            if advance:
                self._last[key] = now
            self._push(sched.next_boundary_after(now), dep.name, task)
            for ts in dict.fromkeys(stamps):
                jobs.append(Job(
                    deployment_name=dep.name, package=dep.package,
                    version=version, task=task, scheduled_at=ts,
                    signal=dep.signal, entity=dep.entity,
                    user_params_key=self._params_key(dep.user_params)))
        # deterministic order: train before score before detect, then
        # chronological (catch-up occurrences execute oldest first), then
        # by name
        jobs.sort(key=lambda j: (_TASK_ORDER.get(j.task, 1), j.scheduled_at,
                                 j.deployment_name))
        return jobs

    def mark_failed(self, job: Job):
        """The failed job re-fires on the next poll at its ORIGINAL
        occurrence boundary (at-least-once per occurrence). Queuing the
        stamp — rather than resetting the deployment's whole watermark —
        means one failed catch-up occurrence cannot be collapsed away by
        its siblings' success and then silently deduplicated against the
        idempotent version/prediction stores. The retry entry's due time
        is the stamp itself (already past), so the next poll pops it.

        A failure surfacing AFTER its deployment was removed (the job was
        in flight when ``remove`` ran) is dropped: recording it would
        queue a stale retry against a future same-name re-registration,
        exactly the state ``on_remove`` exists to clear."""
        if job.deployment_name not in self.deployments:
            return
        key = (job.deployment_name, job.task)
        self._failed.setdefault(key, set()).add(job.scheduled_at)
        self._dirty.add(key)
        self._push(job.scheduled_at, job.deployment_name, job.task)

    # ---------------------- durability surface --------------------------
    def _state_entry(self, key: Tuple[str, str]) -> list:
        name, task = key
        wm = self._last.get(key)
        return [name, task, wm, sorted(self._failed.get(key, ()))]

    def drain_dirty(self) -> Optional[dict]:
        """The watermark/retry delta since the last drain, as one
        journal-record payload — or None when nothing changed. Appended
        by ``Castor.tick`` AFTER the tick's effect records, so a torn WAL
        tail can only leave "effects persisted, watermark behind": the
        whole boundary then re-fires on recovery and the idempotent
        stores absorb the duplicated prefix. An entry's stamp list
        replaces the key's retry set wholesale (empty = cleared)."""
        if not self._dirty:
            return None
        entries = [self._state_entry(k) for k in sorted(self._dirty)]
        self._dirty.clear()
        return {"keys": entries}

    def dump_state(self) -> dict:
        """Full watermark/retry state (snapshot records)."""
        keys = sorted(set(self._last) | set(self._failed))
        return {"keys": [self._state_entry(k) for k in keys]}

    def restore_state(self, d: dict) -> None:
        """Apply a "sched" record: per-key wholesale replacement. Retry
        stamps are re-armed on the heap so the next poll re-fires them,
        exactly as ``mark_failed`` would have."""
        for name, task, wm, stamps in d.get("keys", ()):
            key = (name, task)
            if wm is None:
                self._last.pop(key, None)
            else:
                self._last[key] = float(wm)
            if stamps:
                self._failed[key] = {float(s) for s in stamps}
                for s in stamps:
                    self._push(float(s), name, task)
            else:
                self._failed.pop(key, None)

    def stats(self) -> dict:
        return {"heap_entries": len(self._heap),
                "tracked": len(self._last),
                "failed_keys": len(self._failed),
                "interned_bins": len(BIN_KEYS)}


def _params_key(params: dict) -> str:
    return repr(sorted(params.items()))


#: below this many jobs, plain dict grouping beats numpy's fixed overhead
_VECTORIZE_MIN = 96


def bin_jobs(jobs: List[Job]) -> Dict[Tuple, List[Job]]:
    """Group jobs into executor bins.

    Grouping runs over the INTERNED integer bin ids — one numpy
    argsort/unique over an int64 axis — instead of hashing each job's
    tuple-of-strings key. The returned dict is still keyed by the
    human-readable ``bin_key`` tuples, in first-appearance order (callers
    iterate it to execute bins in the phase's chronological order), so
    the grouping is bitwise-indistinguishable from the dict-based one."""
    n = len(jobs)
    if n < _VECTORIZE_MIN:
        bins: Dict[Tuple, List[Job]] = {}
        for j in jobs:
            bins.setdefault(j.bin_key, []).append(j)
        return bins
    # single-bin fast path on raw attributes: a uniform fleet phase (the
    # steady-state minutely detect poll above all) is ONE bin, and per-job
    # bin-key interning is measurable at fleet width
    j0 = jobs[0]
    p0, v0, t0 = j0.package, j0.version, j0.task
    u0, s0 = j0.user_params_key, j0.scheduled_at
    if all(j.scheduled_at == s0 and j.package == p0 and j.version == v0
           and j.task == t0 and j.user_params_key == u0 for j in jobs):
        return {j0.bin_key: list(jobs)}
    ids = np.fromiter((j.bin_id for j in jobs), dtype=np.int64, count=n)
    uniq, first, inv = np.unique(ids, return_index=True, return_inverse=True)
    order = np.argsort(inv, kind="stable")      # groups contiguous, members
    starts = np.zeros(len(uniq) + 1, dtype=np.int64)    # in original order
    np.cumsum(np.bincount(inv, minlength=len(uniq)), out=starts[1:])
    out: Dict[Tuple, List[Job]] = {}
    for g in np.argsort(first, kind="stable"):  # first-appearance order
        members = order[starts[g]:starts[g + 1]]
        out[bin_key_of(int(uniq[g]))] = [jobs[i] for i in members]
    return out
