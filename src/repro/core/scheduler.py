"""Model scheduling (paper §2 step (7)): periodically load registered
deployments, decide which are due for training/scoring, and emit jobs.

Jobs carry a *bin key* so the fleet executor can megabatch identical
(implementation, task) work — the TPU-native analogue of launching
thousands of serverless containers (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Schedule:
    """Start time + repeat interval, both in epoch seconds."""
    start: float
    every: float

    def occurrences_due(self, last_run: Optional[float], now: float) -> int:
        """How many firings are due in (last_run, now]."""
        if now < self.start:
            return 0
        k_now = int((now - self.start) // self.every)       # latest index due
        if last_run is None:
            return 1                                        # fire once, catch up
        if last_run < self.start:
            return k_now + 1
        k_last = int((last_run - self.start) // self.every)
        return max(0, k_now - k_last)


@dataclass(frozen=True)
class Job:
    deployment_name: str
    package: str
    version: str                    # RESOLVED version (registry pinned at poll)
    task: str                       # "train" | "score"
    scheduled_at: float
    signal: str
    entity: str
    user_params_key: str = ""       # part of the bin key (same config batches)

    @property
    def bin_key(self) -> Tuple[str, str, str, str]:
        return (self.package, self.version, self.task, self.user_params_key)


class ModelScheduler:
    """Tracks last-run state per (deployment, task) and emits due jobs."""

    def __init__(self, deployments, registry):
        self.deployments = deployments
        self.registry = registry
        self._last: Dict[Tuple[str, str], float] = {}

    def poll(self, now: float) -> List[Job]:
        jobs: List[Job] = []
        for dep in self.deployments.all():
            for task in ("train", "score"):
                sched: Optional[Schedule] = getattr(dep, task)
                if sched is None:
                    continue
                due = sched.occurrences_due(self._last.get((dep.name, task)), now)
                if due <= 0:
                    continue
                version = self.registry.resolve_version(dep.package, dep.version)
                jobs.append(Job(
                    deployment_name=dep.name, package=dep.package,
                    version=version, task=task, scheduled_at=now,
                    signal=dep.signal, entity=dep.entity,
                    user_params_key=_params_key(dep.user_params)))
                self._last[(dep.name, task)] = now
        # deterministic order: training before scoring, then by name
        jobs.sort(key=lambda j: (j.task != "train", j.deployment_name))
        return jobs

    def mark_failed(self, job: Job):
        """Failed jobs re-fire on the next poll (at-least-once semantics)."""
        self._last.pop((job.deployment_name, job.task), None)


def _params_key(params: dict) -> str:
    return repr(sorted(params.items()))


def bin_jobs(jobs: List[Job]) -> Dict[Tuple, List[Job]]:
    bins: Dict[Tuple, List[Job]] = {}
    for j in jobs:
        bins.setdefault(j.bin_key, []).append(j)
    return bins
