"""Process-wide intern tables: dense integer handles for the control
plane's hot composite keys (job bin keys, sticky-routing affinity keys,
semantic-graph concepts).

At fleet scale the control plane's cost is dominated by re-hashing and
re-comparing tuples of strings — every poll rebuilt each job's
``(package, version, task, params_key, scheduled_at)`` bin key and every
routing decision crc32'd a sorted member list. Interning replaces that
with one dict hit the *first* time a key is seen and an int thereafter,
and gives numpy an integer axis to ``argsort``/``unique`` when grouping
jobs into bins (``scheduler.bin_jobs``).

Ids are dense, stable for the process lifetime, and NEVER cross process
boundaries: serverless payloads ship names over the wire and workers
re-intern locally (two processes' tables need not agree).
"""
from __future__ import annotations

import threading
from typing import Dict, Hashable, List


class InternTable:
    """Bidirectional value <-> dense int id map. Append-only; thread-safe
    (reads are lock-free CPython dict hits, inserts take a lock)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._ids: Dict[Hashable, int] = {}
        self._vals: List[Hashable] = []

    def intern(self, value: Hashable) -> int:
        i = self._ids.get(value)
        if i is None:
            with self._lock:
                i = self._ids.get(value)
                if i is None:
                    i = len(self._vals)
                    self._vals.append(value)
                    self._ids[value] = i
        return i

    def value(self, i: int) -> Hashable:
        """The original value behind an id (inverse of ``intern``)."""
        return self._vals[i]

    def get(self, value: Hashable):
        """The id if ``value`` was ever interned, else None (no insert)."""
        return self._ids.get(value)

    def __len__(self) -> int:
        return len(self._vals)

    def __contains__(self, value: Hashable) -> bool:
        return value in self._ids
