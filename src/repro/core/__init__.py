from .castor import Castor, Schedule, ModelDeployment, MINUTE, HOUR, DAY, WEEK  # noqa: F401
from .executor import FleetExecutor, LocalPoolExecutor, JobResult  # noqa: F401
from .registry import ModelInterface, ModelRegistry  # noqa: F401
from .semantics import Context, Entity, SemanticGraph, Signal  # noqa: F401
