"""Model-version + prediction lineage (paper §2, Figs. 5-7).

Every trained model version is persisted with metadata; every rolling-horizon
forecast is appended and NEVER overwritten, so historical performance can be
validated across prediction horizons (Fig. 7). The ranking mechanism serves
"the best" prediction per context to downstream consumers that only know the
semantic context.
"""
from __future__ import annotations

import threading
import time as _time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class ModelVersion:
    model_id: str                 # deployment name
    version: int                  # monotonically increasing per model_id
    trained_at: float             # simulation clock
    params: Any                   # fitted parameters (pytree of arrays)
    metadata: Dict = field(default_factory=dict)   # train duration, window, ...


class ModelVersionStore:
    """Idempotent on (model_id, trained_at): duplicate executions of one
    scheduled training job yield one version."""

    def __init__(self):
        self._versions: Dict[str, List[ModelVersion]] = {}
        self._latest: Dict[str, ModelVersion] = {}   # max trained_at memo
        self._lock = threading.Lock()
        self.journal = None           # durability.Journal when Castor.open'd

    def save(self, model_id: str, params, trained_at: float,
             metadata: Optional[dict] = None) -> ModelVersion:
        with self._lock:
            hist = self._versions.setdefault(model_id, [])
            for mv in hist:
                if mv.trained_at == trained_at:      # duplicate execution
                    return mv
            mv = ModelVersion(model_id, len(hist) + 1, trained_at, params,
                              dict(metadata or {}))
            hist.append(mv)
            cur = self._latest.get(model_id)
            if cur is None or (mv.trained_at, mv.version) > \
                    (cur.trained_at, cur.version):
                self._latest[model_id] = mv
            j = self.journal
            if j is not None:         # fresh insert only: replay re-derives
                j.append("mv", {"model_id": model_id,      # the numbering
                                "trained_at": trained_at, "params": params,
                                "metadata": mv.metadata})
            return mv

    def get(self, model_id: str, version: Optional[int] = None, *,
            at: Optional[float] = None) -> Optional[ModelVersion]:
        """Latest means max TRAINED time, not save order: catch-up training
        jobs (one per missed occurrence) may complete out of chronological
        order on a parallel executor, and scoring must never pick a stale
        boundary's model just because it finished last.

        ``at`` replays history faithfully: the newest version with
        ``trained_at <= at`` — a forecast stamped at boundary t must use
        the model a live poller would have had at t, never one trained on
        data observed after t. A replayed occurrence predating the first
        training falls back to the OLDEST version (closest to honest)
        rather than failing forever on at-least-once retries."""
        hist = self._versions.get(model_id)
        if not hist:
            return None
        if version is not None:
            return hist[version - 1]
        latest = self._latest[model_id]
        # steady-state fast path: a live poller's `at` is at/after the
        # newest training, so the memoized latest answers without a scan
        if at is None or latest.trained_at <= at:
            return latest
        key = lambda mv: (mv.trained_at, mv.version)   # noqa: E731
        eligible = [mv for mv in hist if mv.trained_at <= at]
        return max(eligible, key=key) if eligible else min(hist, key=key)

    def history(self, model_id: str) -> List[ModelVersion]:
        return list(self._versions.get(model_id, ()))

    def count(self) -> int:
        return sum(len(v) for v in self._versions.values())

    def model_ids(self) -> List[str]:
        return sorted(self._versions)


@dataclass(frozen=True)
class Forecast:
    deployment_name: str
    signal: str
    entity: str
    created_at: float             # when the scoring job ran
    times: np.ndarray             # horizon timestamps
    values: np.ndarray
    model_version: int
    rank: int = 0
    # q10/q90 prediction band (same shape as values); None for models that
    # predate bands or don't emit residual quantiles. The detection flow
    # compares live readings against these.
    lower: Optional[np.ndarray] = None
    upper: Optional[np.ndarray] = None


def forecast_record(fc: Forecast) -> Dict[str, Any]:
    """WAL/snapshot payload for one forecast (arrays pass bitwise through
    the codec; ``lower``/``upper`` may be None)."""
    return {"deployment_name": fc.deployment_name, "signal": fc.signal,
            "entity": fc.entity, "created_at": fc.created_at,
            "times": fc.times, "values": fc.values,
            "model_version": fc.model_version, "rank": fc.rank,
            "lower": fc.lower, "upper": fc.upper}


def forecast_from_record(d: Dict[str, Any]) -> Forecast:
    low, up = d.get("lower"), d.get("upper")
    return Forecast(
        deployment_name=d["deployment_name"], signal=d["signal"],
        entity=d["entity"], created_at=float(d["created_at"]),
        times=np.asarray(d["times"]), values=np.asarray(d["values"]),
        model_version=int(d["model_version"]), rank=int(d.get("rank", 0)),
        lower=None if low is None else np.asarray(low),
        upper=None if up is None else np.asarray(up))


def forecast_batch_record(fcs: List["Forecast"]) -> Dict[str, Any]:
    """One WAL/snapshot payload for a whole batch of forecasts.

    A uniform batch (every forecast the same horizon length and dtype,
    all banded or all bandless — the shape every fleet bin produces)
    stacks into four ``(n, h)`` arrays, so the codec encodes 4 large
    blobs instead of ``4n`` small ones; that keeps the per-tick WAL
    append off the warm-poll critical path (``bench_durability`` gate
    (b)). Rows of the stack are bitwise the original arrays. Mixed
    batches fall back to the per-forecast ``{"forecasts": [...]}`` list
    — ``forecasts_from_batch`` replays either format."""
    def _sig(fc):
        band = fc.lower is not None and fc.upper is not None
        if not (isinstance(fc.times, np.ndarray)
                and isinstance(fc.values, np.ndarray)):
            return None
        return (fc.times.shape, fc.times.dtype, fc.values.shape,
                fc.values.dtype, band,
                None if not band else (fc.lower.shape, fc.lower.dtype,
                                       fc.upper.shape, fc.upper.dtype))
    first = _sig(fcs[0]) if fcs else None
    if first is None or any(_sig(fc) != first for fc in fcs):
        return {"forecasts": [forecast_record(fc) for fc in fcs]}
    banded = fcs[0].lower is not None and fcs[0].upper is not None
    times = np.stack([fc.times for fc in fcs])
    if bool((times == times[0]).all()):
        times = times[0]       # one shared horizon grid (the fleet-bin
    return {"meta": [[fc.deployment_name,  # case: same boundary, same
                      fc.signal, fc.entity,  # grid for every sensor)
                      fc.created_at, fc.model_version, fc.rank]
                     for fc in fcs],
            "times": times,
            "values": np.stack([fc.values for fc in fcs]),
            "lower": np.stack([fc.lower for fc in fcs]) if banded else None,
            "upper": np.stack([fc.upper for fc in fcs]) if banded else None}


def forecasts_from_batch(d: Dict[str, Any]) -> List[Forecast]:
    if "forecasts" in d:
        return [forecast_from_record(f) for f in d["forecasts"]]
    times = np.asarray(d["times"])
    values = np.asarray(d["values"])
    shared = times.ndim == values.ndim - 1   # deduped horizon grid
    low, up = d.get("lower"), d.get("upper")
    low = None if low is None else np.asarray(low)
    up = None if up is None else np.asarray(up)
    return [Forecast(deployment_name=dep, signal=sig, entity=ent,
                     created_at=float(created),
                     times=times if shared else times[i],
                     values=values[i], model_version=int(ver),
                     rank=int(rank),
                     lower=None if low is None else low[i],
                     upper=None if up is None else up[i])
            for i, (dep, sig, ent, created, ver, rank)
            in enumerate(d["meta"])]


class PredictionStore:
    """Append-only rolling-horizon forecast store.

    Saves are IDEMPOTENT on (deployment, created_at): retried or speculative
    duplicate executions of the same scheduled scoring job persist once —
    rolling horizons at different created_at are all kept (never overwritten).
    """

    def __init__(self):
        self._by_dep: Dict[str, List[Forecast]] = {}
        self._by_ctx: Dict[Tuple[str, str], List[Forecast]] = {}
        self._seen: set = set()
        self._lock = threading.Lock()
        # latest(at=) memo per context: (history_len, chosen, next_created)
        # — a minutely detection fleet resolves its band at every boundary,
        # and the answer only changes when a forecast lands or ``at``
        # crosses the next created_at
        self._latest_memo: Dict[Tuple[str, str], tuple] = {}
        # cache-invalidation surface for executors holding resolved band
        # lists across polls: ``mutations`` bumps on every FRESH save;
        # ``max_created`` bounds the created_at a later ``at`` could newly
        # admit (see FleetExecutor's detect band cache)
        self.mutations = 0
        self.max_created = -float("inf")
        self.journal = None           # durability.Journal when Castor.open'd

    def save(self, fc: Forecast) -> Forecast:
        with self._lock:
            if self._save_locked(fc):
                self._journal_locked([fc])
        return fc

    def save_many(self, fcs: List[Forecast]) -> None:
        """One lock acquisition for a whole fleet bin's forecasts — the
        scoring analogue of ``TimeSeriesStore.read_many`` (N per-forecast
        lock round-trips were measurable at steady state). Journals the
        bin's fresh forecasts as ONE atomic record."""
        with self._lock:
            fresh = [fc for fc in fcs if self._save_locked(fc)]
            self._journal_locked(fresh)

    def _journal_locked(self, fresh: List[Forecast]) -> None:
        j = self.journal
        if j is not None and fresh:
            j.append("fc", forecast_batch_record(fresh))

    def _save_locked(self, fc: Forecast) -> bool:
        key = (fc.deployment_name, float(fc.created_at))
        if key in self._seen:                        # duplicate execution
            return False
        self._seen.add(key)
        self._by_dep.setdefault(fc.deployment_name, []).append(fc)
        self._by_ctx.setdefault((fc.signal, fc.entity), []).append(fc)
        self.mutations += 1
        if fc.created_at > self.max_created:
            self.max_created = float(fc.created_at)
        return True

    def history(self, deployment_name: str) -> List[Forecast]:
        """Full lineage — every rolling-horizon forecast ever produced."""
        return list(self._by_dep.get(deployment_name, ()))

    def deployment_names(self) -> List[str]:
        return sorted(self._by_dep)

    def for_context(self, signal: str, entity: str) -> List[Forecast]:
        return list(self._by_ctx.get((signal, entity), ()))

    def latest(self, signal: str, entity: str,
               at: Optional[float] = None) -> Optional[Forecast]:
        """Best-ranked most-recent forecast for a context (ranking mechanism):
        downstream apps retrieve by semantics only, without knowing which
        model produced the prediction."""
        hist = self._by_ctx.get((signal, entity))
        if not hist:
            return None
        if at is not None:
            # memo fast path: history append-only, so an unchanged length
            # means the same candidate set; the memoized choice stands
            # while ``at`` sits below the next created_at after it
            m = self._latest_memo.get((signal, entity))
            if m is not None:
                n, fc, nxt = m
                if n == len(hist) and fc.created_at <= at \
                        and (nxt is None or at < nxt):
                    return fc
        hist = list(hist)
        cand = [f for f in hist if at is None or f.created_at <= at]
        if not cand:
            return None
        newest = max(f.created_at for f in cand)
        newest_set = [f for f in cand if f.created_at == newest]
        best = min(newest_set, key=lambda f: (f.rank, f.deployment_name))
        if at is not None:
            later = [f.created_at for f in hist if f.created_at > at]
            self._latest_memo[(signal, entity)] = \
                (len(hist), best, min(later) if later else None)
        return best

    def horizons(self, deployment_name: str, target_time: float,
                 tol: float = 1.0) -> List[Tuple[float, float]]:
        """All (created_at, predicted_value) pairs for one target timestamp —
        the Fig. 7 multi-horizon validation view."""
        out = []
        for fc in self.history(deployment_name):
            hit = np.where(np.abs(fc.times - target_time) <= tol)[0]
            if hit.size:
                out.append((fc.created_at, float(fc.values[hit[0]])))
        return sorted(out)

    def count(self) -> int:
        return sum(len(v) for v in self._by_dep.values())
