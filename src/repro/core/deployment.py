"""Model deployments (paper §3.2, Listing 2) + programmatic fleet deployment.

A deployment binds (implementation, semantic context, schedules, user params,
rank). ``deploy_for_all`` implements the paper's key scaling feature:
explore the semantic graph and deploy an implementation to every matching
context, so the application grows as sensors are added.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from .scheduler import Schedule


@dataclass
class ModelDeployment:
    name: str                       # unique deployment name
    package: str                    # implementation reference
    model_class: str = ""           # informational (class name)
    version: Optional[str] = None   # None = latest at execution time
    signal: str = ""
    entity: str = ""
    train: Optional[Schedule] = None
    score: Optional[Schedule] = None
    user_params: Dict = field(default_factory=dict)
    rank: int = 0                   # paper's model-ranking mechanism (0 = best)

    def to_json(self) -> str:
        d = asdict(self)
        return json.dumps(d, indent=2, default=str)

    @property
    def context_key(self):
        return (self.signal, self.entity)


class DeploymentStore:
    def __init__(self):
        self._deps: Dict[str, ModelDeployment] = {}
        self._sorted: Optional[List[ModelDeployment]] = None

    def register(self, dep: ModelDeployment) -> ModelDeployment:
        if dep.name in self._deps:
            raise ValueError(f"deployment {dep.name} already registered")
        self._deps[dep.name] = dep
        self._sorted = None
        return dep

    def remove(self, name: str):
        self._deps.pop(name, None)
        self._sorted = None

    def get(self, name: str) -> ModelDeployment:
        return self._deps[name]

    def all(self) -> List[ModelDeployment]:
        # the scheduler walks every deployment every poll: cache the sort
        # (invalidated on register/remove) instead of re-sorting a
        # thousands-strong fleet each cycle
        if self._sorted is None:
            self._sorted = sorted(self._deps.values(), key=lambda d: d.name)
        return list(self._sorted)

    def for_context(self, signal: str, entity: str) -> List[ModelDeployment]:
        """All models deployed against one context, rank-sorted (Fig. 5)."""
        out = [d for d in self._deps.values()
               if d.signal == signal and d.entity == entity]
        return sorted(out, key=lambda d: (d.rank, d.name))

    def __len__(self):
        return len(self._deps)


def deploy_for_all(graph, deployments: DeploymentStore, *, package: str,
                   signal: str, name_prefix: str,
                   train: Optional[Schedule] = None,
                   score: Optional[Schedule] = None,
                   user_params: Optional[dict] = None,
                   version: Optional[str] = None,
                   kind: Optional[str] = None,
                   under: Optional[str] = None,
                   rank: int = 0) -> List[ModelDeployment]:
    """Programmatic deployment from a semantic rule (paper §3.2):
    one deployment per entity that carries ``signal`` (optionally filtered by
    entity kind / topology)."""
    out = []
    for ent in graph.find_entities(kind=kind, has_signal=signal, under=under):
        dep = ModelDeployment(
            name=f"{name_prefix}-{ent.name}",
            package=package, version=version, signal=signal, entity=ent.name,
            train=train, score=score, user_params=dict(user_params or {}),
            rank=rank)
        out.append(deployments.register(dep))
    return out
