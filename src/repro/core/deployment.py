"""Model deployments (paper §3.2, Listing 2) + programmatic fleet deployment.

A deployment binds (implementation, semantic context, schedules, user params,
rank). ``deploy_for_all`` implements the paper's key scaling feature:
explore the semantic graph and deploy an implementation to every matching
context, so the application grows as sensors are added.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from .scheduler import Schedule


@dataclass
class ModelDeployment:
    name: str                       # unique deployment name
    package: str                    # implementation reference
    model_class: str = ""           # informational (class name)
    version: Optional[str] = None   # None = latest at execution time
    signal: str = ""
    entity: str = ""
    train: Optional[Schedule] = None
    score: Optional[Schedule] = None
    user_params: Dict = field(default_factory=dict)
    rank: int = 0                   # paper's model-ranking mechanism (0 = best)
    # ---- flow typing ----
    # "forecast" deployments train/score as always; other flow kinds (the
    # minutely "detection" flow in repro.flows) schedule different tasks
    # against the same context. Indexed by DeploymentStore.for_flow.
    flow: str = "forecast"
    detect: Optional[Schedule] = None

    def to_json(self) -> str:
        d = asdict(self)
        return json.dumps(d, indent=2, default=str)

    @property
    def context_key(self):
        return (self.signal, self.entity)


def _schedule_record(s: Optional[Schedule]):
    return None if s is None else [s.start, s.every]


def _schedule_from(v) -> Optional[Schedule]:
    return None if v is None else Schedule(float(v[0]), float(v[1]))


def deployment_record(dep: ModelDeployment) -> Dict:
    """WAL/snapshot payload for one deployment. ``cls`` discriminates the
    dataclass to rebuild (``DetectionDeployment`` subclasses add nothing
    beyond a different flow default, but keep the type round-trip exact)."""
    return {"cls": type(dep).__name__, "name": dep.name,
            "package": dep.package, "model_class": dep.model_class,
            "version": dep.version, "signal": dep.signal,
            "entity": dep.entity, "train": _schedule_record(dep.train),
            "score": _schedule_record(dep.score),
            "detect": _schedule_record(dep.detect),
            "user_params": dep.user_params, "rank": dep.rank,
            "flow": dep.flow}


def deployment_from_record(d: Dict) -> ModelDeployment:
    cls = ModelDeployment
    if d.get("cls") == "DetectionDeployment":
        from ..flows.detection import DetectionDeployment
        cls = DetectionDeployment
    return cls(
        name=d["name"], package=d["package"],
        model_class=d.get("model_class", ""), version=d.get("version"),
        signal=d.get("signal", ""), entity=d.get("entity", ""),
        train=_schedule_from(d.get("train")),
        score=_schedule_from(d.get("score")),
        detect=_schedule_from(d.get("detect")),
        user_params=dict(d.get("user_params") or {}),
        rank=int(d.get("rank", 0)),
        flow=d.get("flow", "forecast"))


class DeploymentStore:
    """Indexed deployment registry: by name, by context ``(signal,
    entity)`` and by package, with a monotonically increasing
    ``revision`` and a listener protocol (``on_register(dep)`` /
    ``on_remove(name)``) so downstream caches — the scheduler's calendar
    queue above all — invalidate INCREMENTALLY on changes instead of
    re-scanning or re-sorting the fleet each poll. Context and package
    lookups are index hits proportional to their result size, never a
    fleet scan."""

    def __init__(self):
        self._deps: Dict[str, ModelDeployment] = {}
        self._sorted: Optional[List[ModelDeployment]] = None
        self._by_context: Dict[tuple, Dict[str, ModelDeployment]] = {}
        self._by_package: Dict[str, Dict[str, ModelDeployment]] = {}
        self._by_flow: Dict[str, Dict[str, ModelDeployment]] = {}
        self._revision = 0
        self._listeners: List = []
        self.journal = None           # durability.Journal when Castor.open'd

    @property
    def revision(self) -> int:
        """Bumped on every register/remove: consumers holding derived
        state (sorted views, routing tables) compare-and-refresh against
        this instead of diffing the fleet."""
        return self._revision

    def subscribe(self, listener) -> None:
        """Register a mutation listener: ``listener.on_register(dep)``
        after each registration, ``listener.on_remove(name)`` after each
        removal. The scheduler subscribes itself to keep its calendar
        queue and per-deployment state exactly in sync with the store."""
        self._listeners.append(listener)

    def register(self, dep: ModelDeployment) -> ModelDeployment:
        if dep.name in self._deps:
            raise ValueError(f"deployment {dep.name} already registered")
        self._deps[dep.name] = dep
        self._by_context.setdefault(dep.context_key, {})[dep.name] = dep
        self._by_package.setdefault(dep.package, {})[dep.name] = dep
        self._by_flow.setdefault(
            getattr(dep, "flow", "forecast"), {})[dep.name] = dep
        self._sorted = None
        self._revision += 1
        j = self.journal
        if j is not None:
            j.append("dep", deployment_record(dep))
        for sub in self._listeners:
            sub.on_register(dep)
        return dep

    def remove(self, name: str):
        dep = self._deps.pop(name, None)
        if dep is None:
            return
        for index, key in ((self._by_context, dep.context_key),
                           (self._by_package, dep.package),
                           (self._by_flow, getattr(dep, "flow", "forecast"))):
            bucket = index.get(key)
            if bucket is not None:
                bucket.pop(name, None)
                if not bucket:
                    del index[key]
        self._sorted = None
        self._revision += 1
        j = self.journal
        if j is not None:
            j.append("rmdep", {"name": name})
        for sub in self._listeners:
            sub.on_remove(name)

    def get(self, name: str) -> ModelDeployment:
        return self._deps[name]

    def __contains__(self, name: str) -> bool:
        return name in self._deps

    def all(self) -> List[ModelDeployment]:
        # bulk consumers (benchmark sweeps, deploy_for_all audits) get a
        # cached sort, invalidated by revision bumps — the scheduler no
        # longer calls this per poll at all
        if self._sorted is None:
            self._sorted = sorted(self._deps.values(), key=lambda d: d.name)
        return list(self._sorted)

    def for_context(self, signal: str, entity: str) -> List[ModelDeployment]:
        """All models deployed against one context, rank-sorted (Fig. 5).
        Index hit: O(models on that context), not O(fleet)."""
        out = self._by_context.get((signal, entity), {})
        return sorted(out.values(), key=lambda d: (d.rank, d.name))

    def for_package(self, package: str) -> List[ModelDeployment]:
        """All deployments of one implementation package, name-sorted
        (index hit — e.g. 'which fleets does retiring this package
        strand?')."""
        out = self._by_package.get(package, {})
        return sorted(out.values(), key=lambda d: d.name)

    def for_flow(self, flow: str) -> List[ModelDeployment]:
        """All deployments of one flow kind ("forecast", "detection", ...),
        name-sorted (index hit, not a fleet scan)."""
        out = self._by_flow.get(flow, {})
        return sorted(out.values(), key=lambda d: d.name)

    def flow_counts(self) -> Dict[str, int]:
        """Per-flow deployment counts for ``Castor.stats()``."""
        return {flow: len(bucket)
                for flow, bucket in sorted(self._by_flow.items()) if bucket}

    def __len__(self):
        return len(self._deps)


def deploy_for_all(graph, deployments: DeploymentStore, *, package: str,
                   signal: str, name_prefix: str,
                   train: Optional[Schedule] = None,
                   score: Optional[Schedule] = None,
                   user_params: Optional[dict] = None,
                   version: Optional[str] = None,
                   kind: Optional[str] = None,
                   under: Optional[str] = None,
                   rank: int = 0) -> List[ModelDeployment]:
    """Programmatic deployment from a semantic rule (paper §3.2):
    one deployment per entity that carries ``signal`` (optionally filtered by
    entity kind / topology).

    Incremental and idempotent: re-running the same rule after new
    entities were linked (the paper's "automated replication as the IoT
    application grows") deploys ONLY the not-yet-deployed contexts and
    returns just those new deployments — already-registered names are
    left untouched (their schedules/params are not rewritten), so a
    periodic re-apply of the rule is safe."""
    out = []
    for ent in graph.find_entities(kind=kind, has_signal=signal, under=under):
        name = f"{name_prefix}-{ent.name}"
        if name in deployments:        # already applied to this context
            prev = deployments.get(name)
            if (prev.package, prev.version, prev.signal, prev.entity,
                    prev.train, prev.score, prev.rank, prev.user_params) \
                    != (package, version, signal, ent.name, train, score,
                        rank, dict(user_params or {})):
                # same name, DIFFERENT rule (package, version, schedules,
                # params, or rank changed): skipping silently would leave
                # the caller believing the re-configured fleet exists —
                # the old loud-collision behavior is the right one here
                raise ValueError(
                    f"deployment {name} already registered with a "
                    f"different configuration ({prev.package}=="
                    f"{prev.version}/{prev.signal}); re-apply the "
                    "identical rule, or use a different name_prefix")
            continue
        dep = ModelDeployment(
            name=name,
            package=package, version=version, signal=signal, entity=ent.name,
            train=train, score=score, user_params=dict(user_params or {}),
            rank=rank)
        out.append(deployments.register(dep))
    return out
