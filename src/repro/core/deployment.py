"""Model deployments (paper §3.2, Listing 2) + programmatic fleet deployment.

A deployment binds (implementation, semantic context, schedules, user params,
rank). ``deploy_for_all`` implements the paper's key scaling feature:
explore the semantic graph and deploy an implementation to every matching
context, so the application grows as sensors are added.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from .scheduler import Schedule


@dataclass
class ModelDeployment:
    name: str                       # unique deployment name
    package: str                    # implementation reference
    model_class: str = ""           # informational (class name)
    version: Optional[str] = None   # None = latest at execution time
    signal: str = ""
    entity: str = ""
    train: Optional[Schedule] = None
    score: Optional[Schedule] = None
    user_params: Dict = field(default_factory=dict)
    rank: int = 0                   # paper's model-ranking mechanism (0 = best)

    def to_json(self) -> str:
        d = asdict(self)
        return json.dumps(d, indent=2, default=str)

    @property
    def context_key(self):
        return (self.signal, self.entity)


class DeploymentStore:
    def __init__(self):
        self._deps: Dict[str, ModelDeployment] = {}
        self._sorted: Optional[List[ModelDeployment]] = None

    def register(self, dep: ModelDeployment) -> ModelDeployment:
        if dep.name in self._deps:
            raise ValueError(f"deployment {dep.name} already registered")
        self._deps[dep.name] = dep
        self._sorted = None
        return dep

    def remove(self, name: str):
        self._deps.pop(name, None)
        self._sorted = None

    def get(self, name: str) -> ModelDeployment:
        return self._deps[name]

    def __contains__(self, name: str) -> bool:
        return name in self._deps

    def all(self) -> List[ModelDeployment]:
        # the scheduler walks every deployment every poll: cache the sort
        # (invalidated on register/remove) instead of re-sorting a
        # thousands-strong fleet each cycle
        if self._sorted is None:
            self._sorted = sorted(self._deps.values(), key=lambda d: d.name)
        return list(self._sorted)

    def for_context(self, signal: str, entity: str) -> List[ModelDeployment]:
        """All models deployed against one context, rank-sorted (Fig. 5)."""
        out = [d for d in self._deps.values()
               if d.signal == signal and d.entity == entity]
        return sorted(out, key=lambda d: (d.rank, d.name))

    def __len__(self):
        return len(self._deps)


def deploy_for_all(graph, deployments: DeploymentStore, *, package: str,
                   signal: str, name_prefix: str,
                   train: Optional[Schedule] = None,
                   score: Optional[Schedule] = None,
                   user_params: Optional[dict] = None,
                   version: Optional[str] = None,
                   kind: Optional[str] = None,
                   under: Optional[str] = None,
                   rank: int = 0) -> List[ModelDeployment]:
    """Programmatic deployment from a semantic rule (paper §3.2):
    one deployment per entity that carries ``signal`` (optionally filtered by
    entity kind / topology).

    Incremental and idempotent: re-running the same rule after new
    entities were linked (the paper's "automated replication as the IoT
    application grows") deploys ONLY the not-yet-deployed contexts and
    returns just those new deployments — already-registered names are
    left untouched (their schedules/params are not rewritten), so a
    periodic re-apply of the rule is safe."""
    out = []
    for ent in graph.find_entities(kind=kind, has_signal=signal, under=under):
        name = f"{name_prefix}-{ent.name}"
        if name in deployments:        # already applied to this context
            prev = deployments.get(name)
            if (prev.package, prev.version, prev.signal, prev.entity,
                    prev.train, prev.score, prev.rank, prev.user_params) \
                    != (package, version, signal, ent.name, train, score,
                        rank, dict(user_params or {})):
                # same name, DIFFERENT rule (package, version, schedules,
                # params, or rank changed): skipping silently would leave
                # the caller believing the re-configured fleet exists —
                # the old loud-collision behavior is the right one here
                raise ValueError(
                    f"deployment {name} already registered with a "
                    f"different configuration ({prev.package}=="
                    f"{prev.version}/{prev.signal}); re-apply the "
                    "identical rule, or use a different name_prefix")
            continue
        dep = ModelDeployment(
            name=name,
            package=package, version=version, signal=signal, entity=ent.name,
            train=train, score=score, user_params=dict(user_params or {}),
            rank=rank)
        out.append(deployments.register(dep))
    return out
