"""Model execution engines (paper §2 + §4.3, adapted per DESIGN.md §2).

Two interchangeable executors:

* ``LocalPoolExecutor`` — paper-faithful serverless semantics: each job is an
  independent unit on a bounded worker pool (the paper's 10..200 parallel
  containers), with retries, job timeout, and MapReduce-style speculative
  re-dispatch of stragglers. This is what the Table-3 scalability benchmark
  sweeps.

* ``FleetExecutor`` — the TPU-native adaptation: due jobs are binned by
  (implementation, version, task, params) and each bin executes as ONE
  megabatched computation via the implementation's ``fleet_train`` /
  ``fleet_score`` hooks (vmapped JAX under the hood). Implementations without
  fleet hooks fall back to the pool.

Data path: a fleet bin fetches ALL of its series history with a single
``store.read_many`` call (via ``ForecastModelBase.fleet_load``) against the
compacting columnar ``TimeSeriesStore``, instead of N per-instance
``read()``s; ``last_bin_stats`` records the observed ``read_many_calls`` /
``single_reads`` per bin so tests and benchmarks can assert the batching.

Observational-equivalence guarantee: for the same due jobs, the two
executors persist the same model versions and forecasts (up to per-model
training stochasticity with identical seeds) — ``fleet_load`` sets each
instance's ``_loaded`` to exactly what ``load()`` computes, the batched
store read returns the same points as N single reads, and both paths write
through the same ``ModelVersionStore`` / ``PredictionStore``. Choosing an
executor changes speed, never results. ``tests/test_executor.py`` and
``tests/test_store.py`` pin this contract.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .lineage import Forecast
from .registry import ModelInterface
from .scheduler import Job, bin_jobs


@dataclass
class JobResult:
    job: Job
    ok: bool
    duration_s: float
    attempts: int = 1
    error: str = ""
    output: Any = None
    speculative_win: bool = False   # a backup copy finished first


class _ExecBase:
    def __init__(self, system):
        self.system = system

    # ------------- single-job execution (shared) -------------
    def _instantiate(self, job: Job) -> ModelInterface:
        cls = self.system.registry.get(job.package, job.version)
        ctx = self.system.graph.context(job.signal, job.entity)
        dep = self.system.deployments.get(job.deployment_name)
        latest = self.system.versions.get(job.deployment_name)
        up = dict(dep.user_params)
        # execution-time parameter: the poll's timestamp must ALWAYS win —
        # a stray "now" in a deployment's user_params would otherwise pin
        # every future job to that stale instant
        up["now"] = job.scheduled_at
        return cls(context=ctx, task=job.task, model_id=job.deployment_name,
                   model_version=latest.version if latest else None,
                   user_params=up, system=self.system)

    def _run_one(self, job: Job) -> Any:
        inst = self._instantiate(job)
        if job.task == "train":
            t0 = time.perf_counter()
            model_obj = inst.train()
            dt = time.perf_counter() - t0
            self.system.versions.save(
                job.deployment_name, model_obj, trained_at=job.scheduled_at,
                metadata={"train_seconds": dt, "signal": job.signal,
                          "entity": job.entity, "package": str(job.package)})
            return {"trained": True}
        # score
        latest = self.system.versions.get(job.deployment_name)
        if latest is None:
            raise RuntimeError(f"no trained version for {job.deployment_name}")
        times, values = inst.score(latest.params)
        dep = self.system.deployments.get(job.deployment_name)
        self.system.predictions.save(Forecast(
            deployment_name=job.deployment_name, signal=job.signal,
            entity=job.entity, created_at=job.scheduled_at,
            times=np.asarray(times), values=np.asarray(values),
            model_version=latest.version, rank=dep.rank))
        return {"scored": True, "points": len(times)}


class LocalPoolExecutor(_ExecBase):
    """Paper-faithful parallel job execution on a bounded pool."""

    def __init__(self, system, *, max_parallel: int = 16, max_retries: int = 2,
                 straggler_factor: float = 3.0, straggler_min_s: float = 0.5,
                 speculative: bool = True):
        super().__init__(system)
        self.max_parallel = max_parallel
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.speculative = speculative

    def run(self, jobs: List[Job]) -> List[JobResult]:
        """Dependency phases: all due TRAIN jobs complete before SCORE jobs
        start (a scoring job may consume the version trained this cycle)."""
        trains = [j for j in jobs if j.task == "train"]
        scores = [j for j in jobs if j.task != "train"]
        out: List[JobResult] = []
        for phase in (trains, scores):
            out.extend(self._run_phase(phase))
        return out

    def _run_phase(self, jobs: List[Job]) -> List[JobResult]:
        if not jobs:
            return []
        results: Dict[int, JobResult] = {}
        durations: List[float] = []

        def attempt(job: Job, idx: int, n: int) -> JobResult:
            t0 = time.perf_counter()
            try:
                out = self._run_one(job)
                return JobResult(job, True, time.perf_counter() - t0,
                                 attempts=n, output=out)
            except Exception as e:  # noqa: BLE001
                return JobResult(job, False, time.perf_counter() - t0,
                                 attempts=n, error=f"{type(e).__name__}: {e}")

        with ThreadPoolExecutor(max_workers=self.max_parallel) as pool:
            pending: Dict[Future, Tuple[Job, int, int, float]] = {}
            backups: Dict[int, Future] = {}
            inflight: Dict[int, int] = {}    # job idx -> live copies
            for i, job in enumerate(jobs):
                f = pool.submit(attempt, job, i, 1)
                pending[f] = (job, i, 1, time.perf_counter())
                inflight[i] = 1

            while pending:
                done, _ = wait(list(pending), timeout=self.straggler_min_s,
                               return_when=FIRST_COMPLETED)
                now = time.perf_counter()
                for f in done:
                    job, idx, n, t0 = pending.pop(f)
                    inflight[idx] -= 1
                    res = f.result()
                    if idx in results:      # a copy already finished
                        continue
                    if res.ok:
                        results[idx] = res
                        durations.append(res.duration_s)
                        if idx in backups and backups[idx] is not f:
                            res.speculative_win = n > 1
                    elif n <= self.max_retries:
                        nf = pool.submit(attempt, job, idx, n + 1)
                        pending[nf] = (job, idx, n + 1, now)
                        inflight[idx] += 1
                    elif inflight[idx] == 0:
                        # a job fails only once NO copy of it remains in
                        # flight — a backup that dies must not discard a
                        # still-running primary's success (which would
                        # wrongly re-fire the job next poll)
                        results[idx] = res
                        self.system.scheduler.mark_failed(job)
                # speculative re-dispatch of stragglers (MapReduce-style)
                if self.speculative and durations:
                    med = float(np.median(durations))
                    thresh = max(self.straggler_min_s, self.straggler_factor * med)
                    for f, (job, idx, n, t0) in list(pending.items()):
                        if idx not in backups and now - t0 > thresh:
                            bf = pool.submit(attempt, job, idx, n + 1)
                            backups[idx] = bf
                            pending[bf] = (job, idx, n + 1, now)
                            inflight[idx] += 1
        return [results[i] for i in sorted(results)]


class FleetExecutor(_ExecBase):
    """TPU-native megabatched execution: one computation per job bin."""

    def __init__(self, system, *, fallback: Optional[LocalPoolExecutor] = None):
        super().__init__(system)
        self.fallback = fallback or LocalPoolExecutor(system, max_parallel=8)
        self.last_bin_stats: List[dict] = []

    def run(self, jobs: List[Job]) -> List[JobResult]:
        out: List[JobResult] = []
        self.last_bin_stats = []
        for key, bin_jobs_ in bin_jobs(jobs).items():
            cls = self.system.registry.get(key[0], key[1])
            if not getattr(cls, "SUPPORTS_FLEET", False):
                out.extend(self.fallback.run(bin_jobs_))
                continue
            t0 = time.perf_counter()
            store = getattr(self.system, "store", None)
            rm0 = getattr(store, "read_many_count", 0)
            r0 = getattr(store, "read_count", 0)
            instances = [self._instantiate(j) for j in bin_jobs_]
            try:
                if key[2] == "train":
                    model_objs = cls.fleet_train(instances)
                    for j, mo in zip(bin_jobs_, model_objs):
                        self.system.versions.save(
                            j.deployment_name, mo, trained_at=j.scheduled_at,
                            metadata={"fleet": True, "signal": j.signal,
                                      "entity": j.entity})
                else:
                    latests = [self.system.versions.get(j.deployment_name)
                               for j in bin_jobs_]
                    missing = [j.deployment_name for j, l in
                               zip(bin_jobs_, latests) if l is None]
                    if missing:
                        raise RuntimeError(f"no trained version for {missing[:3]}")
                    preds = cls.fleet_score(instances,
                                            [l.params for l in latests])
                    for j, l, (times, values) in zip(bin_jobs_, latests, preds):
                        dep = self.system.deployments.get(j.deployment_name)
                        self.system.predictions.save(Forecast(
                            deployment_name=j.deployment_name, signal=j.signal,
                            entity=j.entity, created_at=j.scheduled_at,
                            times=np.asarray(times), values=np.asarray(values),
                            model_version=l.version, rank=dep.rank))
                dt = time.perf_counter() - t0
                per = dt / max(len(bin_jobs_), 1)
                out.extend(JobResult(j, True, per) for j in bin_jobs_)
                self.last_bin_stats.append(
                    {"bin": str(key), "jobs": len(bin_jobs_), "seconds": dt,
                     "read_many_calls":
                         getattr(store, "read_many_count", 0) - rm0,
                     "single_reads": getattr(store, "read_count", 0) - r0})
            except Exception as e:  # noqa: BLE001
                dt = time.perf_counter() - t0
                err = f"{type(e).__name__}: {e}"
                for j in bin_jobs_:
                    out.append(JobResult(j, False, dt / len(bin_jobs_), error=err))
                    self.system.scheduler.mark_failed(j)
        return out
