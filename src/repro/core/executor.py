"""Model execution engines (paper §2 + §4.3, adapted per DESIGN.md §2).

Every engine implements one protocol — ``run(jobs) -> List[JobResult]``
(see ``Executor`` below) — with identical semantics: train jobs phase
before score jobs, failures are per job (``scheduler.mark_failed`` gives
at-least-once per occurrence), and all persistence goes through the
idempotent ``ModelVersionStore``/``PredictionStore``, so executors are
interchangeable behind ``Castor.tick(executor=...)``.

Two engines live here (a third, ``ServerlessExecutor`` — the paper's
actual serverless invocation pipeline with stateless payloads, action
aggregation and warm-container affinity — lives in ``repro.serverless``):

* ``LocalPoolExecutor`` — paper-faithful serverless semantics: each job is an
  independent unit on a bounded worker pool (the paper's 10..200 parallel
  containers), with retries, job timeout, and MapReduce-style speculative
  re-dispatch of stragglers. This is what the Table-3 scalability benchmark
  sweeps.

* ``FleetExecutor`` — the TPU-native adaptation: due jobs are binned by
  (implementation, version, task, params, scheduled_at) and each bin
  executes as ONE megabatched computation via the implementation's
  ``fleet_train`` / ``fleet_score`` hooks (vmapped JAX under the hood; with
  >1 device the bin's instance axis is shard_map-partitioned across a fleet
  mesh — see the class docstring). Implementations without fleet hooks fall
  back to the pool. Train bins always phase before score bins, and a score
  bin containing never-trained deployments fails only those jobs.

Data path: a fleet bin fetches ALL of its series history with a single
``store.read_many`` call (via ``ForecastModelBase.fleet_load``) against the
compacting columnar ``TimeSeriesStore``, instead of N per-instance
``read()``s; ``last_bin_stats`` records the observed ``read_many_calls`` /
``single_reads`` per bin so tests and benchmarks can assert the batching.

Observational-equivalence guarantee: for the same due jobs, the two
executors persist the same model versions and forecasts (up to per-model
training stochasticity with identical seeds) — ``fleet_load`` sets each
instance's ``_loaded`` to exactly what ``load()`` computes, the batched
store read returns the same points as N single reads, and both paths write
through the same ``ModelVersionStore`` / ``PredictionStore``. Choosing an
executor changes speed, never results. ``tests/test_executor.py`` and
``tests/test_store.py`` pin this contract.
"""
from __future__ import annotations

import queue
import threading
import time
from operator import attrgetter
import traceback
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..obs.metrics import get_metrics
from ..obs.trace import get_tracer
from .lineage import Forecast
from .registry import ModelInterface
from .scheduler import Job, bin_jobs

#: C-speed sort key — a python lambda per job is measurable at fleet width
_BY_TIME = attrgetter("scheduled_at")


@dataclass
class JobResult:
    job: Job
    ok: bool
    duration_s: float
    attempts: int = 1
    error: str = ""
    output: Any = None
    speculative_win: bool = False   # a backup copy finished first


class Executor:
    """The executor protocol every engine satisfies (LocalPool, Fleet,
    Serverless): execute due jobs, persist effects idempotently, phase
    trains before scores, mark failures for at-least-once re-fire, and
    return one ``JobResult`` per job (order not contractual)."""

    def run(self, jobs: List[Job]) -> List[JobResult]:
        raise NotImplementedError


class _ExecBase(Executor):
    def __init__(self, system):
        self.system = system

    # ------------- single-job execution (shared) -------------
    _UNSET = object()

    def _instantiate(self, job: Job, latest=_UNSET,
                     cls=None) -> ModelInterface:
        """``latest``/``cls`` let callers that already resolved the model
        version or implementation class (the fleet bin path — shared
        across the whole bin) skip per-job registry/store lookups; the
        instance's ``model_version`` attribute is informational."""
        if cls is None:
            cls = self.system.registry.get(job.package, job.version)
        ctx = self.system.graph.context(job.signal, job.entity)
        dep = self.system.deployments.get(job.deployment_name)
        if latest is _ExecBase._UNSET:
            latest = self.system.versions.get(job.deployment_name)
        up = dict(dep.user_params)
        # execution-time parameter: the poll's timestamp must ALWAYS win —
        # a stray "now" in a deployment's user_params would otherwise pin
        # every future job to that stale instant
        up["now"] = job.scheduled_at
        return cls(context=ctx, task=job.task, model_id=job.deployment_name,
                   model_version=latest.version if latest else None,
                   user_params=up, system=self.system)

    def _run_one(self, job: Job) -> Any:
        if job.task == "detect":
            # compare live readings against the band a LIVE poller would
            # have had at this boundary (same at= replay semantics as
            # scoring below); the detector persists through the idempotent
            # DetectionStore, so duplicate executions stay exactly-once
            fc = self.system.predictions.latest(job.signal, job.entity,
                                                at=job.scheduled_at)
            if fc is None or fc.lower is None:
                raise RuntimeError(
                    f"no banded forecast for {job.signal}@{job.entity}")
            inst = self._instantiate(job, latest=None)
            rec = inst.detect(fc)
            self.system.detections.save(rec)
            return {"detected": True, "score": rec.score}
        inst = self._instantiate(job)
        if job.task == "train":
            t0 = time.perf_counter()
            model_obj = inst.train()
            dt = time.perf_counter() - t0
            self.system.versions.save(
                job.deployment_name, model_obj, trained_at=job.scheduled_at,
                metadata={"train_seconds": dt, "signal": job.signal,
                          "entity": job.entity, "package": str(job.package)})
            return {"trained": True}
        # score with the version a LIVE poller would have had at the job's
        # boundary — catch-up occurrences must not leak later-trained models
        latest = self.system.versions.get(job.deployment_name,
                                          at=job.scheduled_at)
        if latest is None:
            raise RuntimeError(f"no trained version for {job.deployment_name}")
        res = inst.score(latest.params)
        # forecasters return (times, values, lower, upper); third-party
        # 2-tuple implementations persist band-less forecasts
        times, values = res[0], res[1]
        lower, upper = (res[2], res[3]) if len(res) > 2 else (None, None)
        dep = self.system.deployments.get(job.deployment_name)
        self.system.predictions.save(Forecast(
            deployment_name=job.deployment_name, signal=job.signal,
            entity=job.entity, created_at=job.scheduled_at,
            times=np.asarray(times), values=np.asarray(values),
            model_version=latest.version, rank=dep.rank,
            lower=None if lower is None else np.asarray(lower),
            upper=None if upper is None else np.asarray(upper)))
        return {"scored": True, "points": len(times)}


class LocalPoolExecutor(_ExecBase):
    """Paper-faithful parallel job execution on a bounded pool."""

    def __init__(self, system, *, max_parallel: int = 16, max_retries: int = 2,
                 straggler_factor: float = 3.0, straggler_min_s: float = 0.5,
                 speculative: bool = True):
        super().__init__(system)
        self.max_parallel = max_parallel
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.speculative = speculative

    def run(self, jobs: List[Job]) -> List[JobResult]:
        """Dependency phases: all due TRAIN jobs complete before SCORE jobs
        start (a scoring job may consume the version trained this cycle),
        and DETECT jobs run last (a detection may consume the band scored
        this cycle)."""
        trains = [j for j in jobs if j.task == "train"]
        detects = [j for j in jobs if j.task == "detect"]
        scores = [j for j in jobs if j.task not in ("train", "detect")]
        out: List[JobResult] = []
        for phase in (trains, scores, detects):
            out.extend(self._run_phase(phase))
        return out

    def _run_phase(self, jobs: List[Job]) -> List[JobResult]:
        if not jobs:
            return []
        with get_tracer().span("exec.pool", task=jobs[0].task,
                               jobs=len(jobs)):
            return self._run_phase_inner(jobs)

    def _run_phase_inner(self, jobs: List[Job]) -> List[JobResult]:
        results: Dict[int, JobResult] = {}
        durations: List[float] = []

        def attempt(job: Job) -> JobResult:
            t0 = time.perf_counter()
            try:
                out = self._run_one(job)
                return JobResult(job, True, time.perf_counter() - t0,
                                 output=out)
            except Exception as e:  # noqa: BLE001
                return JobResult(job, False, time.perf_counter() - t0,
                                 error=f"{type(e).__name__}: {e}")

        with ThreadPoolExecutor(max_workers=self.max_parallel) as pool:
            pending: Dict[Future, Tuple[Job, int, float]] = {}
            backups: Dict[int, Future] = {}
            inflight: Dict[int, int] = {}    # job idx -> live copies
            attempts: Dict[int, int] = {}    # job idx -> copies EVER submitted
            # the retry budget is per JOB, not per copy chain: a job may run
            # at most 1 + max_retries times total, and a speculative backup
            # consumes one attempt from that same budget — before, the
            # backup restarted the count and a job could burn the budget
            # twice over
            for i, job in enumerate(jobs):
                f = pool.submit(attempt, job)
                pending[f] = (job, i, time.perf_counter())
                inflight[i] = 1
                attempts[i] = 1

            while pending:
                done, _ = wait(list(pending), timeout=self.straggler_min_s,
                               return_when=FIRST_COMPLETED)
                now = time.perf_counter()
                for f in done:
                    job, idx, t0 = pending.pop(f)
                    inflight[idx] -= 1
                    res = f.result()
                    if idx in results:      # a copy already finished
                        continue
                    res.attempts = attempts[idx]
                    if res.ok:
                        # speculative_win only when the winning future IS
                        # the backup copy, not merely when one exists
                        res.speculative_win = backups.get(idx) is f
                        results[idx] = res
                        durations.append(res.duration_s)
                    elif attempts[idx] <= self.max_retries:
                        nf = pool.submit(attempt, job)
                        attempts[idx] += 1
                        pending[nf] = (job, idx, now)
                        inflight[idx] += 1
                    elif inflight[idx] == 0:
                        # a job fails only once NO copy of it remains in
                        # flight — a backup that dies must not discard a
                        # still-running primary's success (which would
                        # wrongly re-fire the job next poll)
                        results[idx] = res
                        self.system.scheduler.mark_failed(job)
                # speculative re-dispatch of stragglers (MapReduce-style)
                if self.speculative and durations:
                    med = float(np.median(durations))
                    thresh = max(self.straggler_min_s, self.straggler_factor * med)
                    for f, (job, idx, t0) in list(pending.items()):
                        if idx not in backups and now - t0 > thresh \
                                and attempts[idx] <= self.max_retries:
                            bf = pool.submit(attempt, job)
                            attempts[idx] += 1
                            backups[idx] = bf
                            pending[bf] = (job, idx, now)
                            inflight[idx] += 1
        return [results[i] for i in sorted(results)]


class FleetExecutor(_ExecBase):
    """TPU-native megabatched execution: one computation per job bin.

    Steady state: the executor owns a persistent ``FleetRuntime``
    (core/runtime.py) that keeps each bin's feature state device-resident
    across polls — a warm poll costs O(delta), not O(history). Per-bin
    telemetry (``runtime``/``cache_hit``/``delta_rows``/``retraces``/
    rollout-cache hits+misses) lands in ``last_bin_stats``; opt out per
    deployment with ``user_params["runtime"] = "off"`` or executor-wide
    with ``runtime="off"``.

    Mesh sharding: with >1 jax device the bin's instance axis is partitioned
    across a 1-D fleet mesh via shard_map (``launch.mesh.make_fleet_mesh``) —
    still ONE dispatch per bin, each device training/scoring its N/ndev
    slice. Uneven bins are padded to a shard multiple inside the sharded
    call and the pad rows masked off. Opt out per deployment with
    ``user_params["mesh"] = "off"`` or executor-wide with ``mesh="off"``;
    per-bin telemetry (``mesh_devices``, ``pad``, ``sharded``) lands in
    ``last_bin_stats``.
    """

    def __init__(self, system, *, fallback: Optional[LocalPoolExecutor] = None,
                 mesh: str = "auto", runtime: str = "auto"):
        super().__init__(system)
        self.fallback = fallback or LocalPoolExecutor(system, max_parallel=8)
        self.mesh = mesh                 # "auto" | "off"
        if runtime == "off":
            self.runtime = None
        else:
            from .runtime import FleetRuntime
            self.runtime = FleetRuntime(system)
        self.last_bin_stats: List[dict] = []
        # detect-bin instance cache: detector instances are pure wiring
        # (context + params + system handle, no trained state), identical
        # from one minutely boundary to the next — rebuild only when the
        # deployment store mutates (keyed on its revision)
        self._detect_instances: dict = {}
        # detect-bin band cache: resolved bands per bin, invalidated by
        # PredictionStore.mutations / max_created (see _run_bin)
        self._detect_bands: dict = {}

    def run(self, jobs: List[Job]) -> List[JobResult]:
        """Phase ordering is the executor's responsibility, not the
        caller's: all TRAIN bins complete before any SCORE bin starts (a
        score bin may consume a version trained this cycle), matching
        LocalPoolExecutor.run."""
        out: List[JobResult] = []
        self.last_bin_stats = []
        # single-pass phase partition (three filter scans over a fleet-wide
        # poll were measurable at minutely-detection width)
        trains: List[Job] = []
        detects: List[Job] = []
        scores: List[Job] = []
        t_append, d_append, s_append = (trains.append, detects.append,
                                        scores.append)
        for j in jobs:
            task = j.task
            if task == "detect":
                d_append(j)
            elif task == "train":
                t_append(j)
            else:
                s_append(j)
        tracer = get_tracer()
        for task, phase in (("train", trains), ("score", scores),
                            ("detect", detects)):
            if not phase:
                continue
            # chronological bins regardless of caller order: catch-up
            # occurrences of one deployment must train/score oldest first
            phase.sort(key=_BY_TIME)
            with tracer.span("exec.phase." + task, jobs=len(phase)):
                fleet_bins: List[Tuple[tuple, List[Job]]] = []
                pool_jobs: List[Job] = []
                for key, bin_jobs_ in bin_jobs(phase).items():
                    cls = self.system.registry.get(key[0], key[1])
                    if getattr(cls, "SUPPORTS_FLEET", False):
                        fleet_bins.append((key, bin_jobs_))
                    else:
                        # non-fleet jobs pool into ONE fallback run per
                        # phase: scheduled_at fragments their bins, and
                        # the pool — unlike a megabatch — has no
                        # shared-time-axis reason to run those fragments
                        # sequentially
                        pool_jobs.extend(bin_jobs_)
                if pool_jobs:
                    out.extend(self.fallback.run(pool_jobs))
                for key, bin_jobs_ in fleet_bins:
                    with tracer.span("exec.bin",
                                     bin_id=bin_jobs_[0].bin_id,
                                     jobs=len(bin_jobs_)):
                        out.extend(self._run_bin(key, bin_jobs_))
        return out

    def _bin_mesh(self, bin_jobs_: List[Job]):
        """Fleet mesh for one bin: auto-selected when >1 device and the bin
        is worth splitting; ``user_params["mesh"]="off"`` opts a deployment
        out (bins share user_params, so the first job speaks for all). The
        mesh is sized to min(devices, bin) — a 2-job bin on an 8-device
        host shards over 2 devices, not 8 mostly-padding shards."""
        if self.mesh == "off" or len(bin_jobs_) < 2:
            return None
        dep = self.system.deployments.get(bin_jobs_[0].deployment_name)
        if str(dep.user_params.get("mesh", "auto")).lower() == "off":
            return None
        import jax
        from ..launch.mesh import make_fleet_mesh
        return make_fleet_mesh(min(jax.device_count(), len(bin_jobs_)))

    def _fail(self, job: Job, dt: float, err: str) -> JobResult:
        self.system.scheduler.mark_failed(job)
        return JobResult(job, False, dt, error=err)

    def _run_bin(self, key, bin_jobs_: List[Job]) -> List[JobResult]:
        cls = self.system.registry.get(key[0], key[1])
        out: List[JobResult] = []
        t0 = time.perf_counter()
        store = getattr(self.system, "store", None)
        rm0 = getattr(store, "read_many_count", 0)
        r0 = getattr(store, "read_count", 0)
        task = key[2]
        latests: List = []
        bands: List = []
        if task == "detect":
            # a detection compares against the band a LIVE poller would
            # have had at its boundary (predictions.latest honors rank and
            # at=, the same replay semantics scoring uses for versions); a
            # context with no banded forecast yet fails ALONE, the rest of
            # the bin detects
            preds = self.system.predictions
            at = float(bin_jobs_[0].scheduled_at)
            bkey = (key[0], key[1],
                    tuple(j.deployment_name for j in bin_jobs_))
            # band cache across minutely polls: the resolved bands can
            # only change when a forecast lands (mutations moves) or when
            # a later ``at`` admits an already-stored forecast — excluded
            # by max_created <= cached_at <= at
            cached = self._detect_bands.get(bkey)
            if cached is not None and cached[0] == preds.mutations \
                    and preds.max_created <= cached[1] <= at:
                bands = cached[2]
            else:
                n_bin = len(bin_jobs_)
                present = []
                for j in bin_jobs_:
                    fc = preds.latest(j.signal, j.entity,
                                      at=j.scheduled_at)
                    if fc is None or fc.lower is None:
                        out.append(self._fail(
                            j, 0.0,
                            f"no banded forecast for {j.signal}"
                            f"@{j.entity}"))
                    else:
                        present.append(j)
                        bands.append(fc)
                bin_jobs_ = present
                if not bin_jobs_:
                    return out
                if len(present) == n_bin:       # full bin resolved: the
                    if len(self._detect_bands) >= 8:    # bkey names match
                        self._detect_bands.clear()
                    self._detect_bands[bkey] = (preds.mutations, at, bands)
        elif task != "train":
            # a deployment that was never trained fails ALONE: exclude it
            # from the megabatch, score the rest — one cold model must not
            # poison the whole bin (at-least-once still holds per job).
            # at=scheduled_at: a catch-up bin scores with the versions a
            # live poller would have had at that boundary
            present: List[Job] = []
            for j in bin_jobs_:
                mv = self.system.versions.get(j.deployment_name,
                                              at=j.scheduled_at)
                if mv is None:
                    out.append(self._fail(
                        j, 0.0, f"no trained version for {j.deployment_name}"))
                else:
                    present.append(j)
                    latests.append(mv)
            bin_jobs_ = present
            if not bin_jobs_:
                return out
        # detection is a host-side store compare, nothing to shard
        mesh = None if task == "detect" else self._bin_mesh(bin_jobs_)
        ndev = len(mesh.devices.flat) if mesh is not None else 1
        pad = (-len(bin_jobs_)) % ndev
        if task == "train":
            instances = [self._instantiate(j, cls=cls) for j in bin_jobs_]
        elif task == "detect":
            ikey = bkey if len(bin_jobs_) == len(bkey[2]) else \
                (key[0], key[1],
                 tuple(j.deployment_name for j in bin_jobs_))
            rev = self.system.deployments.revision
            cached = self._detect_instances.get(ikey)
            if cached is not None and cached[0] == rev:
                _, instances, detect_ts_ids, detect_names = cached
            else:
                instances = [self._instantiate(j, latest=None, cls=cls)
                             for j in bin_jobs_]
                detect_ts_ids = [i.context.ts_id for i in instances]
                detect_names = ([i.model_id for i in instances],
                                [i.context.signal.name for i in instances],
                                [i.context.entity.name for i in instances])
                if len(self._detect_instances) >= 8:    # stale-rev bins
                    self._detect_instances.clear()
                self._detect_instances[ikey] = (rev, instances,
                                                detect_ts_ids, detect_names)
        else:       # versions already resolved above: no second lookup
            instances = [self._instantiate(j, latest=mv, cls=cls)
                         for j, mv in zip(bin_jobs_, latests)]
        from ..forecast.base import rollout_cache_stats
        from ..forecast.features import trace_count
        kw = {"mesh": mesh}
        if self.runtime is not None and getattr(cls, "SUPPORTS_RUNTIME",
                                                False):
            kw["runtime"] = self.runtime
        tr0, rc0 = trace_count(), rollout_cache_stats()
        dr0 = getattr(store, "delta_read_count", 0)
        try:
            if task == "train":
                model_objs = cls.fleet_train(instances, **kw)
                for j, mo in zip(bin_jobs_, model_objs):
                    self.system.versions.save(
                        j.deployment_name, mo, trained_at=j.scheduled_at,
                        metadata={"fleet": True, "signal": j.signal,
                                  "entity": j.entity})
            elif task == "detect":
                # ONE vectorized band-compare for the whole bin (one
                # read_many, no per-sensor python loop) through the
                # idempotent DetectionStore — exactly-once per occurrence
                records = cls.fleet_detect(
                    instances, bands,
                    now=float(bin_jobs_[0].scheduled_at),
                    ts_ids=detect_ts_ids, names=detect_names)
                self.system.detections.save_many(records)
            else:
                preds = cls.fleet_score(instances,
                                        [l.params for l in latests],
                                        **kw)
                fcs = []
                for j, l, p in zip(bin_jobs_, latests, preds):
                    times, values = p[0], p[1]
                    lower, upper = (p[2], p[3]) if len(p) > 2 else (None,
                                                                    None)
                    fcs.append(Forecast(
                        deployment_name=j.deployment_name, signal=j.signal,
                        entity=j.entity, created_at=j.scheduled_at,
                        times=times if isinstance(times, np.ndarray)
                        else np.asarray(times),
                        values=values if isinstance(values, np.ndarray)
                        else np.asarray(values),
                        model_version=l.version,
                        rank=self.system.deployments.get(
                            j.deployment_name).rank,
                        lower=None if lower is None else np.asarray(lower),
                        upper=None if upper is None else np.asarray(upper)))
                self.system.predictions.save_many(fcs)
            dt = time.perf_counter() - t0
            per = dt / max(len(bin_jobs_), 1)
            # dataclass __init__ per job is measurable at fleet width:
            # stamp a shared field template and install per-job dicts
            tmpl = {"job": None, "ok": True, "duration_s": per,
                    "attempts": 1, "error": "", "output": None,
                    "speculative_win": False}
            new = JobResult.__new__
            for j in bin_jobs_:
                r = new(JobResult)
                r.__dict__ = dict(tmpl, job=j)
                out.append(r)
            rc1 = rollout_cache_stats()
            stats = {"bin": str(key), "bin_id": bin_jobs_[0].bin_id,
                     "jobs": len(bin_jobs_), "seconds": dt,
                     "read_many_calls":
                         getattr(store, "read_many_count", 0) - rm0,
                     "single_reads": getattr(store, "read_count", 0) - r0,
                     "delta_reads":
                         getattr(store, "delta_read_count", 0) - dr0,
                     "sharded": mesh is not None, "mesh_devices": ndev,
                     "pad": pad, "dispatches": 1,
                     "retraces": trace_count() - tr0,
                     "rollout_cache_hits": rc1["hits"] - rc0["hits"],
                     "rollout_cache_misses": rc1["misses"] - rc0["misses"],
                     "runtime": "off", "cache_hit": False, "delta_rows": 0}
            if self.runtime is not None:
                stats.update(self.runtime.pop_stats())
            self.last_bin_stats.append(stats)
            # absorb the bin's telemetry into the metrics registry (once
            # per bin — off the per-job hot path)
            m = get_metrics()
            m.counter("exec.bins").inc()
            m.counter("exec.jobs").inc(stats["jobs"])
            m.histogram("exec.bin_seconds").observe(dt)
            m.counter("exec.retraces").inc(stats["retraces"])
            m.counter("exec.rollout_cache_hits").inc(
                stats["rollout_cache_hits"])
            m.counter("exec.rollout_cache_misses").inc(
                stats["rollout_cache_misses"])
            if stats["cache_hit"]:
                m.counter("runtime.cache_hits").inc()
            if stats["delta_rows"]:
                m.counter("runtime.delta_rows").inc(stats["delta_rows"])
        except Exception as e:  # noqa: BLE001
            dt = time.perf_counter() - t0
            err = f"{type(e).__name__}: {e}"
            if self.runtime is not None:
                self.runtime.pop_stats()        # don't leak into next bin
            out.extend(self._fail(j, dt / len(bin_jobs_), err)
                       for j in bin_jobs_)
        return out
