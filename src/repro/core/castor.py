"""The Castor system facade: wires the knowledge store, registry, deployments,
scheduler, executors and lineage into the paper's workflow (Fig. 1):

    (1) ingest -> (2) semantics -> (3/4) implement+publish -> (5/6) deploy ->
    (7) schedule -> (8/9) execute -> (10) persist forecasts.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from ..obs.metrics import get_metrics
from ..obs.trace import get_tracer
from ..timeseries.store import TimeSeriesStore
from ..timeseries.weather import WeatherService
from .deployment import DeploymentStore, ModelDeployment, deploy_for_all
from .executor import FleetExecutor, JobResult, LocalPoolExecutor
from .lineage import ModelVersionStore, PredictionStore
from .registry import ModelRegistry
from .scheduler import ModelScheduler, Schedule
from .semantics import Context, Entity, SemanticGraph, Signal


class Castor:
    def __init__(self, *, weather_seed: int = 7):
        self.weather_seed = weather_seed
        self.store = TimeSeriesStore()
        self.graph = SemanticGraph()
        self.registry = ModelRegistry()
        self.deployments = DeploymentStore()
        self.versions = ModelVersionStore()
        self.predictions = PredictionStore()
        from ..flows.detection import DetectionStore
        self.detections = DetectionStore(self.store, self.graph)
        self.weather = WeatherService(seed=weather_seed)
        self.scheduler = ModelScheduler(self.deployments, self.registry)
        self.journal = None            # durability.Journal when open()'d
        self._durable_storage = None   # backend owned by open(path=...)

    # ---------------- durability (WAL + recovery) ----------------
    @classmethod
    def open(cls, path: Optional[str] = None, *, storage=None,
             weather_seed: int = 7, fsync: bool = True,
             snapshot_every: int = 64,
             max_buffer_bytes: int = 4 << 20,
             retain_segments: bool = False,
             pipelined_commit: bool = True) -> "Castor":
        """Open a DURABLE Castor: recover state from ``path`` (a WAL+
        snapshot directory; created empty if absent) or any
        ``StorageBackend`` via ``storage=``, then journal every
        system-of-record mutation from here on. Records group-commit as
        one fsync'd segment per ``tick`` (plus a ``max_buffer_bytes``
        overflow flush), and every ``snapshot_every`` commits the log
        compacts into a full-state snapshot.

        Recovery replays snapshot-then-WAL into bitwise-equal stores and
        re-arms the calendar queue; a torn/corrupt WAL tail (crash
        mid-write) is dropped at the first bad checksum, and the
        boundary-stamped catch-up machinery re-fires anything the lost
        suffix contained. Model *implementations* are code, not data —
        re-``publish`` packages after opening, then ``deploy_for_all``/
        ``tick`` as usual.

        ``pipelined_commit`` (default on) hands each segment put to a
        writer thread so tick k's fsync overlaps tick k+1's compute; at
        most one write is ever in flight and segments land in order, so
        a crash still loses only a suffix of recent work. ``close()``
        (and ``Journal.barrier()``) block until the last write lands."""
        from ..durability.journal import (Journal, load_records, meta_of,
                                          replay_records)
        owned = None
        if storage is None:
            if path is None:
                raise ValueError("Castor.open needs a path or a storage=")
            from ..serverless.storage import FilesystemStorage
            storage = owned = FilesystemStorage(root=path, fsync=fsync)
        records, rec_stats = load_records(storage)
        meta = meta_of(records)
        if meta is not None:
            weather_seed = int(meta.get("weather_seed", weather_seed))
        c = cls(weather_seed=weather_seed)
        replay_records(c, records)     # journal-less: replay re-journals
        journal = Journal(storage, castor=c,          # nothing
                          snapshot_every=snapshot_every,
                          max_buffer_bytes=max_buffer_bytes,
                          retain_segments=retain_segments,
                          pipelined=pipelined_commit)
        journal.start_at(rec_stats["next_seq"])
        c._recovery_stats = rec_stats
        c._durable_storage = owned
        c._attach_journal(journal)
        if meta is None:               # first open: persist the seed
            journal.append("meta", {"format": 1,
                                    "weather_seed": weather_seed})
        return c

    def _attach_journal(self, journal) -> None:
        """Point every system of record at the journal. Hooks fire inside
        the stores' own locks; the journal's lock nests strictly inside
        and never calls back out, so lock order is acyclic."""
        self.journal = journal
        for store in (self.store, self.versions, self.predictions,
                      self.detections, self.deployments, self.graph):
            store.journal = journal

    def _detach_journal(self) -> None:
        self.journal = None
        for store in (self.store, self.versions, self.predictions,
                      self.detections, self.deployments, self.graph):
            store.journal = None

    def _commit_tick(self) -> None:
        """Group-commit one tick's records: the scheduler's watermark/
        retry delta journals as ONE atomic record AFTER the tick's
        effects (so a torn tail can only under-report progress, never
        drop effects a watermark already covers), then the whole buffer
        flushes as one segment — one storage put / fsync per tick, not
        per record."""
        j = self.journal
        if j is None:
            return
        delta = self.scheduler.drain_dirty()
        if delta is not None:
            j.append("sched", delta)
        j.commit()

    # ---------------- (1)/(2) data + semantics ----------------
    def ingest(self, ts_id: str, times, values) -> int:
        return self.store.append(ts_id, times, values)

    def add_signal(self, name: str, unit: str = "", description: str = "") -> Signal:
        return self.graph.add_signal(Signal(name, unit, description))

    def add_entity(self, name: str, kind: str = "ENTITY", lat: float = 0.0,
                   lon: float = 0.0, parent: Optional[str] = None) -> Entity:
        return self.graph.add_entity(Entity(name, kind, lat, lon), parent)

    def link(self, ts_id: str, signal: str, entity: str) -> Context:
        return self.graph.link_timeseries(ts_id, signal, entity)

    # ---------------- (3)/(4) implementations ----------------
    def publish(self, package: str, version: str, cls):
        return self.registry.register(package, version, cls)

    # ---------------- (5)/(6) deployments ----------------
    def deploy(self, dep: ModelDeployment) -> ModelDeployment:
        return self.deployments.register(dep)

    def deploy_for_all(self, **kw) -> List[ModelDeployment]:
        return deploy_for_all(self.graph, self.deployments, **kw)

    def deploy_detections(self, **kw) -> List[ModelDeployment]:
        """Detection-flow fleet deployment: one minutely
        ``DetectionDeployment`` per entity carrying ``signal`` (see
        repro.flows.detection.deploy_detections_for_all)."""
        from ..flows.detection import deploy_detections_for_all
        return deploy_detections_for_all(self.graph, self.deployments, **kw)

    def undeploy(self, name: str) -> None:
        """Remove a deployment. The store's listener protocol clears the
        scheduler's calendar entry, watermark and queued retries for the
        name, so a later same-name ``deploy`` fires from scratch (and a
        redeploy with an edited ``Schedule`` re-keys the calendar)."""
        self.deployments.remove(name)

    # ---------------- (7)-(10) execution ----------------
    def tick(self, now: float, *, executor: str = "fleet",
             max_parallel: int = 16) -> List[JobResult]:
        """One scheduler cycle: poll due jobs, execute, persist.

        ``executor`` names an engine behind the shared ``run(jobs)``
        protocol (see core/executor.py): "fleet" (megabatched; its
        ``FleetRuntime`` persists across ticks so consecutive polls pay
        O(delta) instead of O(history) — see core/runtime.py),
        "serverless" (the invocation pipeline in repro/serverless/; its
        warm workers also persist across ticks), or "local" (the
        paper-faithful stateless pool, built per call)."""
        tracer = self.tracer
        with tracer.span("castor.tick", now=now, executor=executor):
            jobs = self.scheduler.poll(now)
            if not jobs:
                with tracer.span("journal.commit"):
                    self._commit_tick()    # flush buffered ingest records
                return []
            if executor == "fleet":
                ex = self.fleet_executor(max_parallel=max_parallel)
            elif executor == "serverless":
                # honored on FIRST construction (the executor is cached)
                ex = self.serverless_executor(max_in_flight=max_parallel)
            elif executor == "local":
                ex = LocalPoolExecutor(self, max_parallel=max_parallel)
            else:
                raise ValueError(f"unknown executor {executor!r} "
                                 "(expected fleet | serverless | local)")
            try:
                return ex.run(jobs)
            finally:
                # the group-commit point: effects first, then the
                # scheduler delta, one segment put — even when the
                # executor raised (any persisted effects plus
                # ``mark_failed`` retry stamps)
                with tracer.span("journal.commit"):
                    self._commit_tick()

    def fleet_executor(self, *, max_parallel: int = 16) -> FleetExecutor:
        """The system's long-lived fleet executor (steady-state runtime
        state lives here); rebuilt only if the pool size changes."""
        cached = getattr(self, "_fleet_ex", None)
        if cached is None or cached[0] != max_parallel:
            ex = FleetExecutor(self, fallback=LocalPoolExecutor(
                self, max_parallel=max_parallel))
            self._fleet_ex = cached = (max_parallel, ex)
        return cached[1]

    def serverless_executor(self, **kw):
        """The system's long-lived serverless executor (warm-container
        affinity lives here — its workers' FleetRuntimes stay warm across
        ticks). Keyword args configure only the FIRST construction;
        rebuild explicitly via ``repro.serverless.ServerlessExecutor``
        for custom backends."""
        ex = getattr(self, "_serverless_ex", None)
        if ex is None:
            from ..serverless import ServerlessExecutor
            ex = self._serverless_ex = ServerlessExecutor(self, **kw)
        return ex

    def run_until(self, t0: float, t1: float, step: float,
                  executor: str = "fleet") -> List[JobResult]:
        """Index-based stepping (``t = t0 + k*step``, never ``t += step``):
        accumulated float error over a long simulated horizon would
        otherwise drift the poll instants off the scheduler's boundary
        lattice — skipping or double-firing occurrences near the end.
        The step count is fixed up front with a relative epsilon so a
        final boundary whose ``k*step`` rounds a hair above ``t1`` (e.g.
        t0=0, t1=0.3, step=0.1) still fires; a t1 genuinely between
        boundaries floors, never overshoots."""
        if step <= 0:
            raise ValueError(f"step must be positive, got {step}")
        out = []
        r = (t1 - t0) / step
        n = max(0, int(r + 1e-9 * max(1.0, r))) if t1 >= t0 else -1
        for k in range(n + 1):
            out.extend(self.tick(t0 + k * step, executor=executor))
        return out

    # ---------------- retrieval (semantic APIs) ----------------
    def read(self, signal: str, entity: str, start=None, end=None):
        ctx = self.graph.context(signal, entity)
        return self.store.read(ctx.ts_id, start, end)

    def read_many(self, pairs, start=None, end=None):
        """Batched semantic reads: ``pairs`` is [(signal, entity), ...];
        all series are fetched in ONE ``store.read_many`` round-trip."""
        ids = [self.graph.context(s, e).ts_id for s, e in pairs]
        return self.store.read_many(ids, start, end)

    def compact(self):
        """Consolidate every series to one sorted segment (post-bulk-ingest
        hook so the next fleet read is a pure binary-search slice)."""
        self.store.compact()

    def best_forecast(self, signal: str, entity: str,
                      at: Optional[float] = None, *,
                      return_bands: bool = False):
        """Best-ranked most-recent forecast for a context (``at=`` replays
        the forecast a live consumer would have seen at that instant).
        With ``return_bands=True`` returns ``(times, values, lower,
        upper)`` — the q10/q90 prediction band alongside the point
        forecast (lower/upper are None for band-less models) — or None if
        no forecast exists."""
        fc = self.predictions.latest(signal, entity, at)
        if not return_bands:
            return fc
        if fc is None:
            return None
        return fc.times, fc.values, fc.lower, fc.upper

    # ---------------- observability plane (repro.obs) ----------------
    @property
    def tracer(self):
        """The process-global span tracer (obs/trace.py). A property,
        not a constructor capture: ``obs.trace.set_tracer`` swaps (and
        ``.enabled`` toggles) take effect immediately everywhere."""
        return get_tracer()

    @property
    def metrics(self):
        """The process-global metrics registry (obs/metrics.py)."""
        return get_metrics()

    def dump_trace(self, path) -> str:
        """Write every buffered span as Chrome trace-event JSON — open
        the file at ui.perfetto.dev (or chrome://tracing)."""
        from ..obs.export import write_chrome_trace
        return str(write_chrome_trace(path, self.tracer))

    def _mirror_metrics(self) -> None:
        """Absorb the scattered per-subsystem counters into the one
        namespaced registry (snapshot-time mirroring: the hot paths that
        maintain these counters stay untouched)."""
        m = self.metrics
        st = self.store.stats()
        m.gauge("store.points").set(st["points"])
        m.gauge("store.segments").set(st["segments"])
        m.gauge("store.reads").set(st["reads"])
        m.gauge("store.read_many").set(st["read_many"])
        m.gauge("store.delta_reads").set(st["delta_reads"])
        from ..forecast.base import rollout_cache_stats
        rc = rollout_cache_stats()
        m.gauge("rollout_cache.hits").set(rc["hits"])
        m.gauge("rollout_cache.misses").set(rc["misses"])
        from ..forecast.features import trace_count
        m.gauge("jit.retrace.total").set(trace_count())
        sched = self.scheduler.stats()
        m.gauge("scheduler.heap_entries").set(sched["heap_entries"])
        m.gauge("scheduler.tracked").set(sched["tracked"])
        m.gauge("scheduler.interned_bins").set(sched["interned_bins"])
        cached = getattr(self, "_fleet_ex", None)
        rt = cached[1].runtime if cached is not None else None
        if rt is not None:
            m.gauge("runtime.cold_loads").set(rt.cold_loads)
            m.gauge("runtime.warm_loads").set(rt.warm_loads)
            m.gauge("runtime.invalidations").set(rt.invalidations)
        if self.journal is not None:
            js = self.journal.stats()
            m.gauge("wal.records").set(js["records"])
            m.gauge("wal.segments").set(js["segments"])
            m.gauge("wal.snapshots").set(js["snapshots"])
            m.gauge("wal.bytes_written").set(js["bytes_written"])

    def snapshot(self) -> dict:
        """The unified observability snapshot: ``{"stats": <the exact
        dict stats() returns>, "metrics": <registry snapshot>,
        "trace": <tracer ring stats>}``. ``stats()`` is the
        backward-compatible view over this snapshot's ``"stats"`` key."""
        from ..obs.export import obs_snapshot
        self._mirror_metrics()
        return obs_snapshot(self.stats(), self.tracer, self.metrics)

    def stats(self) -> dict:
        st = self.store.stats()
        out = {**self.graph.stats(),
               "points": st["points"],
               "segments": st["segments"],
               "store_reads": st["reads"],
               "store_read_many": st["read_many"],
               "deployments": len(self.deployments),
               "deployments_by_flow": self.deployments.flow_counts(),
               "deployment_revision": self.deployments.revision,
               "model_versions": self.versions.count(),
               "forecasts": self.predictions.count(),
               # detection-flow telemetry: records, scored readings,
               # anomalies flagged, band-miss rate (flows/detection.py)
               "detection": self.detections.stats(),
               # control-plane telemetry: calendar-queue depth + interned
               # bin count (core/scheduler.py)
               "scheduler": self.scheduler.stats()}
        sv = getattr(self, "_serverless_ex", None)
        if sv is not None:
            # per-invocation cold/warm-start + queue/execution latency
            # telemetry from the serverless monitor (repro/serverless/),
            # plus elastic-pool / chaos / storage sub-summaries when the
            # executor was built with those features
            out["serverless"] = sv.stats()
        if self.journal is not None:
            # WAL telemetry: records/segments/snapshots written, bytes,
            # group-commit overflow flushes (durability/journal.py)
            out["durability"] = self.journal.stats()
        return out

    def close(self) -> None:
        """Release long-lived execution resources: flush+close the
        durability journal (any buffered WAL records and the scheduler's
        undrained delta fsync BEFORE the storage backend — possibly an
        owned tempdir — is released), then the cached serverless
        executor's backend (spawned worker processes, owned storage
        buckets). Idempotent: double-close and ``__exit__`` after an
        explicit ``close()`` are no-ops; the in-memory stores stay
        usable."""
        j = getattr(self, "journal", None)
        if j is not None:
            delta = self.scheduler.drain_dirty()
            if delta is not None:
                j.append("sched", delta)
            j.close()
            self._detach_journal()     # journal=None: re-close is a no-op
        owned = getattr(self, "_durable_storage", None)
        if owned is not None:
            self._durable_storage = None
            owned.close()
        sv = getattr(self, "_serverless_ex", None)
        if sv is not None:
            self._serverless_ex = None
            sv.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


MINUTE = 60.0
HOUR = 3600.0
DAY = 24 * HOUR
WEEK = 7 * DAY
__all__ = ["Castor", "Schedule", "ModelDeployment", "MINUTE", "HOUR",
           "DAY", "WEEK"]
