"""Model-implementation registry + the 4-function model interface (Listing 1).

An *implementation* is reusable code (load / transform / train / score); a
*deployment* (deployment.py) binds it to a semantic context and schedules.
The registry plays the paper's PyPI role: versioned artifacts, latest-wins
resolution, retrieval at execution time.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Type

from .semantics import Context


class ModelInterface(abc.ABC):
    """Paper Listing 1. Subclasses implement load/transform/train/score.

    Runtime-populated attributes (transparently provided by the execution
    engine, §3.1): ``context``, ``task``, ``model_id``, ``model_version``,
    ``user_params``, ``system`` (data access: .store, .graph, .weather).
    """

    #: subclasses that support fleet (megabatched) execution set this True and
    #: implement the fleet_* classmethods below.
    SUPPORTS_FLEET = False

    def __init__(self, context: Context, task: str, model_id: str,
                 model_version: Optional[int], user_params: dict, system):
        self.context = context
        self.task = task
        self.model_id = model_id
        self.model_version = model_version
        self.user_params = dict(user_params or {})
        self.system = system

    @abc.abstractmethod
    def load(self):
        """Fetch raw data (semantic store, weather, ...)."""

    @abc.abstractmethod
    def transform(self):
        """Feature engineering on loaded data."""

    @abc.abstractmethod
    def train(self) -> Any:
        """Return a model object (fitted parameters + metadata)."""

    @abc.abstractmethod
    def score(self, model_object) -> Tuple[Any, Any]:
        """Return (times, values) prediction over the configured horizon."""

    # ---- optional fleet hooks (megabatched execution, DESIGN.md §2) ----
    # ``mesh``: optional 1-D jax device mesh (launch/mesh.make_fleet_mesh);
    # when given, the bin's instance axis is shard_map-partitioned across
    # its devices. None = single-device vmap, identical results.
    @classmethod
    def fleet_train(cls, instances: List["ModelInterface"], *, mesh=None):
        raise NotImplementedError

    @classmethod
    def fleet_score(cls, instances: List["ModelInterface"], model_objects, *,
                    mesh=None):
        raise NotImplementedError


@dataclass(frozen=True)
class ImplementationKey:
    package: str
    version: str

    def __str__(self):
        return f"{self.package}=={self.version}"


class ModelRegistry:
    """Versioned registry of implementation classes (the paper's PyPI)."""

    def __init__(self):
        self._impls: Dict[str, Dict[str, Type[ModelInterface]]] = {}

    def register(self, package: str, version: str,
                 cls: Type[ModelInterface]) -> ImplementationKey:
        assert issubclass(cls, ModelInterface), cls
        self._impls.setdefault(package, {})
        if version in self._impls[package]:
            raise ValueError(f"{package}=={version} already published "
                             "(artifacts are immutable)")
        self._impls[package][version] = cls
        return ImplementationKey(package, version)

    def get(self, package: str, version: Optional[str] = None) -> Type[ModelInterface]:
        versions = self._impls.get(package)
        if not versions:
            raise KeyError(f"package {package} not found")
        if version is None:
            version = max(versions, key=_version_key)
        return versions[version]

    def resolve_version(self, package: str, version: Optional[str] = None) -> str:
        versions = self._impls[package]
        return version if version is not None else max(versions, key=_version_key)

    def list(self) -> List[str]:
        return [f"{p}=={v}" for p, vs in sorted(self._impls.items())
                for v in sorted(vs, key=_version_key)]


def _version_key(v: str):
    try:
        return tuple(int(x) for x in v.split("."))
    except ValueError:
        return (0,), v
