"""Knowledge-based representation of IoT data (paper §2, §4.1, Fig. 3).

Every time-series is a node connected to a ``Signal`` concept (what physical
quantity) and an ``Entity`` concept (where / what thing); entity topology
(prosumer -> feeder -> substation) is an edge set. Model code expresses
feature engineering against these concepts, which is what enables
programmatic fleet deployment ("deploy this forecaster to every entity with
an ENERGY_LOAD signal").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple


@dataclass(frozen=True)
class Signal:
    name: str                      # e.g. ENERGY_LOAD
    unit: str = ""                 # e.g. kWh
    description: str = ""


@dataclass(frozen=True)
class Entity:
    name: str                      # e.g. SUBSTATION_S1
    kind: str = "ENTITY"           # SUBSTATION | FEEDER | PROSUMER | ...
    lat: float = 0.0
    lon: float = 0.0


@dataclass(frozen=True)
class Context:
    """A semantic context = (signal, entity) + its time-series node."""
    signal: Signal
    entity: Entity
    ts_id: str

    @property
    def key(self) -> Tuple[str, str]:
        return (self.signal.name, self.entity.name)


class SemanticGraph:
    def __init__(self):
        self.signals: Dict[str, Signal] = {}
        self.entities: Dict[str, Entity] = {}
        self._edges: Dict[str, Set[str]] = {}          # parent -> children
        self._parents: Dict[str, str] = {}             # child -> parent
        self._ts: Dict[Tuple[str, str], str] = {}      # (signal, entity) -> ts_id
        self._ts_rev: Dict[str, Tuple[str, str]] = {}

    # ---------------- concept definition ----------------
    def add_signal(self, sig: Signal) -> Signal:
        self.signals[sig.name] = sig
        return sig

    def add_entity(self, ent: Entity, parent: Optional[str] = None) -> Entity:
        self.entities[ent.name] = ent
        if parent is not None:
            assert parent in self.entities, f"unknown parent {parent}"
            self._edges.setdefault(parent, set()).add(ent.name)
            self._parents[ent.name] = parent
        return ent

    def link_timeseries(self, ts_id: str, signal: str, entity: str) -> Context:
        """Attach semantics to an ingested series (paper step (2))."""
        assert signal in self.signals, f"unknown signal {signal}"
        assert entity in self.entities, f"unknown entity {entity}"
        self._ts[(signal, entity)] = ts_id
        self._ts_rev[ts_id] = (signal, entity)
        return self.context(signal, entity)

    # ---------------- queries (semantic reasoning) ----------------
    def context(self, signal: str, entity: str) -> Context:
        ts_id = self._ts.get((signal, entity))
        if ts_id is None:
            # contexts may exist before data arrives (predictions attach here)
            ts_id = f"ts::{signal}::{entity}"
            self._ts[(signal, entity)] = ts_id
            self._ts_rev[ts_id] = (signal, entity)
        return Context(self.signals[signal], self.entities[entity], ts_id)

    def has_series(self, signal: str, entity: str) -> bool:
        return (signal, entity) in self._ts

    def children(self, entity: str) -> List[Entity]:
        return [self.entities[c] for c in sorted(self._edges.get(entity, ()))]

    def parent(self, entity: str) -> Optional[Entity]:
        p = self._parents.get(entity)
        return self.entities[p] if p else None

    def descendants(self, entity: str) -> List[Entity]:
        out, stack = [], [entity]
        while stack:
            for c in sorted(self._edges.get(stack.pop(), ())):
                out.append(self.entities[c])
                stack.append(c)
        return out

    def find_entities(self, kind: Optional[str] = None,
                      has_signal: Optional[str] = None,
                      under: Optional[str] = None) -> List[Entity]:
        """The fleet-deployment query: all entities matching semantic rules."""
        cand: Iterable[Entity] = self.entities.values()
        if under is not None:
            cand = self.descendants(under)
        out = []
        for e in cand:
            if kind is not None and e.kind != kind:
                continue
            if has_signal is not None and (has_signal, e.name) not in self._ts:
                continue
            out.append(e)
        return sorted(out, key=lambda e: e.name)

    def contexts_for_signal(self, signal: str) -> List[Context]:
        return [self.context(s, e) for (s, e) in sorted(self._ts) if s == signal]

    def signal_of(self, ts_id: str) -> Optional[str]:
        pair = self._ts_rev.get(ts_id)
        return pair[0] if pair else None

    def stats(self) -> dict:
        return {"signals": len(self.signals), "entities": len(self.entities),
                "timeseries": len(self._ts), "edges": sum(map(len, self._edges.values()))}
