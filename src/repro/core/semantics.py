"""Knowledge-based representation of IoT data (paper §2, §4.1, Fig. 3).

Every time-series is a node connected to a ``Signal`` concept (what physical
quantity) and an ``Entity`` concept (where / what thing); entity topology
(prosumer -> feeder -> substation) is an edge set. Model code expresses
feature engineering against these concepts, which is what enables
programmatic fleet deployment ("deploy this forecaster to every entity with
an ENERGY_LOAD signal").

Scale architecture (the Castor companion paper frames the knowledge layer
as the thing that must stay cheap as the application grows): concepts are
**interned** — every signal/entity gets a dense int handle at definition
time — and all topology/index state lives in int space:

* adjacency lists ``_children``/``_parents`` over entity ids;
* an inverted signal -> entity-ids index, so
  ``find_entities(has_signal=...)`` and ``contexts_for_signal`` touch
  only that signal's entities, never scan all entities or series;
* a per-kind entity-id index for ``find_entities(kind=...)``;
* memoized ``descendants`` per root id, invalidated on edge insert by
  walking the new edge's ancestor chain (only the roots whose subtree
  actually changed recompute).

Queries still return name-sorted ``Entity``/``Context`` objects — sorting
happens on the RESULT set, so cost is O(matches log matches), flat in
graph size."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from .interning import InternTable


@dataclass(frozen=True)
class Signal:
    name: str                      # e.g. ENERGY_LOAD
    unit: str = ""                 # e.g. kWh
    description: str = ""


@dataclass(frozen=True)
class Entity:
    name: str                      # e.g. SUBSTATION_S1
    kind: str = "ENTITY"           # SUBSTATION | FEEDER | PROSUMER | ...
    lat: float = 0.0
    lon: float = 0.0


@dataclass(frozen=True)
class Context:
    """A semantic context = (signal, entity) + its time-series node."""
    signal: Signal
    entity: Entity
    ts_id: str

    @property
    def key(self) -> Tuple[str, str]:
        return (self.signal.name, self.entity.name)


class SemanticGraph:
    def __init__(self):
        self.signals: Dict[str, Signal] = {}
        self.entities: Dict[str, Entity] = {}
        self._ent_ids = InternTable()                  # name <-> int handle
        self._sig_ids = InternTable()
        self._children: Dict[int, List[int]] = {}      # parent id -> child ids
        self._parents: Dict[int, int] = {}             # child id -> parent id
        # every parent an entity was EVER linked under (re-parenting keeps
        # the old edge, matching the scanner): memo invalidation must walk
        # all upward paths, not just the latest one
        self._all_parents: Dict[int, Set[int]] = {}
        self._ts: Dict[Tuple[str, str], str] = {}      # (signal, entity) -> ts_id
        self._ts_rev: Dict[str, Tuple[str, str]] = {}
        self._sig_ents: Dict[int, Set[int]] = {}       # signal id -> entity ids
        self._kind_ents: Dict[str, Set[int]] = {}      # kind -> entity ids
        self._desc_memo: Dict[int, List[str]] = {}     # root id -> desc names
        self.journal = None           # durability.Journal when Castor.open'd

    # ---------------- int handles ----------------
    def entity_id(self, name: str) -> int:
        """Dense int handle of an entity (stable for the graph's life)."""
        i = self._ent_ids.get(name)
        if i is None:
            raise KeyError(f"unknown entity {name}")
        return i

    def signal_id(self, name: str) -> int:
        i = self._sig_ids.get(name)
        if i is None:
            raise KeyError(f"unknown signal {name}")
        return i

    # ---------------- concept definition ----------------
    def add_signal(self, sig: Signal) -> Signal:
        changed = self.signals.get(sig.name) != sig
        self.signals[sig.name] = sig
        self._sig_ids.intern(sig.name)
        j = self.journal
        if j is not None and changed:      # idempotent re-adds stay silent
            j.append("sig", {"name": sig.name, "unit": sig.unit,
                             "description": sig.description})
        return sig

    def add_entity(self, ent: Entity, parent: Optional[str] = None) -> Entity:
        prev = self.entities.get(ent.name)
        eid = self._ent_ids.intern(ent.name)
        if prev is not None and prev.kind != ent.kind:
            self._kind_ents.get(prev.kind, set()).discard(eid)
        self.entities[ent.name] = ent
        self._kind_ents.setdefault(ent.kind, set()).add(eid)
        changed = prev != ent
        if parent is not None:
            assert parent in self.entities, f"unknown parent {parent}"
            pid = self._ent_ids.intern(parent)
            if self._parents.get(eid) != pid:
                changed = True
            siblings = self._children.setdefault(pid, [])
            if eid not in siblings:
                siblings.append(eid)
                self._invalidate_descendants(pid)
            self._parents[eid] = pid
            self._all_parents.setdefault(eid, set()).add(pid)
        j = self.journal
        if j is not None and changed:      # idempotent re-adds stay silent
            j.append("ent", {"name": ent.name, "kind": ent.kind,
                             "lat": ent.lat, "lon": ent.lon,
                             "parent": parent})
        return ent

    def _invalidate_descendants(self, pid: int) -> None:
        """A new edge under ``pid`` changes the descendant set of ``pid``
        and every ancestor above it — drop exactly those memos (the rest
        of the graph's memoized subtrees stay warm). Walks ALL recorded
        upward edges, so a subtree reachable through a since-replaced
        parent link still invalidates."""
        seen: Set[int] = set()
        stack = [pid]
        while stack:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            self._desc_memo.pop(cur, None)
            stack.extend(self._all_parents.get(cur, ()))

    def _link(self, signal: str, entity: str, ts_id: str) -> None:
        self._ts[(signal, entity)] = ts_id
        self._ts_rev[ts_id] = (signal, entity)
        self._sig_ents.setdefault(self._sig_ids.intern(signal),
                                  set()).add(self._ent_ids.intern(entity))

    def link_timeseries(self, ts_id: str, signal: str, entity: str) -> Context:
        """Attach semantics to an ingested series (paper step (2))."""
        assert signal in self.signals, f"unknown signal {signal}"
        assert entity in self.entities, f"unknown entity {entity}"
        changed = self._ts.get((signal, entity)) != ts_id
        self._link(signal, entity, ts_id)
        j = self.journal
        if j is not None and changed:
            # explicit links only: the ``context()`` auto-created
            # ``ts::{signal}::{entity}`` node is deterministic and
            # regenerates identically on first touch after recovery
            j.append("lnk", {"ts_id": ts_id, "signal": signal,
                             "entity": entity})
        return self.context(signal, entity)

    # ---------------- queries (semantic reasoning) ----------------
    def context(self, signal: str, entity: str) -> Context:
        ts_id = self._ts.get((signal, entity))
        if ts_id is None:
            # contexts may exist before data arrives (predictions attach here)
            ts_id = f"ts::{signal}::{entity}"
            self._link(signal, entity, ts_id)
        return Context(self.signals[signal], self.entities[entity], ts_id)

    def has_series(self, signal: str, entity: str) -> bool:
        return (signal, entity) in self._ts

    def _name(self, eid: int) -> str:
        return self._ent_ids.value(eid)

    def children(self, entity: str) -> List[Entity]:
        eid = self._ent_ids.get(entity)
        kids = self._children.get(eid, ()) if eid is not None else ()
        return [self.entities[n] for n in sorted(map(self._name, kids))]

    def parent(self, entity: str) -> Optional[Entity]:
        eid = self._ent_ids.get(entity)
        pid = self._parents.get(eid) if eid is not None else None
        return self.entities[self._name(pid)] if pid is not None else None

    def _descendant_names(self, root: int) -> List[str]:
        """Memoized transitive closure under one root, in the traversal
        order the scanner always produced (a pure function of the tree
        shape — children visited name-sorted — so it is insertion-order
        independent). Memos are dropped by ``_invalidate_descendants``
        when an edge lands in the subtree."""
        memo = self._desc_memo.get(root)
        if memo is None:
            out: List[str] = []
            stack = [root]
            while stack:
                kids = self._children.get(stack.pop(), ())
                for name in sorted(map(self._name, kids)):
                    out.append(name)
                    stack.append(self._ent_ids.intern(name))
            self._desc_memo[root] = memo = out
        return memo

    def descendants(self, entity: str) -> List[Entity]:
        eid = self._ent_ids.get(entity)
        if eid is None:
            return []
        return [self.entities[n] for n in self._descendant_names(eid)]

    def find_entities(self, kind: Optional[str] = None,
                      has_signal: Optional[str] = None,
                      under: Optional[str] = None) -> List[Entity]:
        """The fleet-deployment query: all entities matching semantic
        rules. Each predicate is an index: the candidate set starts from
        the most selective one given and the rest filter by membership —
        no predicate ever walks all entities (the no-predicate call
        returns the whole graph by definition)."""
        cand: Optional[Set[int]] = None
        if has_signal is not None:
            sid = self._sig_ids.get(has_signal)
            ents = self._sig_ents.get(sid, set()) if sid is not None else set()
            cand = set(ents)
        if kind is not None:
            ents = self._kind_ents.get(kind, set())
            cand = set(ents) if cand is None else cand & ents
        if under is not None:
            uid = self._ent_ids.get(under)
            down = ({self._ent_ids.intern(n)
                     for n in self._descendant_names(uid)}
                    if uid is not None else set())
            cand = down if cand is None else cand & down
        if cand is None:
            names = list(self.entities)
        else:
            names = [self._name(i) for i in cand]
        return [self.entities[n] for n in sorted(names)]

    def contexts_for_signal(self, signal: str) -> List[Context]:
        """All contexts carrying one signal, entity-name-sorted — an
        inverted-index hit, not a scan of every linked series."""
        sid = self._sig_ids.get(signal)
        ents = self._sig_ents.get(sid, ()) if sid is not None else ()
        return [self.context(signal, n)
                for n in sorted(map(self._name, ents))]

    def signal_of(self, ts_id: str) -> Optional[str]:
        pair = self._ts_rev.get(ts_id)
        return pair[0] if pair else None

    def stats(self) -> dict:
        return {"signals": len(self.signals), "entities": len(self.entities),
                "timeseries": len(self._ts),
                "edges": sum(map(len, self._children.values()))}
