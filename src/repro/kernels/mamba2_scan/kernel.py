"""Pallas TPU chunked SSD (Mamba2) scan.

TPU adaptation of the paper's (CUDA) parallel-scan formulation: all O(S) work
becomes dense (chunk x chunk) / (chunk x N) MXU matmuls in VMEM; only the
n_chunks-long inter-chunk recurrence is sequential, carried in a VMEM scratch
state of shape (P, N) per (batch, head). Grid: (B, H, chunks) with the chunk
dimension innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, st_out_ref,
            state_ref, *, chunk: int, nc: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, :, 0].astype(jnp.float32)            # (c, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)          # (c,)
    A = A_ref[0].astype(jnp.float32)                     # ()
    Bm = B_ref[0, 0, :, 0].astype(jnp.float32)           # (c, N)
    Cm = C_ref[0, 0, :, 0].astype(jnp.float32)           # (c, N)
    Dh = D_ref[0].astype(jnp.float32)                    # ()

    seg = dt * A                                         # (c,)
    cum = jnp.cumsum(seg)                                # inclusive
    total = cum[-1]

    # intra-chunk causal kernel L[t,u] = exp(cum[t]-cum[u]), u <= t
    rel = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(rel), 0.0)

    CB = Cm @ Bm.T                                       # (c_t, c_u)
    dx = dt[:, None] * x                                 # (c, P)
    y_intra = (CB * L) @ dx                              # (c, P)

    prev = state_ref[...]                                # (P, N)
    y_inter = (jnp.exp(cum)[:, None] * Cm) @ prev.T      # (c, P)
    y = y_intra + y_inter + Dh * x
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    # state update: S <- exp(total) S + sum_u exp(total-cum_u) dt_u x_u B_u^T
    w = jnp.exp(total - cum) * dt                        # (c,)
    SB = (w[:, None] * x).T @ Bm                         # (P, N)
    state_ref[...] = jnp.exp(total) * prev + SB

    @pl.when(c == nc - 1)
    def _emit():
        st_out_ref[0, 0] = state_ref[...]


def ssd_scan_pallas(x, dt, A, Bm, Cm, D, init_state=None, *, chunk: int = 64,
                    interpret: bool = False):
    """Shapes as in ref.py; G (groups) must be 1 for the kernel path."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert G == 1, "kernel path supports ngroups=1 (all assigned archs)"
    assert init_state is None, "kernel path starts from zero state (prefill)"
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    xr = x.reshape(B, nc, chunk, H, P)
    dtr = dt.reshape(B, nc, chunk, H)
    Br = Bm.reshape(B, nc, chunk, N)
    Cr = Cm.reshape(B, nc, chunk, N)

    grid = (B, H, nc)
    y, st = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, 1, N), lambda b, h, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, chunk, 1, N), lambda b, h, c: (b, c, 0, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, chunk, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xr[..., None, :].reshape(B, nc, chunk, H, P),
      dtr, A, Br[:, :, :, None, :], Cr[:, :, :, None, :], D)
    return y.reshape(B, S, H, P), st
