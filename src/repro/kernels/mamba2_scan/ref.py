"""Pure-jnp oracles for the Mamba2 SSD scan.

Two references:
  * ``ssd_sequential`` — the literal per-timestep recurrence (ground truth).
  * ``ssd_chunked``    — the matmul-heavy chunked decomposition (what the
                         Pallas kernel implements); tested against sequential.

Shapes (G = groups, usually 1; H heads, P head channels, N state):
    x:  (B, S, H, P)     dt: (B, S, H)       A: (H,)   [negative decay rates]
    Bm: (B, S, G, N)     Cm: (B, S, G, N)    D: (H,)
    init_state: (B, H, P, N) or None
Returns y: (B, S, H, P), final_state: (B, H, P, N).

Recurrence (per head h, discretised):
    a_t = exp(dt_t * A_h)                         scalar per (t, h)
    S_t = a_t * S_{t-1} + dt_t * x_t B_t^T        (P, N)
    y_t = S_t C_t + D_h * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(m, H):
    # (B, S, G, N) -> (B, S, H, N) by repeating each group over its heads
    B, S, G, N = m.shape
    assert H % G == 0
    return jnp.repeat(m, H // G, axis=2)


def ssd_sequential(x, dt, A, Bm, Cm, D, init_state=None):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = _expand_groups(Bm.astype(jnp.float32), H)
    Cf = _expand_groups(Cm.astype(jnp.float32), H)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                     # (B,H,P) (B,H) (B,H,N) (B,H,N)
        a = jnp.exp(dtt * Af)[..., None, None]    # (B,H,1,1)
        dBx = (dtt[..., None] * xt)[..., None] * Bt[..., None, :]  # (B,H,P,N)
        state = a * state + dBx
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct) + Df[None, :, None] * xt
        return state, y

    inputs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
              Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, s0, inputs)
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)
    return y, final


def ssd_chunked(x, dt, A, Bm, Cm, D, init_state=None, *, chunk: int = 64):
    """Chunked SSD: intra-chunk dense matmuls + inter-chunk state recurrence.

    TPU-idiomatic: all O(S) work is MXU matmuls over (chunk x chunk) /
    (chunk x N) tiles; only n_chunks sequential steps carry state.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, H)
    Bf = _expand_groups(Bm.astype(jnp.float32), H).reshape(B, nc, chunk, H, N)
    Cf = _expand_groups(Cm.astype(jnp.float32), H).reshape(B, nc, chunk, H, N)
    Af = A.astype(jnp.float32)

    # cumulative log-decay within each chunk: l[t] = sum_{u<=t} dt_u * A
    seg = dtf * Af[None, None, None, :]              # (B,nc,c,H)
    cum = jnp.cumsum(seg, axis=2)                    # inclusive
    total = cum[:, :, -1, :]                         # (B,nc,H) chunk total

    # intra-chunk (causal) kernel: L[t,u] = exp(cum[t]-cum[u]) for u<=t
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nc,c,c,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)

    # y_intra[t] = sum_{u<=t} L[t,u] * (C_t . B_u) * dt_u * x_u
    # Cf: (B,nc,c,H,N), Bf: (B,nc,c,H,N) -> scores (B,nc,c_t,c_u,H)
    # einsum labels: b=batch, c=chunk index, t/u=time-in-chunk, n=state dim
    CB = jnp.einsum("bcthn,bcuhn->bctuh", Cf, Bf)
    W = CB * Lmat                                    # (B,nc,t,u,H)
    dx = dtf[..., None] * xf                         # (B,nc,c,H,P)
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", W, dx)

    # chunk state contribution: states_c = sum_u exp(total - cum[u]) dt_u x_u B_u^T
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)           # (B,nc,c,H)
    SB = jnp.einsum("bcuh,bcuhp,bcuhn->bchpn", decay_to_end * dtf, xf, Bf)

    # inter-chunk recurrence over nc chunks
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    chunk_decay = jnp.exp(total)                     # (B,nc,H)

    def step(state, inp):
        sb, cd = inp                                 # (B,H,P,N), (B,H)
        prev = state
        state = cd[..., None, None] * state + sb
        return state, prev                           # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, s0, (SB.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (B,nc,H,P,N)

    # y_inter[t] = C_t . (exp(cum[t]) * prev_state)
    y_inter = jnp.einsum("bcthn,bchpn,bcth->bcthp",
                         Cf, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, A, Bm, Cm, D):
    """One-token state update. x:(B,H,P) dt:(B,H) Bm/Cm:(B,G,N) state:(B,H,P,N)."""
    H = x.shape[1]
    Bf = jnp.repeat(Bm.astype(jnp.float32), H // Bm.shape[1], axis=1)
    Cf = jnp.repeat(Cm.astype(jnp.float32), H // Cm.shape[1], axis=1)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32))[..., None, None]
    state = a * state + (dtf[..., None] * xf)[..., None] * Bf[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Cf) + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), state
