"""jit'd public entry points for the Mamba2 SSD scan."""
from __future__ import annotations

from functools import partial

import jax

from ..common import resolve
from .ref import ssd_chunked, ssd_decode_step  # noqa: F401  (decode re-export)


@partial(jax.jit, static_argnames=("impl", "chunk"))
def ssd_scan(x, dt, A, Bm, Cm, D, init_state=None, *, impl: str | None = None,
             chunk: int = 64):
    """Chunked SSD scan. Returns (y, final_state). See ref.py for shapes."""
    impl = resolve(impl)
    chunk = min(chunk, x.shape[1])
    if impl == "xla":
        return ssd_chunked(x, dt, A, Bm, Cm, D, init_state, chunk=chunk)
    from .kernel import ssd_scan_pallas
    return ssd_scan_pallas(x, dt, A, Bm, Cm, D, init_state, chunk=chunk,
                           interpret=(impl == "pallas_interpret"))
