"""jit'd public entry point for the fleet-batched per-instance-weights MLP."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import resolve
from .ref import fleet_mlp_reference

#: Python-level dispatch counter. Inside a jitted caller (the device
#: scoring rollout) the count rises only while TRACING — once per compiled
#: bin shape — whereas the host-loop reference path dispatches once per
#: horizon step. Benchmarks/tests read it via ``invocation_count()``.
_invocations = 0


def invocation_count() -> int:
    return _invocations


def _pad0(a, pad):
    return jnp.concatenate(
        [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)


@partial(jax.jit, static_argnames=("impl", "block_n"))
def _fleet_mlp(x, weights, biases, *, impl: str | None = None, block_n: int = 8):
    impl = resolve(impl)
    if impl == "xla":
        return fleet_mlp_reference(x, weights, biases)
    from .kernel import fleet_mlp_pallas
    # the Pallas grid needs N % block_n == 0; a mesh-sharded fleet bin hands
    # each device an arbitrary N/ndev slice, so zero-pad up to the block
    # multiple here (zero weights -> zero outputs, sliced off below)
    N = x.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = _pad0(x, pad)
        weights = [_pad0(w, pad) for w in weights]
        biases = [_pad0(b, pad) for b in biases]
    out = fleet_mlp_pallas(x, weights, biases, block_n=bn,
                           interpret=(impl == "pallas_interpret"))
    return out[:N] if pad else out


def fleet_mlp(x, weights, biases, *, impl: str | None = None, block_n: int = 8):
    """x: (N,b,F); weights/biases: per-layer stacks with leading N.
    Returns (N,b,O). ReLU between layers; final layer linear."""
    global _invocations
    _invocations += 1
    return _fleet_mlp(x, weights, biases, impl=impl, block_n=block_n)
