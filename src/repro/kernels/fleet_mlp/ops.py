"""jit'd public entry point for the fleet-batched per-instance-weights MLP."""
from __future__ import annotations

from functools import partial

import jax

from ..common import resolve
from .ref import fleet_mlp_reference

#: Python-level dispatch counter. Inside a jitted caller (the device
#: scoring rollout) the count rises only while TRACING — once per compiled
#: bin shape — whereas the host-loop reference path dispatches once per
#: horizon step. Benchmarks/tests read it via ``invocation_count()``.
_invocations = 0


def invocation_count() -> int:
    return _invocations


@partial(jax.jit, static_argnames=("impl", "block_n"))
def _fleet_mlp(x, weights, biases, *, impl: str | None = None, block_n: int = 8):
    impl = resolve(impl)
    if impl == "xla":
        return fleet_mlp_reference(x, weights, biases)
    from .kernel import fleet_mlp_pallas
    return fleet_mlp_pallas(x, weights, biases, block_n=block_n,
                            interpret=(impl == "pallas_interpret"))


def fleet_mlp(x, weights, biases, *, impl: str | None = None, block_n: int = 8):
    """x: (N,b,F); weights/biases: per-layer stacks with leading N.
    Returns (N,b,O). ReLU between layers; final layer linear."""
    global _invocations
    _invocations += 1
    return _fleet_mlp(x, weights, biases, impl=impl, block_n=block_n)
