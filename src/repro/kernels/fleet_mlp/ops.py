"""jit'd public entry point for the fleet-batched per-instance-weights MLP."""
from __future__ import annotations

from functools import partial

import jax

from ..common import resolve
from .ref import fleet_mlp_reference


@partial(jax.jit, static_argnames=("impl", "block_n"))
def fleet_mlp(x, weights, biases, *, impl: str | None = None, block_n: int = 8):
    """x: (N,b,F); weights/biases: per-layer stacks with leading N.
    Returns (N,b,O). ReLU between layers; final layer linear."""
    impl = resolve(impl)
    if impl == "xla":
        return fleet_mlp_reference(x, weights, biases)
    from .kernel import fleet_mlp_pallas
    return fleet_mlp_pallas(x, weights, biases, block_n=block_n,
                            interpret=(impl == "pallas_interpret"))
