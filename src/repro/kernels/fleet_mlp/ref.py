"""Pure-jnp oracle for the fleet-batched MLP — the paper's many-small-models
hot-spot (Castor scoring megabatch): N independent model instances, each with
its OWN weights, scored in one fused computation.

    x:       (N, b, F)                per-instance feature batch
    weights: [ (N, F, H1), (N, H1, H2), ..., (N, Hk, O) ]
    biases:  [ (N, H1), ..., (N, O) ]
ReLU between layers, final layer linear. float32 accumulation.
"""
from __future__ import annotations

import jax.numpy as jnp


def fleet_mlp_reference(x, weights, biases):
    h = x.astype(jnp.float32)
    n = len(weights)
    for i, (w, b) in enumerate(zip(weights, biases)):
        h = jnp.einsum("nbf,nfh->nbh", h, w.astype(jnp.float32))
        h = h + b.astype(jnp.float32)[:, None, :]
        if i < n - 1:
            h = jnp.maximum(h, 0.0)
    return h.astype(x.dtype)
