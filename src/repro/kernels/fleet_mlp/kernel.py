"""Pallas TPU fleet-batched MLP: N independent model instances with
per-instance weights in one kernel — the Castor scoring-megabatch hot-spot.

Grid: (N / block_n,). Each block holds ``block_n`` instances' weights AND
their feature batches in VMEM and runs the whole depth as batched matmuls,
turning the paper's "N containers x tiny GEMM" into MXU-dense batched GEMMs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(*refs, depth: int):
    x_ref = refs[0]
    w_refs = refs[1:1 + depth]
    b_refs = refs[1 + depth:1 + 2 * depth]
    o_ref = refs[1 + 2 * depth]

    h = x_ref[...].astype(jnp.float32)                     # (bn, b, F)
    for i in range(depth):
        w = w_refs[i][...].astype(jnp.float32)             # (bn, F, H)
        b = b_refs[i][...].astype(jnp.float32)             # (bn, H)
        h = jax.lax.dot_general(h, w, (((2,), (1,)), ((0,), (0,))))
        h = h + b[:, None, :]
        if i < depth - 1:
            h = jnp.maximum(h, 0.0)
    o_ref[...] = h.astype(o_ref.dtype)


def fleet_mlp_pallas(x, weights, biases, *, block_n: int = 8,
                     interpret: bool = False):
    N, b, F = x.shape
    depth = len(weights)
    block_n = min(block_n, N)
    assert N % block_n == 0, (N, block_n)

    in_specs = [pl.BlockSpec((block_n, b, F), lambda i: (i, 0, 0))]
    for w in weights:
        in_specs.append(pl.BlockSpec((block_n,) + w.shape[1:],
                                     lambda i: (i, 0, 0)))
    for bb in biases:
        in_specs.append(pl.BlockSpec((block_n,) + bb.shape[1:],
                                     lambda i: (i, 0)))
    O = weights[-1].shape[-1]

    return pl.pallas_call(
        functools.partial(_kernel, depth=depth),
        grid=(N // block_n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n, b, O), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, b, O), x.dtype),
        interpret=interpret,
    )(x, *weights, *biases)
