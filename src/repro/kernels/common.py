"""Shared kernel-dispatch policy.

Every kernel exposes ``op(..., impl=None)`` where impl is one of
    "xla"               pure-jnp (chunked where applicable) — CPU default
    "pallas"            real Pallas lowering — TPU default
    "pallas_interpret"  Pallas interpret=True — CPU validation of kernel bodies
``None`` resolves via :func:`default_impl` (overridable with REPRO_KERNEL_IMPL).
"""
from __future__ import annotations

import os

import jax

VALID = ("xla", "pallas", "pallas_interpret")


def default_impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        assert env in VALID, env
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve(impl: str | None) -> str:
    impl = impl or default_impl()
    assert impl in VALID, impl
    return impl
