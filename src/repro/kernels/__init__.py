"""Pallas TPU kernels (validated interpret=True on CPU) with pure-jnp oracles."""
