"""Pallas TPU single-token GQA decode attention against a KV cache.

Memory-bound by design: the KV cache streams HBM->VMEM in ``block_k`` tiles;
(m, l, acc) carries live in VMEM scratch across cache blocks; per-request
``lengths`` masks invalid cache slots. Grid: (B*KV, cache blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_k: int, nk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    k_start = j * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, :, 0].astype(jnp.float32)            # (bk, D)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            block_k: int = 512, interpret: bool = False):
    B, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = D ** -0.5
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k

    qh = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    lengths = lengths.astype(jnp.int32)

    grid = (B * KV, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda h, j: (h // KV,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda h, j: (h // KV, j, h % KV, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda h, j: (h // KV, j, h % KV, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qh, k_cache, v_cache)
    return out.reshape(B, H, D)
