"""Distributed flash-decode: the KV cache stays SHARD-RESIDENT along S
(model axis); each shard computes a partial (unnormalised out, running max,
denominator) over its local cache chunk and the shards combine with a tiny
psum of exp-corrected statistics — (B, H, D+2) per layer instead of gathering
the (B, S, KV, D) cache.

This is the beyond-paper serving optimization of §Perf: XLA's auto-partition
of a softmax over a sharded axis chooses to all-gather the cache; expressing
the combine explicitly via shard_map removes ~all decode collective volume.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...distributed.sharding import shard_map_compat as _shard_map

NEG_INF = -1e30


def _partial(q, k, v, lengths, offset):
    """Local unnormalised attention over one S-chunk.
    q: (B,H,D), k/v: (B,S_loc,KV,D), positions offset..offset+S_loc.
    Returns o_unnorm (B,H,D) f32, m (B,H) f32, l (B,H) f32."""
    B, H, D = q.shape
    S_loc, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    # einsum directly on the (B,S,KV,D) layout: no materialised transpose
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    pos = offset + jnp.arange(S_loc)
    valid = pos[None, :] < lengths[:, None]                  # (B, S_loc)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,KV,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return (o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def decode_attention_distributed(q, k_cache, v_cache, lengths, *, mesh,
                                 seq_axis: str = "model",
                                 batch_axes=("data",)):
    """q (B,H,D); caches (B,S,KV,D) with S sharded on ``seq_axis`` and B on
    ``batch_axes``. Returns (B,H,D)."""
    import math
    b_ax = tuple(a for a in batch_axes if a in mesh.axis_names)
    if b_ax and q.shape[0] % math.prod(mesh.shape[a] for a in b_ax) != 0:
        b_ax = ()                      # e.g. B=1 long-context: replicate B
    bspec = b_ax if len(b_ax) > 1 else (b_ax[0] if b_ax else None)

    def local(q, k, v, lens):
        i = jax.lax.axis_index(seq_axis)
        o, m, l = _partial(q, k, v, lens, i * k.shape[1])
        m_max = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_max)
        o = jax.lax.psum(o * corr[..., None], seq_axis)
        l = jax.lax.psum(l * corr, seq_axis)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, seq_axis, None, None),
                  P(bspec, seq_axis, None, None), P(bspec)),
        out_specs=P(bspec, None, None),
    )(q, k_cache, v_cache, lengths)
