"""jit'd public entry point for single-token GQA decode attention."""
from __future__ import annotations

from functools import partial

import jax

from ..common import resolve
from .ref import decode_attention_reference


@partial(jax.jit, static_argnames=("impl", "block_k"))
def decode_attention(q, k_cache, v_cache, lengths, *, impl: str | None = None,
                     block_k: int = 512):
    """q: (B,H,D), caches: (B,S,KV,D), lengths: (B,) -> (B,H,D)."""
    impl = resolve(impl)
    if impl == "xla":
        return decode_attention_reference(q, k_cache, v_cache, lengths)
    from .kernel import decode_attention_pallas
    return decode_attention_pallas(q, k_cache, v_cache, lengths,
                                   block_k=block_k,
                                   interpret=(impl == "pallas_interpret"))
