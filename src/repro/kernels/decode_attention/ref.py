"""Pure-jnp oracle for single-token GQA decode attention against a KV cache.

    q:        (B, H, D)        one new token per request
    k_cache:  (B, S, KV, D)
    v_cache:  (B, S, KV, D)
    lengths:  (B,) int32       number of valid cache entries per request
Returns (B, H, D). float32 accumulation.
"""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_reference(q, k_cache, v_cache, lengths, *,
                               scale: float | None = None):
    B, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    assert H % KV == 0
    G = H // KV
    if scale is None:
        scale = D ** -0.5

    # f32 ACCUMULATION without materialising an f32 copy of the cache:
    # dots take the native (bf16) operands with preferred_element_type=f32
    # (MXU semantics); the scale applies to the f32 scores.
    qg = q.reshape(B, KV, G, D)
    # einsum on the native (B,S,KV,D) layout: no materialised transpose
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S)[None, :] < lengths[:, None]         # (B, S)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32).reshape(B, H, D)
    return o.astype(q.dtype)
