"""Pallas TPU flash attention (blocked causal GQA, online softmax).

Grid: (B*KV*G head-batches, q blocks, k blocks) — k innermost/sequential.
Carries (m, l, acc) live in VMEM scratch across the k dimension; causal
blocks that are fully masked are skipped with ``pl.when``. Block sizes are
MXU-aligned (multiples of 128 for full-size head dims).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            q_offset: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(1)
    q_start = i * block_q + q_offset
    k_start = j * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    q_offset = Skv - Sq

    # (B,S,H,D) -> head-batch-major (B*KV*G, S, D); k/v -> (B*KV, S, D)
    qh = q.transpose(0, 2, 1, 3).reshape(B * KV * G, Sq, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)

    grid = (B * KV * G, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k,
                          q_offset=q_offset, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, i, j: (h // G, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, i, j: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, KV, G, Sq, D).transpose(0, 3, 1, 2, 4) \
              .reshape(B, Sq, H, D)
