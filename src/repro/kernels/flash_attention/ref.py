"""Pure-jnp oracle for blocked (flash) GQA attention.

Shapes (time-major per batch):
    q: (B, S_q, H, D)    k,v: (B, S_kv, KV, D)    with H % KV == 0.
Accumulation in float32 regardless of input dtype.
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_reference(q, k, v, *, causal: bool = True,
                        scale: float | None = None,
                        q_offset: int | None = None):
    """O(S^2) reference attention with GQA head-group broadcast.

    ``q_offset``: absolute position of q[0] relative to k[0] (for chunked /
    decode use). Defaults to S_kv - S_q (q block ends aligned with kv end).
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    if scale is None:
        scale = D ** -0.5
    if q_offset is None:
        q_offset = Skv - Sq

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # (B, KV, G, Sq, D) x (B, KV, Skv, D) -> (B, KV, G, Sq, Skv)
    qg = qf.reshape(B, Sq, KV, G, D).transpose(0, 2, 3, 1, 4)
    kg = kf.transpose(0, 2, 1, 3)
    s = jnp.einsum("bkgqd,bkud->bkgqu", qg, kg)

    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Skv)
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)

    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    vg = vf.transpose(0, 2, 1, 3)
    o = jnp.einsum("bkgqu,bkud->bkgqd", p, vg)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return o.astype(q.dtype)
