"""Memory-sane XLA attention: q-chunked with f32 accumulation.

This is the production XLA path (used when the Pallas kernel is not engaged,
e.g. CPU dry-run): scores are materialised only for one q-chunk at a time,
so peak temp memory is O(B * H * chunk * S) instead of O(B * H * S^2).
Numerically identical to ref.attention_reference (same masked softmax).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_xla(q, k, v, *, causal: bool = True, scale: float | None = None,
                  q_chunk: int = 1024):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    if scale is None:
        scale = D ** -0.5
    q_chunk = min(q_chunk, Sq)
    assert Sq % q_chunk == 0, (Sq, q_chunk)
    nq = Sq // q_chunk
    q_offset = Skv - Sq

    # (B, KV, G, Sq, D) view of q; k/v as (B, KV, Skv, D). Dots accumulate in
    # f32 via preferred_element_type — no materialised f32 copies of k/v.
    qg = q.reshape(B, Sq, KV, G, D)
    qg = qg.transpose(0, 2, 3, 1, 4).reshape(B, KV, G, nq, q_chunk, D)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    kpos = jnp.arange(Skv)

    def chunk_fn(ci):
        qc = jax.lax.dynamic_index_in_dim(qg, ci, axis=3, keepdims=False)
        s = jnp.einsum("bkgqd,bkud->bkgqu", qc, kg,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = ci * q_chunk + jnp.arange(q_chunk) + q_offset
            mask = kpos[None, :] <= qpos[:, None]
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqu,bkud->bkgqd", p.astype(v.dtype), vg,
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)     # stacked chunk outputs stay compact

    # remat per chunk: backward recomputes scores/probs instead of saving the
    # O(chunk x S) softmax residuals — the XLA analogue of flash attention's
    # recompute-in-backward (the Pallas kernel does the same in VMEM).
    chunk_fn = jax.checkpoint(chunk_fn)
    o = jax.lax.map(chunk_fn, jnp.arange(nq))            # (nq,B,KV,G,qc,D)
    o = o.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, G, Sq, D)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return o.astype(q.dtype)
