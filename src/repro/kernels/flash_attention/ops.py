"""jit'd public entry point for flash GQA attention."""
from __future__ import annotations

from functools import partial

import jax

from ..common import resolve
from .xla import attention_xla


@partial(jax.jit, static_argnames=("causal", "impl", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, impl: str | None = None,
                    block_q: int = 128, block_k: int = 128):
    """q: (B,S,H,D), k/v: (B,S,KV,D) -> (B,S,H,D)."""
    impl = resolve(impl)
    if impl == "xla":
        return attention_xla(q, k, v, causal=causal)
    from .kernel import flash_attention_pallas
    return flash_attention_pallas(q, k, v, causal=causal,
                                  block_q=block_q, block_k=block_k,
                                  interpret=(impl == "pallas_interpret"))
