"""jit'd public entry points for the RWKV6 WKV scan."""
from __future__ import annotations

from functools import partial

import jax

from ..common import resolve
from .ref import wkv6_chunked, wkv6_decode_step  # noqa: F401


@partial(jax.jit, static_argnames=("impl", "chunk"))
def wkv6_scan(r, k, v, w, u, init_state=None, *, impl: str | None = None,
              chunk: int = 32):
    """Chunked WKV6 scan. Returns (y, final_state). See ref.py for shapes."""
    impl = resolve(impl)
    chunk = min(chunk, r.shape[1])
    if impl == "xla":
        return wkv6_chunked(r, k, v, w, u, init_state, chunk=chunk)
    from .kernel import wkv6_scan_pallas
    return wkv6_scan_pallas(r, k, v, w, u, init_state, chunk=chunk,
                            interpret=(impl == "pallas_interpret"))
