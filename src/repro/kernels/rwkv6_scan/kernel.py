"""Pallas TPU chunked RWKV6 (Finch) WKV scan with data-dependent decay.

Unlike the factorised XLA path (which must clamp exp(-cum)), the kernel
materialises the masked per-channel decay D_{u+1:t} = exp(cum_excl[t]-cum[u])
EXACTLY per (chunk x chunk x K) tile in VMEM — numerically safe for any decay
because the masked exponent is always <= 0. Cross-chunk state (K, V) carried
in VMEM scratch. Grid: (B, H, chunks), chunk innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, st_out_ref, state_ref,
            *, chunk: int, nc: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0, :, 0].astype(jnp.float32)            # (c, K)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)            # (c, V)
    w = w_ref[0, 0, :, 0].astype(jnp.float32)            # (c, K) in (0,1)
    u = u_ref[0].astype(jnp.float32)                     # (K,)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)                       # (c, K) inclusive
    cum_excl = cum - logw
    total = cum[-1]                                      # (K,)

    # exact masked decay tile: rel[t,u,k] = cum_excl[t,k] - cum[u,k] (u < t)
    rel = cum_excl[:, None, :] - cum[None, :, :]         # (c, c, K)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = jnp.where(tri[:, :, None], jnp.exp(rel), 0.0)

    scores = jnp.einsum("tk,uk,tuk->tu", r, k, dec)      # (c, c)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)          # (c,)
    y_intra = scores @ v + diag[:, None] * v

    prev = state_ref[...]                                # (K, V)
    y_inter = (r * jnp.exp(cum_excl)) @ prev
    y_ref[0, 0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    k_tail = k * jnp.exp(total[None, :] - cum)           # (c, K)
    state_ref[...] = jnp.exp(total)[:, None] * prev + k_tail.T @ v

    @pl.when(c == nc - 1)
    def _emit():
        st_out_ref[0, 0] = state_ref[...]


def wkv6_scan_pallas(r, k, v, w, u, init_state=None, *, chunk: int = 32,
                     interpret: bool = False):
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert init_state is None, "kernel path starts from zero state (prefill)"
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    def prep(t, last):
        return t.reshape(B, nc, chunk, H, last)[:, :, :, :, :] \
                .transpose(0, 1, 2, 3, 4)

    rr = r.reshape(B, nc, chunk, H, K)
    kk = k.reshape(B, nc, chunk, H, K)
    vv = v.reshape(B, nc, chunk, H, V)
    ww = w.reshape(B, nc, chunk, H, K)

    grid = (B, H, nc)
    y, st = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 1, K), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1, K), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1, V), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1, K), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, 1, V), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, chunk, H, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, u)
    return y.reshape(B, S, H, V), st
