"""Pure-jnp oracles for the RWKV-6 (Finch) WKV scan with data-dependent decay.

Per head (K = head key dim, V = head value dim, here K == V == head_size):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
Shapes:
    r, k, w: (B, S, H, K)   v: (B, S, H, V)   u: (H, K)
    w in (0, 1): already exp(-exp(..)).   state: (B, H, K, V)
Returns y: (B, S, H, V), final_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_sequential(r, k, v, w, u, init_state=None):
    B, S, H, K = r.shape
    V = v.shape[-1]
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    s0 = (jnp.zeros((B, H, K, V), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, inp):
        rt, kt, vt, wt = inp                         # (B,H,K) (B,H,K) (B,H,V) (B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]     # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, y

    inputs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    final, ys = jax.lax.scan(step, s0, inputs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), final


def wkv6_chunked(r, k, v, w, u, init_state=None, *, chunk: int = 32):
    """Chunked WKV6: log-space cumulative decays + dense intra-chunk matmuls.

    Within a chunk (positions t, u, 0-indexed):
      y_t  = r_t ( D_{0:t} S_in + sum_{u<t} (D_{u+1:t} k_u) v_u^T + u_bonus k_t v_t^T )
    where D_{a:b} = prod_{i=a}^{b-1} diag(w_i); computed via cumsum(log w).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    rf = r.astype(jnp.float32).reshape(B, nc, chunk, H, K)
    kf = k.astype(jnp.float32).reshape(B, nc, chunk, H, K)
    vf = v.astype(jnp.float32).reshape(B, nc, chunk, H, V)
    wf = w.astype(jnp.float32).reshape(B, nc, chunk, H, K)
    uf = u.astype(jnp.float32)

    logw = jnp.log(jnp.maximum(wf, 1e-38))
    cum = jnp.cumsum(logw, axis=2)                   # inclusive: sum_{i<=t} log w_i
    total = cum[:, :, -1]                            # (B,nc,H,K)

    # decay applied to incoming state for position t: prod_{i<t} w_i = exp(cum[t-1])
    cum_excl = cum - logw                            # exclusive cumsum
    r_dec = rf * jnp.exp(cum_excl)                   # r_t * D_{0:t}

    # k_u needs decay D_{u+1:t}: fold exp(-cum[u]) into k, exp(cum_excl[t]) into r.
    # D_{u+1:t} = exp(cum_excl[t] - cum[u])   (for u < t).
    # exp(cum_excl) <= 1 is always safe; exp(-cum) grows with aggressive decay,
    # so clamp the exponent at 80 (f32 overflows ~88). Channels that clamp have
    # per-step decay so strong that their clipped contribution is negligible —
    # the Pallas kernel computes the masked (t,u) decay exactly per tile instead.
    k_dec = kf * jnp.exp(jnp.clip(-cum, a_max=80.0))
    # strictly-lower-triangular attention (u < t)
    scores = jnp.einsum("bnthk,bnuhk->bntuh", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(tri[None, None, :, :, None], scores, 0.0)
    # diagonal (current-token bonus u)
    diag = jnp.einsum("bnthk,hk,bnthk->bnth", rf, uf, kf)
    y_intra = (jnp.einsum("bntuh,bnuhv->bnthv", scores, vf)
               + diag[..., None] * vf)

    # chunk state contribution: S_out = D_total S_in + sum_u D_{u+1:end} k_u v_u^T
    k_tail = kf * jnp.exp(total[:, :, None] - cum)   # D_{u+1:end} k_u
    SB = jnp.einsum("bnuhk,bnuhv->bnhkv", k_tail, vf)

    s0 = (jnp.zeros((B, H, K, V), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, inp):
        sb, tot = inp
        prev = state
        state = jnp.exp(tot)[..., None] * state + sb
        return state, prev

    final, prev_states = jax.lax.scan(
        step, s0, (SB.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2, 3)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,H,K,V)

    y_inter = jnp.einsum("bnthk,bnhkv->bnthv", r_dec, prev_states)
    y = (y_intra + y_inter).reshape(B, S, H, V)
    return y.astype(r.dtype), final


def wkv6_decode_step(state, r, k, v, w, u):
    """One token. r/k/w:(B,H,K) v:(B,H,V) state:(B,H,K,V)."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rf, state + uf[None, :, :, None] * kv)
    state = wf[..., :, None] * state + kv
    return y.astype(r.dtype), state
