"""Shared fixtures for the multi-device (forced host devices) subprocess
harnesses in tests/test_fleet_mesh.py and benchmarks/bench_table3 — one
place for the castor factory, subprocess env, and equivalence tolerances
so the test and the benchmark gate cannot drift apart.
"""
from __future__ import annotations

import os

DAY = 86400.0
FLEET_NOW = 35 * DAY

#: sharded == unsharded forecast agreement: float32 batched solves/matmuls
#: reassociate across shard boundaries (measured deviations are ~1e-5)
FLEET_RTOL, FLEET_ATOL = 2e-3, 1e-3


def subprocess_env(src_dir) -> dict:
    """Minimal env for a jax subprocess (the device-count override must
    precede jax init, hence subprocesses at all). JAX_PLATFORMS must be
    forwarded: without it jax probes for accelerator plugins and hangs on
    hosts with a baked-in (but absent) TPU toolchain."""
    return {"PYTHONPATH": str(src_dir),
            "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}


HOUR = 3600.0


def build_steady_castor(kind: str, cls, hp: dict, *, n: int = 6,
                        seed: int = 9, site: str = "Z",
                        train_every: float = 1e12,
                        score_every: float = HOUR, days: int = 38,
                        window_days: int = 14):
    """Smart-grid fleet for steady-state poll sequences: one ``kind``
    deployment per prosumer (named ``s-{site}_PRO_0_{i}``), first due at
    FLEET_NOW, scoring every ``score_every`` — data pre-ingested through
    ``days`` so successive polls find new window rows. Shared by
    tests/test_fleet_runtime.py and benchmarks/bench_steady_state.py so
    the equivalence fixtures and the perf gate exercise the same system."""
    from .core import Castor, Schedule
    from .timeseries.ingest import SiteSpec, build_site
    c = Castor()
    build_site(c, SiteSpec(site, n_prosumers=n, n_feeders=1,
                           n_substations=1, seed=seed),
               t0=0.0, t1=days * DAY)
    c.publish(kind, "1.0", cls)
    c.deploy_for_all(package=kind, signal="ENERGY_LOAD", name_prefix="s",
                     kind="PROSUMER", train=Schedule(FLEET_NOW, train_every),
                     score=Schedule(FLEET_NOW, score_every),
                     user_params={"train_window_days": window_days, **hp})
    return c


MINUTE = 60.0


def build_detection_castor(n: int = 3, *, site: str = "D", seed: int = 11,
                           anomaly_sensor: int = 0, minutes: int = 75,
                           days: int = 38):
    """Forecast fleet + minutely live feed + minutely detection fleet —
    the shared fixture behind tests/test_flows.py and
    benchmarks/bench_detection.py.

    One LR forecast deployment per prosumer is trained AND scored at
    FLEET_NOW (so every context has a banded forecast), then minutely
    readings are ingested over (FLEET_NOW, FLEET_NOW + minutes*MINUTE]:
    in-band noise around the point forecast for every sensor except
    ``anomaly_sensor``, which is spiked far outside any plausible band
    from the window's midpoint on. A ``BandAnomalyDetector`` detection
    deployment (named ``d-{site}_PRO_0_{i}``) is registered per context,
    first due FLEET_NOW + MINUTE, firing every minute."""
    import numpy as np
    from .core import Schedule
    from .forecast import LinearForecaster
    from .forecast.anomaly import BandAnomalyDetector
    c = build_steady_castor("lr", LinearForecaster, {}, n=n, seed=seed,
                            site=site, days=days)
    res = c.tick(FLEET_NOW, executor="fleet")
    assert res and all(r.ok for r in res), \
        [r.error for r in res if not r.ok]
    rng = np.random.default_rng(seed + 1)
    t = FLEET_NOW + MINUTE * np.arange(1, minutes + 1)
    for i in range(n):
        ent = f"{site}_PRO_0_{i}"
        fc = c.best_forecast("ENERGY_LOAD", ent)
        v = np.interp(t, fc.times, fc.values) \
            + rng.normal(0.0, 0.01, t.shape)
        if i == anomaly_sensor:
            v = v.copy()
            v[minutes // 2:] += 25.0
        c.ingest(c.graph.context("ENERGY_LOAD", ent).ts_id, t, v)
    c.publish("anom", "1.0", BandAnomalyDetector)
    c.deploy_detections(package="anom", signal="ENERGY_LOAD",
                        name_prefix="d", kind="PROSUMER",
                        detect=Schedule(FLEET_NOW + MINUTE, MINUTE))
    return c


def run_polls(c, k: int, *, executor=None, t0: float = FLEET_NOW,
              step: float = HOUR):
    """Run ``k`` consecutive scheduler polls through ``executor`` (default:
    the castor's persistent fleet executor — the runtime-warm path),
    asserting every job succeeds. Returns the executor (its
    ``last_bin_stats`` describe the final poll)."""
    ex = executor if executor is not None else c.fleet_executor()
    for i in range(k):
        res = ex.run(c.scheduler.poll(t0 + i * step))
        assert all(r.ok for r in res), \
            [r.error for r in res if not r.ok]
    return ex


def _canon(obj):
    """Canonical bitwise-comparable form of a params pytree / array: every
    array becomes (dtype, shape, raw bytes), dicts sort by key. Two objects
    canonicalizing equal are BITWISE equal — no tolerance anywhere."""
    import numpy as np
    if isinstance(obj, dict):
        return ("dict", tuple((k, _canon(v))
                              for k, v in sorted(obj.items())))
    if isinstance(obj, (list, tuple)):
        return ("seq", tuple(_canon(v) for v in obj))
    if hasattr(obj, "dtype") and hasattr(obj, "shape"):
        a = np.asarray(obj)
        return ("arr", str(a.dtype), tuple(a.shape), a.tobytes())
    return ("val", obj)


def snapshot_stores(c) -> dict:
    """Bitwise snapshot of a castor's ModelVersionStore + PredictionStore:
    per deployment, every version's (version, trained_at, params bytes) and
    every forecast's (created_at, model_version, rank, times/values bytes),
    sorted by occurrence stamp so executor completion order cannot leak in.
    Two runs with identical effects produce identical snapshots — the
    exactly-once equivalence surface the chaos suite asserts on."""
    versions = {}
    for name in sorted(getattr(c.versions, "_versions", {})):
        versions[name] = tuple(
            (mv.version, float(mv.trained_at), _canon(mv.params))
            for mv in sorted(c.versions.history(name),
                             key=lambda mv: (mv.trained_at, mv.version)))
    forecasts = {}
    for name in sorted(getattr(c.predictions, "_by_dep", {})):
        forecasts[name] = tuple(
            (float(fc.created_at), fc.model_version, fc.rank, fc.signal,
             fc.entity, _canon(fc.times), _canon(fc.values),
             _canon(fc.lower) if fc.lower is not None else None,
             _canon(fc.upper) if fc.upper is not None else None)
            for fc in sorted(c.predictions.history(name),
                             key=lambda fc: fc.created_at))
    detections = {}
    derived = {}
    det_store = getattr(c, "detections", None)
    if det_store is not None:
        for name in sorted(getattr(det_store, "_by_dep", {})):
            detections[name] = tuple(
                (float(dr.scheduled_at), dr.score, dr.n_readings,
                 dr.n_anomalies, dr.band_misses, dr.model_version,
                 dr.signal, dr.entity, dr.derived_signal)
                for dr in sorted(det_store.history(name),
                                 key=lambda dr: dr.scheduled_at))
            # the derived anomaly series the store wrote back — the
            # exactly-once surface chaos must not double-append to
            for dr in det_store.history(name):
                key = (dr.derived_signal, dr.entity)
                if key not in derived:
                    try:
                        ctx = c.graph.context(*key)
                    except KeyError:
                        continue
                    t, v = c.store.read(ctx.ts_id)
                    derived[key] = (_canon(t), _canon(v))
    return {"versions": versions, "forecasts": forecasts,
            "detections": detections, "derived": derived}


def assert_stores_bitwise_equal(c_ref, c_got, *, context: str = "") -> None:
    """Assert two castors' model-version + prediction stores are bitwise
    identical (same deployments, same occurrences, same params/forecast
    BYTES). Either argument may be a castor or an already-taken
    ``snapshot_stores`` snapshot (the chaos suite caches its fault-free
    baselines that way). Failure messages name the first diverging
    deployment rather than dumping two full snapshots."""
    def _snap(x):
        return x if isinstance(x, dict) and "versions" in x \
            else snapshot_stores(x)
    ref, got = _snap(c_ref), _snap(c_got)
    for kind in ("versions", "forecasts", "detections"):
        rk, gk = ref.get(kind, {}), got.get(kind, {})
        assert set(rk) == set(gk), \
            (f"{context}: {kind} deployment sets differ: "
             f"{sorted(set(rk) ^ set(gk))}")
        for name in rk:
            r, g = rk[name], gk[name]
            assert len(r) == len(g), \
                (f"{context}: {name} has {len(g)} {kind}, expected "
                 f"{len(r)} — duplicate or lost effects")
            for i, (re_, ge) in enumerate(zip(r, g)):
                assert re_ == ge, \
                    (f"{context}: {name} {kind}[{i}] diverges "
                     f"(stamp {ge[0] if ge else '?'} vs {re_[0]})")
    rd, gd = ref.get("derived", {}), got.get("derived", {})
    assert set(rd) == set(gd), \
        (f"{context}: derived-series sets differ: "
         f"{sorted(set(rd) ^ set(gd))}")
    for key in rd:
        assert rd[key] == gd[key], \
            (f"{context}: derived series {key} diverges — a duplicate "
             f"detection double-appended, or one was lost")


# ------------------------------------------------------------ durability
#
# Crash-restart harness: a *plan* is a castor-independent description of
# a workload — semantics, the full external feed, publish/deploy rules,
# and the poll boundaries — captured once from a scratch build. The
# fault-free reference and every recovered castor execute the SAME
# ``drive_plan``, so bitwise comparison isolates exactly what the
# WAL/recovery machinery did. The feed re-sends with at-least-once
# semantics (``replay_feed`` filters by each series' recovered
# ``last_time``): external data cannot be regenerated from a journal, so
# a real deployment's producers would replay it the same way.


def _graph_plan(g):
    signals = [(s.name, s.unit, s.description) for s in g.signals.values()]
    entities = []
    for name, ent in g.entities.items():      # insertion order: parents
        p = g.parent(name)                    # precede their children
        entities.append((ent.name, ent.kind, ent.lat, ent.lon,
                         p.name if p is not None else None))
    links = sorted((tid, s, e) for (s, e), tid in g._ts.items())
    return signals, entities, links


def steady_plan(kind: str, cls, hp: dict, *, n: int = 4, seed: int = 9,
                site: str = "Z", polls: int = 3,
                train_every: float = DAY, score_every: float = HOUR,
                days: int = 38, window_days: int = 14) -> dict:
    """Capture a steady-state forecast workload (the
    ``build_steady_castor`` fleet, dailies training + hourly scoring) as
    a replayable plan with ``polls`` hourly boundaries from FLEET_NOW."""
    from .core import Schedule
    scratch = build_steady_castor(kind, cls, hp, n=n, seed=seed, site=site,
                                  train_every=train_every,
                                  score_every=score_every, days=days,
                                  window_days=window_days)
    signals, entities, links = _graph_plan(scratch.graph)
    feed = {tid: scratch.store.read(tid) for tid in scratch.store.ids()}
    return {
        "signals": signals, "entities": entities, "links": links,
        "feed": feed,
        "publish": [(kind, "1.0", cls)],
        "deploy": [("forecast", dict(
            package=kind, signal="ENERGY_LOAD", name_prefix="s",
            kind="PROSUMER", train=Schedule(FLEET_NOW, train_every),
            score=Schedule(FLEET_NOW, score_every),
            user_params={"train_window_days": window_days, **hp}))],
        "boundaries": [FLEET_NOW + k * score_every for k in range(polls)],
    }


def detection_plan(n: int = 3, *, site: str = "D", seed: int = 11,
                   anomaly_sensor: int = 0, minutes: int = 40,
                   days: int = 38) -> dict:
    """Capture the minutely detection workload
    (``build_detection_castor``: banded LR fleet at FLEET_NOW, minutely
    spiked feed, a BandAnomalyDetector per context) as a replayable plan:
    one FLEET_NOW train+score boundary, then ``minutes`` minutely detect
    boundaries. The minutely readings — a function of the (deterministic)
    FLEET_NOW forecast — are captured as static numbers, so the plan's
    feed is closed under replay."""
    from .core import Schedule
    from .forecast import LinearForecaster
    from .forecast.anomaly import BandAnomalyDetector
    scratch = build_detection_castor(n=n, site=site, seed=seed,
                                     anomaly_sensor=anomaly_sensor,
                                     minutes=minutes, days=days)
    signals, entities, links = _graph_plan(scratch.graph)
    feed = {tid: scratch.store.read(tid) for tid in scratch.store.ids()}
    return {
        "signals": signals, "entities": entities, "links": links,
        "feed": feed,
        "publish": [("lr", "1.0", LinearForecaster),
                    ("anom", "1.0", BandAnomalyDetector)],
        "deploy": [
            ("forecast", dict(
                package="lr", signal="ENERGY_LOAD", name_prefix="s",
                kind="PROSUMER", train=Schedule(FLEET_NOW, 1e12),
                score=Schedule(FLEET_NOW, HOUR),
                user_params={"train_window_days": 14})),
            ("detection", dict(
                package="anom", signal="ENERGY_LOAD", name_prefix="d",
                kind="PROSUMER",
                detect=Schedule(FLEET_NOW + MINUTE, MINUTE))),
        ],
        "boundaries": [FLEET_NOW] + [FLEET_NOW + k * MINUTE
                                     for k in range(1, minutes + 1)],
    }


def replay_feed(c, feed) -> int:
    """At-least-once re-ingestion: append only the points past each
    series' recovered ``last_time`` (feeds are time-sorted, so the suffix
    mask is exact; on a fresh castor the whole feed lands). Returns the
    number of points appended."""
    import numpy as np
    total = 0
    for tid in sorted(feed):
        t, v = feed[tid]
        last = c.store.last_time(tid)
        if last is not None:
            keep = np.asarray(t) > last
            t, v = np.asarray(t)[keep], np.asarray(v)[keep]
        if len(t):
            total += c.ingest(tid, t, v)
    return total


def drive_plan(c, plan, *, executor: str = "fleet",
               boundaries=None) -> None:
    """Execute a plan on a castor — fresh OR recovered. Every step is
    idempotent against already-recovered state: semantics re-adds are
    no-ops, the feed replays only its missing suffix, implementations
    re-publish (the registry holds code, which a journal never persists),
    deploy rules skip registered contexts, and boundary ticks re-fire
    only occurrences the recovered watermarks don't already cover."""
    from .core import Signal
    for name, unit, desc in plan["signals"]:
        c.graph.add_signal(Signal(name, unit, desc))
    for name, kind, lat, lon, parent in plan["entities"]:
        c.add_entity(name, kind, lat, lon, parent=parent)
    for tid, sig, ent in plan["links"]:
        c.link(tid, sig, ent)
    replay_feed(c, plan["feed"])
    for package, version, cls in plan["publish"]:
        c.publish(package, version, cls)
    for flow, rule in plan["deploy"]:
        if flow == "detection":
            c.deploy_detections(**rule)
        else:
            c.deploy_for_all(**rule)
    for t in boundaries if boundaries is not None else plan["boundaries"]:
        res = c.tick(t, executor=executor)
        bad = [r.error for r in res if not r.ok]
        assert not bad, bad


def build_fleet_castor(kind: str, cls, hp: dict, mesh_opt: str, *,
                       n: int = 6, seed: int = 9, site: str = "Z",
                       run: bool = True):
    """Small smart-grid fleet: one ``kind`` deployment per prosumer
    (named ``s-{site}_PRO_0_{i}``), train+score due at FLEET_NOW. With
    ``run`` the due jobs execute through a FleetExecutor (asserting
    success). Returns ``(castor, fleet_executor)``."""
    from .core import Castor, Schedule
    from .core.executor import FleetExecutor
    from .timeseries.ingest import SiteSpec, build_site
    c = Castor()
    build_site(c, SiteSpec(site, n_prosumers=n, n_feeders=1,
                           n_substations=1, seed=seed),
               t0=0.0, t1=38 * DAY)
    c.publish(kind, "1.0", cls)
    c.deploy_for_all(package=kind, signal="ENERGY_LOAD", name_prefix="s",
                     kind="PROSUMER", train=Schedule(FLEET_NOW, 1e12),
                     score=Schedule(FLEET_NOW, 1e12),
                     user_params={"train_window_days": 14,
                                  "mesh": mesh_opt, **hp})
    fx = FleetExecutor(c)
    if run:
        res = fx.run(c.scheduler.poll(FLEET_NOW))
        assert all(r.ok for r in res), [r.error for r in res if not r.ok]
    return c, fx
