"""Hierarchical span tracer with cross-process stitching.

A span is one timed interval on the request path — ``castor.tick`` →
``scheduler.poll`` → ``exec.phase.*`` → ``exec.bin`` → ``store.*`` /
``journal.flush``. Spans nest via a per-thread stack: a span opened
while another is active becomes its child and inherits its trace id, so
every tick is one trace.

Design constraints (ISSUE 10):

- **Counter-based ids.** Span and trace ids come from
  ``itertools.count().__next__`` (atomic in CPython) — no uuid/random,
  so traces are deterministic under an injected clock.
- **Injectable monotonic clock.** ``Tracer(clock=...)`` lets tests
  drive time explicitly; ``epoch`` anchors the monotonic clock to wall
  time for Perfetto export.
- **Bounded ring.** Finished spans land in a ``deque(maxlen=capacity)``
  — O(1) append, oldest evicted; ``evicted`` is derivable from
  ``finished - len(buf)``.
- **Cheap when off.** ``span()`` on a disabled tracer returns one
  shared no-op context manager: no allocation, two attribute loads.

Cross-process stitching: the invoker puts ``current()`` —
``{"trace_id", "parent_id"}`` — on the JSON invocation payload; the
worker process opens its spans under ``adopt(ctx)`` so they carry the
invoker's trace id and parent under the invoker's (pre-allocated)
invoke-span id; ``export_since(mark)`` ships the worker's finished
spans back on the result JSON; ``absorb()`` re-ids them onto the
invoker's counter (remapping internal parent links, preserving the
remote parent link) and optionally re-bases their timestamps onto the
invoker's clock — one stitched trace, correct parentage, no shared
memory.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple


class Span:
    """One finished interval. ``args`` is a small dict or None.
    ``remote_parent`` marks a span whose ``parent_id`` lives in ANOTHER
    process's id space (it was opened under ``adopt``): two processes
    draw ids from independent counters, so without the flag ``absorb``
    could not tell a remote parent from a numerically-colliding local
    one."""
    __slots__ = ("trace_id", "span_id", "parent_id", "name",
                 "t0", "t1", "tid", "args", "seq", "remote_parent")

    def __init__(self, trace_id, span_id, parent_id, name, t0, t1, tid,
                 args, seq, remote_parent=False):
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.tid = tid
        self.args = args
        self.seq = seq
        self.remote_parent = remote_parent

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        d = {"trace_id": self.trace_id, "span_id": self.span_id,
             "parent_id": self.parent_id, "name": self.name,
             "t0": self.t0, "t1": self.t1, "tid": self.tid}
        if self.args:
            d["args"] = self.args
        if self.remote_parent:
            d["rp"] = 1
        return d

    @staticmethod
    def from_dict(d: dict) -> "Span":
        return Span(d["trace_id"], d["span_id"], d["parent_id"],
                    d["name"], d["t0"], d["t1"], d.get("tid", 0),
                    d.get("args"), 0, bool(d.get("rp")))

    def __repr__(self):
        return (f"Span({self.name!r}, id={self.span_id}, "
                f"parent={self.parent_id}, trace={self.trace_id}, "
                f"dur={self.duration:.6f})")


class _NullCtx:
    """Shared no-op span for disabled tracers."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        return self


_NULL_CTX = _NullCtx()


class _SpanCtx:
    __slots__ = ("tracer", "name", "args", "trace_id", "span_id",
                 "parent_id", "remote", "t0")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        tr = self.tracer
        stack = tr._stack()
        if stack:
            top = stack[-1]
            self.trace_id = top[0]
            self.parent_id = top[1]
            self.remote = top[2]
        else:
            self.trace_id = tr._next_trace()
            self.parent_id = 0
            self.remote = False
        self.span_id = tr._next_id()
        stack.append((self.trace_id, self.span_id, False))
        self.t0 = tr.clock()
        return self

    def __exit__(self, *exc):
        tr = self.tracer
        t1 = tr.clock()
        tr._stack().pop()
        tr._finish(Span(self.trace_id, self.span_id, self.parent_id,
                        self.name, self.t0, t1,
                        threading.get_ident(), self.args, 0,
                        self.remote))
        return False

    def set(self, **kw):
        """Attach args discovered mid-span (e.g. a result count)."""
        if self.args is None:
            self.args = kw
        else:
            self.args.update(kw)
        return self


class _AdoptCtx:
    """Pushes a remote (trace_id, parent_id) frame so spans opened under
    it stitch into a trace that lives in another process. The frame is
    marked remote: direct children record ``remote_parent=True`` so
    ``absorb`` never confuses their parent — an id from the INVOKER's
    counter — with a same-valued local worker span id."""
    __slots__ = ("tracer", "frame")

    def __init__(self, tracer: "Tracer", trace_id: int, parent_id: int):
        self.tracer = tracer
        self.frame = (trace_id, parent_id, True)

    def __enter__(self):
        self.tracer._stack().append(self.frame)
        return self

    def __exit__(self, *exc):
        self.tracer._stack().pop()
        return False


class Tracer:
    def __init__(self, capacity: int = 65536, clock=time.perf_counter,
                 enabled: bool = True,
                 epoch: Optional[Tuple[float, float]] = None):
        self.capacity = int(capacity)
        self.clock = clock
        self.enabled = enabled
        self.buf: deque = deque(maxlen=self.capacity)
        self._next_id = itertools.count(1).__next__
        self._next_trace = itertools.count(1).__next__
        self._seq = itertools.count(1).__next__
        self._tls = threading.local()
        self._lock = threading.Lock()
        self.finished = 0
        # (wall_time, monotonic_time) anchor pairing the injectable
        # clock with the epoch, so export can emit absolute timestamps
        self.epoch = epoch if epoch is not None \
            else (time.time(), self.clock())

    # -- span lifecycle ------------------------------------------------
    def _stack(self) -> list:
        s = getattr(self._tls, "stack", None)
        if s is None:
            s = self._tls.stack = []
        return s

    def span(self, name: str, **args):
        """Context manager timing one nested interval. On a disabled
        tracer this is the shared no-op (kwargs are still evaluated by
        the caller — keep call sites' kwargs cheap)."""
        if not self.enabled:
            return _NULL_CTX
        return _SpanCtx(self, name, args or None)

    def _finish(self, span: Span) -> None:
        with self._lock:
            span.seq = self._seq()
            self.finished += 1
            self.buf.append(span)

    def record(self, name: str, t0: float, t1: float, *,
               span_id: Optional[int] = None, parent_id: int = 0,
               trace_id: Optional[int] = None,
               args: Optional[dict] = None) -> int:
        """Append an interval measured outside a ``with`` block (e.g. a
        serverless invocation whose dispatch and settle happen on
        different control-flow legs). ``span_id`` may be pre-allocated
        via ``allocate_id`` so children created elsewhere (a worker
        process) can parent under it before it is recorded."""
        if not self.enabled:
            return 0
        if span_id is None:
            span_id = self._next_id()
        if trace_id is None:
            trace_id = self._next_trace()
        self._finish(Span(trace_id, span_id, parent_id, name, t0, t1,
                          threading.get_ident(), args or None, 0))
        return span_id

    def allocate_id(self) -> int:
        return self._next_id()

    def new_trace_id(self) -> int:
        return self._next_trace()

    # -- cross-process stitching --------------------------------------
    def current(self) -> Optional[Dict[str, int]]:
        """Trace context of the innermost open span on this thread, as a
        JSON-ready dict — or None when no span is open (or disabled)."""
        if not self.enabled:
            return None
        stack = self._stack()
        if not stack:
            return None
        top = stack[-1]
        return {"trace_id": top[0], "parent_id": top[1]}

    def adopt(self, ctx: Optional[Dict[str, int]]):
        """Open spans under a remote trace context (see module doc)."""
        if not self.enabled or not ctx:
            return _NULL_CTX
        return _AdoptCtx(self, int(ctx["trace_id"]),
                         int(ctx["parent_id"]))

    def mark(self) -> int:
        """Watermark for ``export_since`` — spans finished after this
        call have a strictly greater ``seq``."""
        with self._lock:
            return self.finished

    def export_since(self, mark: int) -> List[dict]:
        """Finished spans with ``seq > mark``, oldest first, as JSON
        dicts. Walks the ring from the right so the cost is O(exported),
        not O(capacity)."""
        out: List[dict] = []
        with self._lock:
            for span in reversed(self.buf):
                if span.seq <= mark:
                    break
                out.append(span.to_dict())
        out.reverse()
        return out

    def absorb(self, spans: List[dict], t_base: Optional[float] = None) -> int:
        """Stitch spans shipped from another process into this tracer.

        Span ids are re-assigned from this tracer's counter (two
        processes draw from independent counters, so shipped ids may
        collide with local ones); parent links *within* the shipped set
        are remapped, while ``remote_parent`` spans — opened under
        ``adopt``, their parent being this process's invoke span — pass
        through untouched. When ``t_base`` is given, timestamps are
        shifted so
        the earliest shipped span starts at ``t_base`` (worker and
        invoker monotonic clocks are not comparable; the dispatch time
        on the invoker's clock is the honest anchor). Returns the number
        of spans absorbed."""
        if not self.enabled or not spans:
            return 0
        idmap = {d["span_id"]: self._next_id() for d in spans}
        shift = 0.0
        if t_base is not None:
            shift = t_base - min(d["t0"] for d in spans)
        for d in spans:
            s = Span.from_dict(d)
            s.span_id = idmap[s.span_id]
            if s.remote_parent:
                s.remote_parent = False     # parent is local to us now
            else:
                s.parent_id = idmap.get(s.parent_id, s.parent_id)
            s.t0 += shift
            s.t1 += shift
            self._finish(s)
        return len(spans)

    # -- inspection ----------------------------------------------------
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self.buf)

    @property
    def evicted(self) -> int:
        return self.finished - len(self.buf)

    def stats(self) -> dict:
        with self._lock:
            return {"enabled": self.enabled, "capacity": self.capacity,
                    "finished": self.finished,
                    "buffered": len(self.buf),
                    "evicted": self.finished - len(self.buf)}

    def clear(self) -> None:
        with self._lock:
            self.buf.clear()
            self.finished = 0


NULL_TRACER = Tracer(capacity=1, enabled=False)

_DEFAULT = Tracer()


def get_tracer() -> Tracer:
    """The process-global tracer. ``Castor`` and directly-constructed
    components (executors, stores, journals) default to this, so a
    worker process's spans land in one place for shipping."""
    return _DEFAULT


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-global tracer (tests, ``benchmarks/run.py
    --trace``). Returns the previous one."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = tracer
    return prev
