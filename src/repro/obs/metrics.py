"""Metrics registry: counters, gauges, and log-bucket histograms.

One namespaced surface for telemetry that PRs 1-9 scattered across
``FleetExecutor.last_bin_stats`` dicts, ``InvocationMonitor`` record
lists, per-store read counters, journal stats, and the module-global
retrace counter in ``forecast/features.py``.

Design constraints (ISSUE 10):

- **Zero-alloc hot path.** ``Counter.inc`` / ``Gauge.set`` are single
  attribute writes; ``Histogram.observe`` indexes a pre-allocated bucket
  list via ``math.frexp`` (no log, no dict, no allocation). Hot code
  holds a direct reference to the metric object — the registry dict is
  only probed at get-or-create time.
- **Log buckets.** Buckets are powers of two: bucket ``i`` covers
  ``[2**(i+EMIN-1), 2**(i+EMIN))`` (bucket 0 additionally absorbs
  underflow and non-positive values). 64 buckets starting at 2**-27
  (~7.5 ns) span everything from sub-microsecond span durations to
  multi-gigabyte byte counts.
- **Quantiles are bucket-bounded.** ``quantile(q)`` returns the upper
  edge of the bucket where the cumulative count crosses ``q``, clamped
  to the observed ``[min, max]`` — so the estimate is always within a
  factor of 2 of the true order statistic and never outside the
  observed range. The hypothesis property tests pin exactly this.

Thread-safety: metric *creation* is locked; *updates* are plain
attribute read-modify-writes. Concurrent increments may rarely lose an
update under free-threading — acceptable for telemetry, and the repo's
hot paths (fleet bins, journal flush) update metrics from one thread.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Tuple

_EMIN = -27          # bucket 0 upper edge = 2**_EMIN (~7.5e-9)
_NBUCKETS = 64       # top bucket lower edge = 2**(_EMIN+62) (~3.4e10)

_frexp = math.frexp


class Counter:
    """Monotonic counter. ``inc`` is one attribute add."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


def bucket_index(v: float) -> int:
    """Log2 bucket index for ``v`` (clamped to [0, _NBUCKETS-1]).

    For ``v > 0``: ``frexp(v) = (m, e)`` with ``v = m * 2**e`` and
    ``0.5 <= m < 1``, so ``v`` lies in ``[2**(e-1), 2**e)`` and the
    bucket index is ``e - _EMIN``. Non-positive values land in bucket 0.
    """
    if v <= 0.0:
        return 0
    i = _frexp(v)[1] - _EMIN
    if i < 0:
        return 0
    if i >= _NBUCKETS:
        return _NBUCKETS - 1
    return i


def bucket_bounds(i: int) -> Tuple[float, float]:
    """(lower, upper] edges of bucket ``i``; bucket 0's lower edge is 0."""
    hi = 2.0 ** (i + _EMIN)
    lo = 0.0 if i == 0 else 2.0 ** (i + _EMIN - 1)
    return lo, hi


class Histogram:
    """Fixed 64-bucket log2 histogram with running count/sum/min/max."""
    __slots__ = ("name", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.counts: List[int] = [0] * _NBUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            i = 0
        else:
            i = _frexp(v)[1] - _EMIN
            if i < 0:
                i = 0
            elif i >= _NBUCKETS:
                i = _NBUCKETS - 1
        self.counts[i] += 1

    def quantile(self, q: float) -> float:
        """Bucket-upper-edge estimate of the ``q`` order statistic,
        clamped to the observed [min, max]. 0.0 when empty."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                hi = 2.0 ** (i + _EMIN)
                if hi < self.min:
                    return self.min
                if hi > self.max:
                    return self.max
                return hi
        return self.max

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": (self.sum / self.count) if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Get-or-create registry keyed by dotted metric name.

    Names are namespaced by subsystem: ``exec.*`` (fleet bins),
    ``serverless.*`` (invocations), ``store.*``, ``wal.*`` (journal),
    ``runtime.*``, ``rollout_cache.*``, ``jit.retrace.*``,
    ``detection.*`` (per-deployment rolling error gauges).
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name)
                    self._metrics[name] = m
        if type(m) is not cls:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def items(self):
        with self._lock:
            return sorted(self._metrics.items())

    def snapshot(self) -> dict:
        """{name: scalar | histogram-summary dict}, sorted by name."""
        out = {}
        for name, m in self.items():
            if type(m) is Histogram:
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


_GLOBAL = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    """The process-global registry. Components constructed outside a
    ``Castor`` (direct executor/store construction in tests and
    benchmarks) default to this."""
    return _GLOBAL


def note_retrace(name: str) -> None:
    """Shared retrace-counter helper (ISSUE 10 satellite 2).

    Call as the first line of a jitted function body: the Python body
    only runs while jax traces, so each increment is one (re)trace of
    that function. Unlike ``forecast.features.note_trace`` this keeps a
    *named* counter per hot-path fn (``jit.retrace.<name>``) in the
    global registry; the legacy un-named total keeps its existing delta
    semantics and is mirrored here by ``features.note_trace`` itself.
    """
    _GLOBAL.counter("jit.retrace." + name).inc()


def retrace_counts() -> Dict[str, int]:
    """{fn-name: retrace count} for every ``jit.retrace.*`` counter."""
    pre = "jit.retrace."
    return {name[len(pre):]: m.value for name, m in _GLOBAL.items()
            if name.startswith(pre)}
