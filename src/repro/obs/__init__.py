"""Unified observability plane (ISSUE 10).

Three small modules behind one import surface:

- ``trace``   — hierarchical span tracer (counter ids, injectable clock,
                bounded ring, cross-process stitching).
- ``metrics`` — namespaced counters / gauges / log-bucket histograms.
- ``export``  — Perfetto/Chrome trace-event JSON, Prometheus text
                exposition, and the JSON snapshot ``Castor.stats()`` is a
                view over.

Everything here is host-side Python: no jax imports, no allocation on
the hot paths, and a process-global default tracer/registry so that
components constructed outside a ``Castor`` (tests build executors and
stores directly) are still instrumented.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_metrics, note_retrace, retrace_counts)
from .trace import NULL_TRACER, Span, Tracer, get_tracer, set_tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_metrics",
    "note_retrace", "retrace_counts",
    "NULL_TRACER", "Span", "Tracer", "get_tracer", "set_tracer",
]
