"""Exporters over the trace ring and metrics registry.

Three output shapes, one source of truth:

- ``chrome_trace`` / ``write_chrome_trace`` — Chrome trace-event JSON
  (``ph: "X"`` complete events) that Perfetto (ui.perfetto.dev) and
  ``chrome://tracing`` open directly. ``Castor.dump_trace(path)`` is a
  thin wrapper.
- ``prometheus_text`` — Prometheus text exposition (counters, gauges,
  and cumulative ``_bucket{le=...}`` histogram series).
- ``obs_snapshot`` — the JSON snapshot ``Castor.stats()`` is a
  backward-compatible view over: ``{"stats": <legacy schema>,
  "metrics": ..., "trace": ...}``.

``write_json_artifact`` is the single code path for the repo's
``artifacts/*.json`` telemetry files (ISSUE 10 satellite 3) — the bench
modules that used to hand-roll ``Path.write_text(json.dumps(...))``
now route here, keeping one serialization convention (sorted keys,
indent=1, trailing newline) without changing file shapes.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional

from .metrics import (Histogram, MetricsRegistry, bucket_bounds,
                      get_metrics)
from .trace import Tracer, get_tracer


# -- Perfetto / Chrome trace-event JSON -------------------------------

def chrome_trace(tracer: Optional[Tracer] = None, *,
                 pid: int = 1) -> dict:
    """Chrome trace-event JSON for every span in the ring.

    Timestamps are microseconds on the wall clock, derived from the
    tracer's ``epoch`` anchor — ``(wall, mono)`` captured at tracer
    construction — so traces from injected deterministic clocks export
    reproducibly (inject ``epoch=(0.0, 0.0)``).
    """
    tr = tracer if tracer is not None else get_tracer()
    wall0, mono0 = tr.epoch
    events = []
    for s in tr.spans():
        ev = {
            "name": s.name,
            "cat": s.name.split(".", 1)[0],
            "ph": "X",
            "ts": (wall0 + (s.t0 - mono0)) * 1e6,
            "dur": (s.t1 - s.t0) * 1e6,
            "pid": pid,
            "tid": s.tid,
            "args": dict(s.args or {},
                         trace_id=s.trace_id, span_id=s.span_id,
                         parent_id=s.parent_id),
        }
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, tracer: Optional[Tracer] = None, *,
                       pid: int = 1) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(chrome_trace(tracer, pid=pid)) + "\n")
    return path


# -- Prometheus text exposition ---------------------------------------

def _prom_name(name: str) -> str:
    return "repro_" + name.replace(".", "_").replace("-", "_")


def prometheus_text(registry: Optional[MetricsRegistry] = None) -> str:
    """Prometheus text format, one family per metric. Histograms emit
    cumulative ``_bucket{le="..."}`` series plus ``_sum``/``_count``."""
    reg = registry if registry is not None else get_metrics()
    lines = []
    for name, m in reg.items():
        pname = _prom_name(name)
        if type(m) is Histogram:
            lines.append(f"# TYPE {pname} histogram")
            cum = 0
            for i, c in enumerate(m.counts):
                if c == 0:
                    continue
                cum += c
                le = bucket_bounds(i)[1]
                lines.append(f'{pname}_bucket{{le="{le!r}"}} {cum}')
            lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{pname}_sum {m.sum!r}")
            lines.append(f"{pname}_count {m.count}")
        elif type(m).__name__ == "Counter":
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname} {m.value}")
        else:
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {m.value!r}")
    return "\n".join(lines) + "\n"


# -- JSON snapshot -----------------------------------------------------

def obs_snapshot(stats: dict, tracer: Optional[Tracer] = None,
                 registry: Optional[MetricsRegistry] = None) -> dict:
    """The unified snapshot: the legacy ``Castor.stats()`` dict rides
    under ``"stats"`` (unchanged schema — ``Castor.stats()`` returns
    exactly that sub-dict), next to the metrics registry snapshot and
    the tracer's ring stats."""
    tr = tracer if tracer is not None else get_tracer()
    reg = registry if registry is not None else get_metrics()
    return {"stats": stats, "metrics": reg.snapshot(),
            "trace": tr.stats()}


# -- artifact files (satellite 3) -------------------------------------

def write_json_artifact(path, payload: dict) -> Path:
    """One code path for ``artifacts/*.json`` telemetry emission."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return path
