"""Time-series transformations (paper §4.1, Fig. 4) + feature engineering
(Table 1): alignment/resampling of irregular feeds, integration of
instantaneous signals into energy, lagged features, calendar features.
Mostly numpy (host-side data prep); the calendar features additionally
ship a jnp form (``calendar_features_jnp``) so the device-resident scoring
rollout can assemble them inside a jitted program.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

HOUR = 3600.0
DAY = 24 * HOUR


def regular_grid(start: float, end: float, step: float) -> np.ndarray:
    """THE binning rule for [start, end) grids — single source of truth for
    ``align_resample`` and the fleet feature path, so per-series rows and
    the shared fleet grid can never disagree on length."""
    nbins = max(int(round((end - start) / step)), 1)
    return start + step * np.arange(nbins)


def align_resample(times, values, *, step: float, start: Optional[float] = None,
                   end: Optional[float] = None, how: str = "mean",
                   with_mask: bool = False):
    """Aggregate an irregular series onto a regular grid [start, end) with
    bin width ``step``. Empty bins are filled by forward-fill (then 0).

    With ``with_mask=True`` additionally returns the boolean fill mask —
    ``mask[j]`` is True where bin j held real points (False bins carry
    forward-filled or zero values). The incremental fleet runtime needs
    the mask to re-derive window-relative fill semantics from a ring
    buffer whose fill sources may have slid out of the current window.
    """
    t = np.asarray(times, np.float64)
    v = np.asarray(values, np.float64)
    if t.size == 0:
        e = np.empty(0)
        return (e, e, np.empty(0, bool)) if with_mask else (e, e)
    start = float(t.min() // step * step) if start is None else start
    end = float(t.max() // step * step + step) if end is None else end
    grid = regular_grid(start, end, step)
    nbins = grid.size
    idx = np.floor((t - start) / step).astype(np.int64)
    ok = (idx >= 0) & (idx < nbins)
    idx, vv = idx[ok], v[ok]
    sums = np.bincount(idx, weights=vv, minlength=nbins)
    cnts = np.bincount(idx, minlength=nbins)
    if how == "sum":
        out = sums                       # empty bins carry zero mass
    else:
        with np.errstate(invalid="ignore"):
            out = np.where(cnts > 0, sums / np.maximum(cnts, 1), np.nan)
        # forward-fill gaps (mean/level signals only — never for sums)
        filled = np.where(cnts > 0)[0]
        if filled.size:
            ffidx = np.maximum.accumulate(
                np.where(cnts > 0, np.arange(nbins), -1))
            out = np.where(ffidx >= 0, out[np.maximum(ffidx, 0)], 0.0)
        else:
            out = np.zeros(nbins)
    if with_mask:
        return grid, out, cnts > 0
    return grid, out


def integrate_to_energy(times, current, *, voltage: float = 230.0,
                        step: float = 900.0) -> Tuple[np.ndarray, np.ndarray]:
    """Fig. 4: instantaneous current magnitude [A] at irregular resolution ->
    energy [kWh] on a regular ``step`` grid (trapezoidal integration of
    P = V*I over each bin)."""
    t = np.asarray(times, np.float64)
    i = np.asarray(current, np.float64)
    if t.size < 2:
        return np.empty(0), np.empty(0)
    order = np.argsort(t)
    t, i = t[order], i[order]
    p_kw = voltage * i / 1000.0                         # kW
    # trapezoid segments, assigned to the bin of their midpoint
    seg_e = 0.5 * (p_kw[1:] + p_kw[:-1]) * np.diff(t) / HOUR   # kWh
    mid = 0.5 * (t[1:] + t[:-1])
    start = float(t[0] // step * step)
    nbins = int((t[-1] - start) // step) + 1
    idx = np.floor((mid - start) / step).astype(np.int64)
    ok = (idx >= 0) & (idx < nbins)
    energy = np.bincount(idx[ok], weights=seg_e[ok], minlength=nbins)
    grid = start + step * np.arange(nbins)
    return grid, energy


def lagged_features(series: np.ndarray, lags) -> np.ndarray:
    """X[t, j] = series[t - lags[j]]; rows with any missing lag are the
    caller's responsibility (first max(lags) rows)."""
    s = np.asarray(series, np.float64)
    lags = list(lags)
    out = np.zeros((s.size, len(lags)))
    for j, L in enumerate(lags):
        out[L:, j] = s[: s.size - L] if L > 0 else s
        out[:L, j] = s[0]
    return out


def calendar_phases(times) -> Tuple[np.ndarray, np.ndarray]:
    """Epoch times -> (hour-of-day 0..24, day-of-week 0..6), float64.

    The modular reduction happens HERE, on the host in float64: epoch
    seconds overflow float32 precision after ~194 days, so a jitted
    (float32) program must receive the reduced phases, never raw times.
    """
    t = np.asarray(times, np.float64)
    return (t % DAY) / HOUR, (t // DAY) % 7


def calendar_features(times) -> np.ndarray:
    """Paper Table 1: time-of-day + week-day features (smooth encodings)."""
    hod, dow = calendar_phases(times)
    feats = [np.sin(2 * np.pi * hod / 24), np.cos(2 * np.pi * hod / 24),
             np.sin(2 * np.pi * dow / 7), np.cos(2 * np.pi * dow / 7),
             (dow >= 5).astype(np.float64)]
    return np.stack(feats, axis=1)


def calendar_features_jnp(hod, dow):
    """jnp twin of ``calendar_features`` over pre-reduced phases (see
    ``calendar_phases``), traceable inside the device scoring rollout."""
    import jax.numpy as jnp
    return jnp.stack(
        [jnp.sin(2 * jnp.pi * hod / 24), jnp.cos(2 * jnp.pi * hod / 24),
         jnp.sin(2 * jnp.pi * dow / 7), jnp.cos(2 * jnp.pi * dow / 7),
         (dow >= 5).astype(jnp.float32)], axis=-1)


def train_val_split(times, values, split_time):
    t = np.asarray(times)
    m = t < split_time
    return (t[m], np.asarray(values)[m]), (t[~m], np.asarray(values)[~m])


def mape(actual, predicted, eps: float = 1e-9) -> float:
    a = np.asarray(actual, np.float64)
    p = np.asarray(predicted, np.float64)
    denom = np.maximum(np.abs(a), eps)
    return float(np.mean(np.abs(a - p) / denom) * 100.0)
