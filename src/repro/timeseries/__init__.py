from .store import TimeSeriesStore  # noqa: F401
from .weather import WeatherService  # noqa: F401
from . import transforms, ingest  # noqa: F401
