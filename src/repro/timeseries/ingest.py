"""Synthetic IoT ingestion: deterministic smart-grid-like sensor fleets with
irregular sampling (paper §4.1, Fig. 2: ~500 sensors, ~15M readings/month at
the Cyprus site). Generates energy-demand profiles (daily/weekly shape +
temperature response + noise) and instantaneous current feeds for the
Fig.-4 transformation model."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .transforms import DAY, HOUR


@dataclass
class SiteSpec:
    name: str
    n_prosumers: int
    n_feeders: int
    n_substations: int
    seed: int = 0


def demand_profile(rng, times, temperature) -> np.ndarray:
    """kWh per interval: base + daily/weekly shape + temperature response."""
    t = np.asarray(times, np.float64)
    hod = (t % DAY) / HOUR
    dow = ((t // DAY) % 7).astype(np.int64)
    base = rng.uniform(1.0, 6.0)
    morning = np.exp(-0.5 * ((hod - rng.uniform(7, 9)) / 1.5) ** 2)
    evening = np.exp(-0.5 * ((hod - rng.uniform(18, 20)) / 2.0) ** 2)
    weekend = np.where(dow >= 5, rng.uniform(0.7, 0.9), 1.0)
    temp_resp = 0.08 * np.maximum(temperature - 22.0, 0) \
        + 0.05 * np.maximum(16.0 - temperature, 0)
    noise = rng.normal(0, 0.05, size=t.shape)
    return np.maximum(
        base * (0.4 + morning + 1.2 * evening) * weekend + temp_resp + noise, 0.01)


def build_site(castor, spec: SiteSpec, *, t0: float, t1: float,
               step: float = HOUR) -> dict:
    """Create topology + ingest regular energy series for every entity.
    Returns {"contexts": [...], "readings": n}."""
    rng = np.random.default_rng(spec.seed)
    castor.add_signal("ENERGY_LOAD", "kWh", "energy demand per interval")
    castor.add_signal("CURRENT_MAG", "A", "instantaneous current magnitude")
    times = np.arange(t0, t1, step)

    contexts, total = [], 0
    for s in range(spec.n_substations):
        sub = castor.add_entity(f"{spec.name}_SUB_{s}", "SUBSTATION",
                                lat=35.0 + s * 0.01, lon=33.0 + s * 0.01)
        feeders = []
        for f in range(spec.n_feeders):
            fd = castor.add_entity(f"{spec.name}_FD_{s}_{f}", "FEEDER",
                                   lat=sub.lat + 0.001 * f, lon=sub.lon,
                                   parent=sub.name)
            feeders.append(fd)
        agg = np.zeros_like(times)
        for p in range(spec.n_prosumers):
            fd = feeders[p % len(feeders)]
            pr = castor.add_entity(f"{spec.name}_PRO_{s}_{p}", "PROSUMER",
                                   lat=fd.lat + 0.0001 * p, lon=fd.lon,
                                   parent=fd.name)
            temp = castor.weather.temperature(pr.lat, pr.lon, times)
            load = demand_profile(rng, times, temp)
            # irregular raw feed: jitter timestamps, drop ~2%
            keep = rng.random(times.size) > 0.02
            jit = times[keep] + rng.uniform(-0.1, 0.1, keep.sum()) * step
            ts_id = f"raw::{pr.name}::load"
            total += castor.ingest(ts_id, jit, load[keep])
            castor.link(ts_id, "ENERGY_LOAD", pr.name)
            contexts.append(("ENERGY_LOAD", pr.name))
            agg += load
        ts_id = f"raw::{sub.name}::load"
        total += castor.ingest(ts_id, times, agg)
        castor.link(ts_id, "ENERGY_LOAD", sub.name)
        contexts.append(("ENERGY_LOAD", sub.name))
    # bulk ingest done: consolidate so the first fleet read_many is a pure
    # binary-search slice (one sorted segment per series)
    castor.compact()
    seg = castor.store.stats()["segments"]      # store-wide, hence the key
    return {"contexts": contexts, "readings": total, "store_segments": seg}


def ingest_current_feed(castor, entity: str, *, t0: float, t1: float,
                        mean_dt: float = 60.0, seed: int = 3) -> str:
    """One-minute-ish instantaneous current feed (Fig. 4 input)."""
    rng = np.random.default_rng(seed)
    n = int((t1 - t0) / mean_dt)
    times = np.sort(t0 + (t1 - t0) * rng.random(n))
    hod = (times % DAY) / HOUR
    amps = 10 + 6 * np.sin(2 * np.pi * (hod - 7) / 24) ** 2 \
        + rng.normal(0, 0.5, n)
    ts_id = f"raw::{entity}::current"
    castor.ingest(ts_id, times, np.maximum(amps, 0.1))
    castor.link(ts_id, "CURRENT_MAG", entity)
    return ts_id
