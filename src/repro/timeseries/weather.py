"""Synthetic weather service keyed by (lat, lon) — stands in for the paper's
external weather-forecast provider. Deterministic: temperature is a smooth
function of location, season, hour and a location-seeded noise process, so
train/validation reads are reproducible. ``forecast`` adds horizon-dependent
noise to mimic forecast degradation."""
from __future__ import annotations

from typing import Optional

import numpy as np

DAY = 86400.0
YEAR = 365.0 * DAY

_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_GOLD = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (vectorized, wrapping uint64)."""
    x = (x ^ (x >> np.uint64(30))) * _M1
    x = (x ^ (x >> np.uint64(27))) * _M2
    return x ^ (x >> np.uint64(31))


def _counter_normals(keys: np.ndarray, salt: int, idx: np.ndarray
                     ) -> np.ndarray:
    """Standard normals addressed by (site key, salt, position): a
    counter-based generator (splitmix64 -> Box-Muller), so a whole
    fleet's draws vectorize as (N, T) array math instead of N generator
    constructions — generator construction alone dominated steady-state
    fleet polls. Values are deterministic per address and independent of
    batch composition, which keeps the scalar and batched weather reads
    bitwise-identical by construction."""
    c = (keys[:, None] * _GOLD + np.uint64(salt & 0xFFFFFFFFFFFFFFFF)
         + idx.astype(np.uint64) * _M2)
    h1 = _mix64(c * np.uint64(2))
    h2 = _mix64(c * np.uint64(2) + np.uint64(1))
    # 53-bit mantissas -> u1 in (0, 1], u2 in [0, 1)
    u1 = ((h1 >> np.uint64(11)).astype(np.float64) + 1.0) / 2.0 ** 53
    u2 = (h2 >> np.uint64(11)).astype(np.float64) / 2.0 ** 53
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


class WeatherService:
    def __init__(self, seed: int = 7):
        self.seed = seed
        self._params_cache: dict = {}    # per-site generator parameters

    def _key(self, lat: float, lon: float) -> int:
        return (self.seed * 1_000_003 + int(lat * 1e4) * 7919
                + int(lon * 1e4) * 104729) % (2**31 - 1)

    def _site_params(self, lats, lons) -> tuple:
        """Per-site generator parameters, drawn in the SAME per-site rng
        order as the scalar path (one tiny rng per site; the heavy array
        math is what the *_many entry points vectorize). Deterministic per
        site, so they are memoized — rng CONSTRUCTION was the dominant
        cost of a steady-state poll's weather reads."""
        phase = np.empty(len(lats))
        amp_d = np.empty(len(lats))
        amp_y = np.empty(len(lats))
        base = np.empty(len(lats))
        for i, (lat, lon) in enumerate(zip(lats, lons)):
            k = self._key(lat, lon)
            p = self._params_cache.get(k)
            if p is None:
                rng = np.random.default_rng(k)
                p = self._params_cache[k] = (
                    rng.uniform(0, 2 * np.pi), rng.uniform(4, 8),
                    rng.uniform(8, 14), rng.uniform(8, 18))
            phase[i], amp_d[i], amp_y[i], base[i] = p
        return phase[:, None], amp_d[:, None], amp_y[:, None], base[:, None]

    def sites(self, lats, lons) -> "SiteBatch":
        """Precomputed key/parameter arrays for a FIXED fleet of sites.
        The steady-state runtime caches one per bin, so each poll's
        weather reads are pure (N, T) array math — zero per-site python
        on the hot path."""
        return SiteBatch(self, lats, lons)

    def temperature_many(self, lats, lons, times) -> np.ndarray:
        """Batched ``temperature``: ``(N,)`` sites x ``(T,)`` times ->
        ``(N, T)``, bitwise-identical rows to N scalar calls (the per-site
        parameters come from the same draws and the elementwise math
        broadcasts without reassociation)."""
        return self.sites(lats, lons).temperature(times)

    def temperature(self, lat: float, lon: float, times) -> np.ndarray:
        """Actual temperature at given epoch times (deg C)."""
        return self.temperature_many([lat], [lon], times)[0]

    def forecast_many(self, lats, lons, issued_at: float, times, *,
                      draw_len: Optional[int] = None) -> np.ndarray:
        """Batched ``forecast``: one call for a whole fleet bin -> (N, T),
        rows bitwise-identical to N scalar calls (see SiteBatch.forecast
        for the counter-based error and ``draw_len`` semantics)."""
        return self.sites(lats, lons).forecast(issued_at, times,
                                               draw_len=draw_len)

    def forecast(self, lat: float, lon: float, issued_at: float, times) -> np.ndarray:
        """Forecast issued at ``issued_at`` for target ``times``: the truth
        plus error growing with lead time (~0.2 degC/day)."""
        return self.forecast_many([lat], [lon], issued_at, times)[0]


class SiteBatch:
    """Key + generator-parameter arrays for a fixed (lat, lon) fleet.
    Every weather entry point funnels through here, so scalar and batched
    reads cannot drift apart."""

    def __init__(self, service: WeatherService, lats, lons):
        self.keys = np.asarray(
            [service._key(la, lo) for la, lo in zip(lats, lons)], np.uint64)
        self._params = service._site_params(lats, lons)

    def temperature(self, times) -> np.ndarray:
        """Observed temperature (N, T): deterministic elementwise function
        of time per site — slicing the time grid slices the result (the
        observation noise is addressed by the timestamp itself, not by
        array position, so incremental ring appends equal full reads).

        The ~0.3 degC observation noise matters beyond realism: perfectly
        smooth sinusoidal temperatures make a lagged-temperature design
        block nearly rank-deficient, amplifying f32 solver differences
        between the batched and single ridge paths far past the pinned
        executor-equivalence tolerances."""
        t = np.asarray(times, np.float64)
        phase, amp_d, amp_y, base = self._params
        seasonal = amp_y * np.sin(2 * np.pi * t / YEAR + phase)
        diurnal = amp_d * np.sin(2 * np.pi * t / DAY - np.pi / 2)
        slow = 2.0 * np.sin(2 * np.pi * t / (11 * DAY) + phase * 0.7)
        obs = 0.3 * _counter_normals(self.keys, 0x5DEECE66D,
                                     np.round(t).astype(np.int64))
        return base + seasonal + diurnal + slow + obs

    def forecast(self, issued_at: float, times, *,
                 draw_len: Optional[int] = None) -> np.ndarray:
        """Forecast = truth + counter-based error growing with lead time.

        ``draw_len``: when ``times`` is the TRAILING slice of a longer
        ``draw_len``-point grid, the error draws are addressed at their
        full-grid positions, so the result equals
        ``forecast(..., full_grid)[:, -len(times):]`` exactly — a
        steady-state score poll skips the math for history it never reads.

        The error is counter-based (``_counter_normals``): one vectorized
        (N, T) evaluation per fleet bin, deterministic per (site, issue
        time, lead position) and independent of the batch — N per-site
        generator constructions used to dominate the poll.
        """
        t = np.asarray(times, np.float64)
        truth = self.temperature(t)
        lead_days = np.maximum(t - issued_at, 0.0) / DAY
        n_draw = t.size if draw_len is None else int(draw_len)
        idx = np.arange(n_draw - t.size, n_draw)
        err = 0.2 * _counter_normals(self.keys, int(issued_at) % 65521, idx)
        return truth + err * np.sqrt(1.0 + lead_days)
