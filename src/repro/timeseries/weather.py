"""Synthetic weather service keyed by (lat, lon) — stands in for the paper's
external weather-forecast provider. Deterministic: temperature is a smooth
function of location, season, hour and a location-seeded noise process, so
train/validation reads are reproducible. ``forecast`` adds horizon-dependent
noise to mimic forecast degradation."""
from __future__ import annotations

import numpy as np

DAY = 86400.0
YEAR = 365.0 * DAY


class WeatherService:
    def __init__(self, seed: int = 7):
        self.seed = seed

    def _key(self, lat: float, lon: float) -> int:
        return (self.seed * 1_000_003 + int(lat * 1e4) * 7919
                + int(lon * 1e4) * 104729) % (2**31 - 1)

    def temperature(self, lat: float, lon: float, times) -> np.ndarray:
        """Actual temperature at given epoch times (deg C)."""
        t = np.asarray(times, np.float64)
        rng = np.random.default_rng(self._key(lat, lon))
        phase, amp_d, amp_y = rng.uniform(0, 2 * np.pi), rng.uniform(4, 8), rng.uniform(8, 14)
        base = rng.uniform(8, 18)
        seasonal = amp_y * np.sin(2 * np.pi * t / YEAR + phase)
        diurnal = amp_d * np.sin(2 * np.pi * t / DAY - np.pi / 2)
        slow = 2.0 * np.sin(2 * np.pi * t / (11 * DAY) + phase * 0.7)
        jitter = 0.3 * np.sin(t / 977.0 + phase)     # deterministic "noise"
        return base + seasonal + diurnal + slow + jitter

    def forecast(self, lat: float, lon: float, issued_at: float, times) -> np.ndarray:
        """Forecast issued at ``issued_at`` for target ``times``: the truth
        plus error growing with lead time (~0.2 degC/day)."""
        t = np.asarray(times, np.float64)
        truth = self.temperature(lat, lon, t)
        lead_days = np.maximum(t - issued_at, 0.0) / DAY
        rng = np.random.default_rng(self._key(lat, lon) ^ int(issued_at) % 65521)
        err = rng.normal(0.0, 0.2, size=t.shape) * np.sqrt(1.0 + lead_days)
        return truth + err
