"""Chunked, compacting columnar time-series store (LSM-lite).

Semantics match the paper's store: ingestion is append-only (irregular,
possibly out-of-order timestamps allowed), reads return time-sorted views,
nothing is ever overwritten. Persistence is NPZ so a real backend (the
paper used a relational DB) could be swapped behind the same interface.

Engine design
-------------
The seed implementation concatenated and re-sorted a series' entire append
history on every ``read()`` (and even ``last_time()``), so read cost grew
superlinearly with ingestion. This engine organizes each series as:

* an unsorted **tail**: raw appended chunks, bounded by ``tail_max`` points;
* a list of sorted immutable **segments**: columnar ``(times, values)``
  pairs, each ascending in time, ordered oldest-to-newest by creation.

Write path: ``append`` lands chunks in the tail in O(1). When the tail
exceeds ``tail_max`` it is stable-sorted into a new segment (touching only
the new points) and similar-sized segments are tiered-merged two at a time.
A merge of two sorted runs is a single linear interleave (the searchsorted
trick) — the full history is **never** re-sorted in one shot, and total
ingest cost stays O(n log n) amortized with O(log n) live segments.

Read path: ``read``/``read_many`` binary-search every segment's window
boundaries plus a cached sorted view of the tail, and linearly interleave
only the returned window points — O(log n + k + dirty) for a k-point
window, where *dirty* is the (usually tiny) data not yet in the oldest
segment. When dirty data exceeds 1/8 of the series, the read first
consolidates (flush tail, linear-merge segments to one) so the cost is
amortized against the appends that created it; after that, reads are pure
O(log n + k) slices until enough new appends arrive. Steady interleaved
append/read workloads therefore never rewrite the full history per read.
``last_time``/``first_time`` are O(1) (tracked incrementally on append).

Invariants (checked by ``tests/test_store.py``):

1. every segment is sorted ascending by time;
2. segments are ordered oldest-to-newest by creation, and points with equal
   timestamps keep global append order across tail sorts and merges (stable
   compaction — reads observe exactly the seed store's ordering);
3. ``sum(segment sizes) + tail size == count`` — compaction moves points,
   it never drops or duplicates them;
4. returned arrays are read-only views of immutable segment storage —
   many parallel model executions share one columnar copy (copy before
   mutating).

Concurrency: one lock per store guards both paths (appends are chunk-level,
as in the paper's parallel-sender ingestion benchmark); reads may compact
but observe the same points an uncompacted read would.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def _merge_sorted(t_old: np.ndarray, v_old: np.ndarray,
                  t_new: np.ndarray, v_new: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Linear stable interleave of two sorted runs (older run wins ties)."""
    n1, n2 = t_old.size, t_new.size
    pos_old = np.searchsorted(t_new, t_old, side="left") + np.arange(n1)
    pos_new = np.searchsorted(t_old, t_new, side="right") + np.arange(n2)
    t = np.empty(n1 + n2, np.float64)
    v = np.empty(n1 + n2, np.float64)
    t[pos_old], t[pos_new] = t_old, t_new
    v[pos_old], v[pos_new] = v_old, v_new
    return t, v


def _freeze(a: np.ndarray) -> np.ndarray:
    a.flags.writeable = False
    return a


@dataclass
class _Segment:
    """Immutable sorted columnar run."""
    times: np.ndarray
    values: np.ndarray

    @property
    def n(self) -> int:
        return self.times.size


@dataclass
class _Series:
    segments: List[_Segment] = field(default_factory=list)
    tail_t: List[np.ndarray] = field(default_factory=list)
    tail_v: List[np.ndarray] = field(default_factory=list)
    tail_n: int = 0
    count: int = 0
    t_min: float = math.inf
    t_max: float = -math.inf
    tail_view: Optional[_Segment] = None    # cached sorted tail (ephemeral)


_EMPTY = _freeze(np.empty(0, np.float64))


class TimeSeriesStore:
    """Append-only columnar store; see module docstring for the design."""

    def __init__(self, *, tail_max: int = 1024, merge_factor: int = 2):
        self._data: Dict[str, _Series] = {}
        self._lock = threading.Lock()
        self.tail_max = int(tail_max)
        self.merge_factor = int(merge_factor)
        # telemetry (Fig. 2 benchmark + executor bin stats)
        self.append_count = 0          # points ingested
        self.read_count = 0            # single-series read() calls
        self.read_many_count = 0       # batched read_many() calls
        self.delta_read_count = 0      # watermark-delta read_many(since=...)
        self.compaction_count = 0      # tail flushes
        self.merge_count = 0           # segment merges
        self.merged_points = 0         # points moved by merges
        self.journal = None            # durability.Journal when Castor.open'd

    # ---------------- write path ----------------
    def append(self, ts_id: str, times, values) -> int:
        times = np.asarray(times, np.float64).ravel()
        values = np.asarray(values, np.float64).ravel()
        assert times.shape == values.shape, (times.shape, values.shape)
        if times.size == 0:
            return 0
        with self._lock:
            s = self._data.setdefault(ts_id, _Series())
            s.tail_t.append(times)
            s.tail_v.append(values)
            s.tail_n += times.size
            s.tail_view = None
            s.count += times.size
            s.t_min = min(s.t_min, float(times.min()))
            s.t_max = max(s.t_max, float(times.max()))
            self.append_count += times.size
            j = self.journal
            if j is not None:      # one record per append call (atomic:
                j.append("ts", {   # a chunk replays whole or not at all)
                    "id": ts_id, "t": times, "v": values})
            if s.tail_n >= self.tail_max:
                self._flush_tail(s)
                self._tier_merge(s)
        return times.size

    def append_points(self, ts_ids: Sequence[str], times, values) -> int:
        """Batched one-point-per-series append under ONE lock — the
        detection flow's derived-signal write-back (a minutely bin lands
        exactly one (t, score) point on every sensor's anomaly series;
        N ``append()`` calls would pay N lock round-trips and N array
        coercions for scalar writes)."""
        from ..obs.trace import get_tracer
        tracer = get_tracer()
        if not tracer.enabled:
            return self._append_points(ts_ids, times, values)
        with tracer.span("store.append_points", n=len(ts_ids)):
            return self._append_points(ts_ids, times, values)

    def _append_points(self, ts_ids: Sequence[str], times, values) -> int:
        t = np.asarray(times, np.float64).ravel()
        v = np.asarray(values, np.float64).ravel()
        assert len(ts_ids) == t.size == v.size, (len(ts_ids), t.size, v.size)
        t_list = t.tolist()                  # python floats: cheap compares
        # one C-loop view split per column instead of a python slice pair
        # per point (rows of the (n, 1) reshape are the same 1-element
        # float64 views t[k:k+1] would produce)
        rows_t = list(t.reshape(-1, 1))
        rows_v = list(v.reshape(-1, 1))
        data_get = self._data.get
        tail_max = self.tail_max
        with self._lock:
            for k, ts_id in enumerate(ts_ids):
                # get-then-create, not setdefault(_Series()): steady state
                # always hits, and a throwaway _Series per point is real
                # money at fleet width
                s = data_get(ts_id)
                if s is None:
                    s = self._data[ts_id] = _Series()
                s.tail_t.append(rows_t[k])
                s.tail_v.append(rows_v[k])
                s.tail_n += 1
                s.tail_view = None
                s.count += 1
                tk = t_list[k]
                if tk < s.t_min:
                    s.t_min = tk
                if tk > s.t_max:
                    s.t_max = tk
                if s.tail_n >= tail_max:
                    self._flush_tail(s)
                    self._tier_merge(s)
            self.append_count += t.size
            j = self.journal
            if j is not None:      # whole batch = one atomic record (the
                j.append("tsp", {  # detection flow suppresses this and
                    "ids": list(ts_ids), "t": t, "v": v})   # journals the
            # coarser "det" record instead — see DetectionStore.save_many)
        return int(t.size)

    def _flush_tail(self, s: _Series) -> None:
        """Promote the sorted tail view to a new immutable segment."""
        if not s.tail_n:
            return
        s.segments.append(self._tail_segment(s))   # reuses the cached sort
        s.tail_t, s.tail_v, s.tail_n = [], [], 0
        s.tail_view = None
        self.compaction_count += 1

    def _tier_merge(self, s: _Series) -> None:
        """Merge newest segments while similar-sized (amortized O(n log n))."""
        while (len(s.segments) >= 2 and
               s.segments[-1].n * self.merge_factor >= s.segments[-2].n):
            self._merge_last_two(s)

    def _merge_last_two(self, s: _Series) -> None:
        new = s.segments.pop()
        old = s.segments.pop()
        t, v = _merge_sorted(old.times, old.values, new.times, new.values)
        s.segments.append(_Segment(_freeze(t), _freeze(v)))
        self.merge_count += 1
        self.merged_points += t.size

    def _consolidate(self, s: _Series) -> None:
        """Flush tail + linear-merge down to a single sorted segment."""
        self._flush_tail(s)
        while len(s.segments) > 1:
            self._merge_last_two(s)

    def compact(self, ts_id: Optional[str] = None) -> None:
        """Force full consolidation (one sorted segment per series).

        Call after bulk ingest so the first fleet read is already a pure
        binary-search slice.
        """
        with self._lock:
            if ts_id is not None:
                s = self._data.get(ts_id)       # unknown id: no-op, like read
                targets = [s] if s is not None else []
            else:
                targets = list(self._data.values())
            for s in targets:
                self._consolidate(s)

    # ---------------- read path ----------------
    def _tail_segment(self, s: _Series) -> _Segment:
        """Sorted view of the tail, cached until the next append."""
        if s.tail_view is None:
            t = np.concatenate(s.tail_t) if len(s.tail_t) > 1 else s.tail_t[0]
            v = np.concatenate(s.tail_v) if len(s.tail_v) > 1 else s.tail_v[0]
            order = np.argsort(t, kind="stable")
            s.tail_view = _Segment(_freeze(t[order]), _freeze(v[order]))
        return s.tail_view

    def _prior_count_locked(self, s: Optional[_Series], t) -> int:
        """Number of stored points with time < ``t`` — O(log n) binary
        searches over the sorted segments plus the cached sorted tail.
        This is the late-data watermark check for delta readers: a count
        that moved under an unchanged watermark means an out-of-order
        append landed in already-consumed history."""
        if s is None or s.count == 0 or t is None:
            return 0
        n = sum(int(np.searchsorted(seg.times, t)) for seg in s.segments)
        if s.tail_n:
            n += int(np.searchsorted(self._tail_segment(s).times, t))
        return n

    def _read_locked(self, s: Optional[_Series], start, end,
                     consolidate: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray]:
        if s is None or s.count == 0:
            return _EMPTY, _EMPTY
        # amortized consolidation: once dirty (non-oldest-segment) data
        # reaches 1/8 of the series, merge it down so future reads are
        # slices; below that, serve via an ephemeral window merge so a
        # small append never forces an O(n) rewrite on the next read.
        # Watermark-delta reads (read_many(since=...)) skip this: their
        # windows touch only the newest points, so triggering an O(n)
        # rewrite on the steady-state hot path would defeat the O(delta)
        # contract.
        dirty = s.count - (s.segments[0].n if s.segments else 0)
        if consolidate and dirty and dirty * 8 >= s.count:
            self._consolidate(s)
        segs = list(s.segments)
        if s.tail_n:
            segs.append(self._tail_segment(s))   # newest run: append order
        parts: List[Tuple[np.ndarray, np.ndarray]] = []
        for seg in segs:
            lo = 0 if start is None else int(np.searchsorted(seg.times, start))
            hi = seg.n if end is None else int(np.searchsorted(seg.times, end))
            if hi > lo:
                parts.append((seg.times[lo:hi], seg.values[lo:hi]))
        if not parts:
            return _EMPTY, _EMPTY
        t, v = parts[0]
        for t2, v2 in parts[1:]:                 # oldest-first: ties stable
            t, v = _merge_sorted(t, v, t2, v2)
        if t.flags.writeable:                    # merged copies: same
            _freeze(t), _freeze(v)               # read-only contract as views
        return t, v

    def read(self, ts_id: str, start: Optional[float] = None,
             end: Optional[float] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Time-sorted read-only view of [start, end)."""
        with self._lock:
            self.read_count += 1
            return self._read_locked(self._data.get(ts_id), start, end)

    def read_many(self, ts_ids: Sequence[str], start: Optional[float] = None,
                  end: Optional[float] = None, *,
                  since: Optional[float] = None, prior_counts: bool = False):
        """Batched read: ONE store round-trip for a whole fleet bin.

        Returns one ``(times, values)`` pair per id (empty arrays for
        unknown ids), all under a single lock acquisition. This is the
        entry point ``FleetExecutor`` bins use instead of N ``read()``s.

        ``since`` is the watermark-delta form: equivalent to
        ``start=since`` but served without the amortized consolidation
        pass (the window touches only the newest points — O(log n + delta)
        guaranteed) and counted in ``delta_read_count`` telemetry.

        With ``prior_counts=True`` the return value is ``(pairs, prior)``
        where ``prior[i]`` is the number of stored points of ``ts_ids[i]``
        strictly before ``start``/``since`` — computed under the SAME lock
        acquisition as the read, so a delta reader can detect out-of-order
        (late) appends race-free: if ``prior`` moved since the last poll,
        history changed behind the watermark and cached state is stale.
        """
        from ..obs.trace import get_tracer
        tracer = get_tracer()
        if not tracer.enabled:
            return self._read_many(ts_ids, start, end, since=since,
                                   prior_counts=prior_counts)
        with tracer.span("store.read_many", n=len(ts_ids),
                         delta=since is not None):
            return self._read_many(ts_ids, start, end, since=since,
                                   prior_counts=prior_counts)

    def _read_many(self, ts_ids: Sequence[str],
                   start: Optional[float] = None,
                   end: Optional[float] = None, *,
                   since: Optional[float] = None,
                   prior_counts: bool = False):
        fast = since is not None
        if fast:
            start = since
        consolidate = not fast
        data_get = self._data.get
        with self._lock:
            self.read_many_count += 1
            if fast:
                self.delta_read_count += 1
            out, prior = [], []
            for i in ts_ids:
                s = data_get(i)
                if fast and s is not None and s.count \
                        and len(s.segments) == 1 and not s.tail_n:
                    # steady-state fast path: consolidated series, delta
                    # window — two binary searches, zero-copy views
                    # (ndarray.searchsorted directly: the np.searchsorted
                    # dispatch wrapper is measurable at fleet width)
                    seg = s.segments[0]
                    lo = seg.times.searchsorted(start)
                    hi = seg.n if end is None else \
                        seg.times.searchsorted(end)
                    if prior_counts:
                        prior.append(int(lo))
                    out.append((seg.times[lo:hi], seg.values[lo:hi]))
                    continue
                if prior_counts:
                    prior.append(self._prior_count_locked(s, start))
                out.append(self._read_locked(s, start, end, consolidate))
            if prior_counts:
                return out, np.asarray(prior, np.int64)
            return out

    def read_many_flat(self, ts_ids: Sequence[str],
                       start: Optional[float] = None,
                       end: Optional[float] = None, *,
                       since: Optional[float] = None
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``read_many`` flattened for vectorized consumers: ONE
        ``(sizes, times, values)`` triple — per-series windows
        concatenated in order, ``sizes[i]`` points belonging to
        ``ts_ids[i]``. Skips the per-series pair materialization that a
        fleet-width caller would immediately re-concatenate (measurable
        at minutely detection width). Counts as one ``read_many`` (and
        one delta read with ``since=``) in telemetry."""
        from ..obs.trace import get_tracer
        tracer = get_tracer()
        if not tracer.enabled:
            return self._read_many_flat(ts_ids, start, end, since=since)
        with tracer.span("store.read_many", n=len(ts_ids),
                         delta=since is not None, flat=True):
            return self._read_many_flat(ts_ids, start, end, since=since)

    def _read_many_flat(self, ts_ids: Sequence[str],
                        start: Optional[float] = None,
                        end: Optional[float] = None, *,
                        since: Optional[float] = None
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        fast = since is not None
        if fast:
            start = since
        consolidate = not fast
        data_get = self._data.get
        no_end = end is None
        parts_t: List[np.ndarray] = []
        parts_v: List[np.ndarray] = []
        pt_append, pv_append = parts_t.append, parts_v.append
        sizes_l: List[int] = []
        sz_append = sizes_l.append
        with self._lock:
            self.read_many_count += 1
            if fast:
                self.delta_read_count += 1
            for i in ts_ids:
                s = data_get(i)
                if fast and s is not None and s.count \
                        and len(s.segments) == 1 and not s.tail_n:
                    seg = s.segments[0]
                    st = seg.times
                    lo = st.searchsorted(start)
                    hi = seg.n if no_end else st.searchsorted(end)
                    if hi > lo:
                        sz_append(hi - lo)
                        pt_append(st[lo:hi])
                        pv_append(seg.values[lo:hi])
                    else:
                        sz_append(0)
                    continue
                t, v = self._read_locked(s, start, end, consolidate)
                sz_append(t.size)
                if t.size:
                    pt_append(t)
                    pv_append(v)
        sizes = np.asarray(sizes_l, np.int64)
        if parts_t:
            return sizes, np.concatenate(parts_t), np.concatenate(parts_v)
        return sizes, _EMPTY, _EMPTY

    def read_window_batch(self, ts_ids: Sequence[str],
                          start: Optional[float] = None,
                          end: Optional[float] = None
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Fleet windowing helper: padded ``(N, T)`` matrices + validity mask.

        Rows are left-aligned and zero-padded to the longest series in the
        window; ``mask[i, j]`` is True where ``times[i, j]``/``values[i, j]``
        hold real points. Ready to feed vmapped per-series kernels.
        """
        series = self.read_many(ts_ids, start, end)
        n = len(series)
        width = max((t.size for t, _ in series), default=0)
        times = np.zeros((n, width), np.float64)
        values = np.zeros((n, width), np.float64)
        mask = np.zeros((n, width), bool)
        for i, (t, v) in enumerate(series):
            times[i, :t.size] = t
            values[i, :t.size] = v
            mask[i, :t.size] = True
        return times, values, mask

    def last_time(self, ts_id: str) -> Optional[float]:
        with self._lock:                # metadata is written under the lock
            s = self._data.get(ts_id)
            return s.t_max if s is not None and s.count else None

    def first_time(self, ts_id: str) -> Optional[float]:
        with self._lock:
            s = self._data.get(ts_id)
            return s.t_min if s is not None and s.count else None

    def ids(self) -> List[str]:
        with self._lock:
            return list(self._data)

    def length(self, ts_id: str) -> int:
        with self._lock:
            s = self._data.get(ts_id)
            return s.count if s else 0

    def total_points(self) -> int:
        with self._lock:
            return sum(s.count for s in self._data.values())

    def stats(self) -> dict:
        with self._lock:
            return {
                "series": len(self._data),
                "points": sum(s.count for s in self._data.values()),
                "segments": sum(len(s.segments) for s in self._data.values()),
                "tail_points": sum(s.tail_n for s in self._data.values()),
                "appends": self.append_count,
                "reads": self.read_count,
                "read_many": self.read_many_count,
                "delta_reads": self.delta_read_count,
                "compactions": self.compaction_count,
                "merges": self.merge_count,
                "merged_points": self.merged_points,
            }

    # ---------------- persistence ----------------
    def save(self, path: str):
        p = Path(path)
        p.mkdir(parents=True, exist_ok=True)
        arrays = {}
        with self._lock:
            for ts_id, s in self._data.items():
                self._consolidate(s)
                seg = s.segments[0] if s.segments else None
                arrays[f"t::{ts_id}"] = seg.times if seg else _EMPTY
                arrays[f"v::{ts_id}"] = seg.values if seg else _EMPTY
        np.savez_compressed(p / "timeseries.npz", **arrays)

    @classmethod
    def load(cls, path: str) -> "TimeSeriesStore":
        st = cls()
        f = Path(path) / "timeseries.npz"
        if f.exists():
            z = np.load(f)
            ids = {k[3:] for k in z.files if k.startswith("t::")}
            for ts_id in ids:
                st.append(ts_id, z[f"t::{ts_id}"], z[f"v::{ts_id}"])
            st.compact()
        return st
