"""Append-only knowledge-backed time-series store.

Semantics match the paper's store: ingestion is append-only (irregular,
possibly out-of-order timestamps allowed), reads return time-sorted views,
nothing is ever overwritten. Persistence is newline-JSON + NPZ so a real
backend (the paper used a relational DB) could be swapped behind the same
interface.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


@dataclass
class _Series:
    times: List[np.ndarray] = field(default_factory=list)
    values: List[np.ndarray] = field(default_factory=list)
    count: int = 0


class TimeSeriesStore:
    def __init__(self):
        self._data: Dict[str, _Series] = {}
        self._lock = threading.Lock()
        self.append_count = 0          # ingestion telemetry (Fig. 2 benchmark)

    # ---------------- write path ----------------
    def append(self, ts_id: str, times, values) -> int:
        times = np.asarray(times, np.float64).ravel()
        values = np.asarray(values, np.float64).ravel()
        assert times.shape == values.shape, (times.shape, values.shape)
        with self._lock:
            s = self._data.setdefault(ts_id, _Series())
            s.times.append(times)
            s.values.append(values)
            s.count += times.size
            self.append_count += times.size
        return times.size

    # ---------------- read path ----------------
    def read(self, ts_id: str, start: Optional[float] = None,
             end: Optional[float] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Time-sorted view of [start, end)."""
        s = self._data.get(ts_id)
        if s is None or not s.times:
            return np.empty(0), np.empty(0)
        t = np.concatenate(s.times)
        v = np.concatenate(s.values)
        order = np.argsort(t, kind="stable")
        t, v = t[order], v[order]
        lo = np.searchsorted(t, start) if start is not None else 0
        hi = np.searchsorted(t, end) if end is not None else t.size
        return t[lo:hi], v[lo:hi]

    def last_time(self, ts_id: str) -> Optional[float]:
        t, _ = self.read(ts_id)
        return float(t[-1]) if t.size else None

    def ids(self) -> List[str]:
        return list(self._data)

    def length(self, ts_id: str) -> int:
        s = self._data.get(ts_id)
        return s.count if s else 0

    def total_points(self) -> int:
        return sum(s.count for s in self._data.values())

    # ---------------- persistence ----------------
    def save(self, path: str):
        p = Path(path)
        p.mkdir(parents=True, exist_ok=True)
        arrays = {}
        for ts_id, s in self._data.items():
            t, v = self.read(ts_id)
            arrays[f"t::{ts_id}"] = t
            arrays[f"v::{ts_id}"] = v
        np.savez_compressed(p / "timeseries.npz", **arrays)

    @classmethod
    def load(cls, path: str) -> "TimeSeriesStore":
        st = cls()
        f = Path(path) / "timeseries.npz"
        if f.exists():
            z = np.load(f)
            ids = {k[3:] for k in z.files if k.startswith("t::")}
            for ts_id in ids:
                st.append(ts_id, z[f"t::{ts_id}"], z[f"v::{ts_id}"])
        return st
