"""LSTM forecaster (paper §4.2): 2 stacked LSTM layers over the last 24
hourly target values, sigmoid-scaled output, Adam(1e-3). Paper hidden 512;
``hidden`` user param keeps CPU runs fast. Fleet = vmapped training."""
from __future__ import annotations

from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .base import ForecastModelBase
from .features import FeatureSpec, bucket_n, edge_pad, note_trace

N_LAYERS = 2


def _init(key, width):
    params = {}
    in_dim = 1
    for l in range(N_LAYERS):
        key, k1, k2 = jax.random.split(key, 3)
        params[f"wx{l}"] = jax.random.normal(k1, (in_dim, 4 * width)) \
            * jnp.sqrt(1.0 / max(in_dim, 1))
        params[f"wh{l}"] = jax.random.normal(k2, (width, 4 * width)) \
            * jnp.sqrt(1.0 / width)
        params[f"b{l}"] = jnp.zeros((4 * width,))
        in_dim = width
    key, k = jax.random.split(key)
    params["wo"] = jax.random.normal(k, (width, 1)) * jnp.sqrt(1.0 / width)
    params["bo"] = jnp.zeros((1,))
    return params


def _lstm_layer(params, l, xs):
    """xs: (T, B, D) -> (T, B, W)."""
    W = params[f"wh{l}"].shape[0]
    B = xs.shape[1]

    def step(carry, x):
        h, c = carry
        z = x @ params[f"wx{l}"] + h @ params[f"wh{l}"] + params[f"b{l}"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((B, W))
    (_, _), hs = jax.lax.scan(step, (h0, h0), xs)
    return hs


def _lstm_out(params, seqs, y_scale):
    """seqs: (B, T) normalised target window -> (B,) prediction."""
    xs = seqs.T[:, :, None]                       # (T, B, 1)
    for l in range(N_LAYERS):
        xs = _lstm_layer(params, l, xs)
    h_last = xs[-1]                               # (B, W)
    raw = (h_last @ params["wo"] + params["bo"])[:, 0]
    return jax.nn.sigmoid(raw) * y_scale


def _loss(params, seqs, y, y_scale):
    return jnp.mean(jnp.square(_lstm_out(params, seqs, y_scale) - y))


@partial(jax.jit, static_argnames=("epochs", "width", "lr"))
def _fit_jax(key, seqs, y, y_scale, *, epochs: int, width: int, lr: float):
    note_trace("lstm_fit")           # Python body runs only while tracing
    params = _init(key, width)

    def step(carry, i):
        params, mu, nu = carry
        g = jax.grad(_loss)(params, seqs, y, y_scale)
        t = i + 1
        mu = jax.tree_util.tree_map(lambda m, gg: 0.9 * m + 0.1 * gg, mu, g)
        nu = jax.tree_util.tree_map(lambda v, gg: 0.999 * v + 0.001 * gg * gg, nu, g)
        def upd(p, m, v):
            mh = m / (1 - 0.9 ** t)
            vh = v / (1 - 0.999 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + 1e-8)
        params = jax.tree_util.tree_map(upd, params, mu, nu)
        return (params, mu, nu), None

    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    (params, _, _), _ = jax.lax.scan(step, (params, z, z),
                                     jnp.arange(epochs, dtype=jnp.float32))
    return params


class LSTMForecaster(ForecastModelBase):
    """Sequence model: features are the raw 24-lag window (Table 1)."""
    KIND = "LSTM"
    SUPPORTS_FLEET = True
    DEFAULTS = {**ForecastModelBase.DEFAULTS,
                "hidden": 32, "epochs": 200, "lr": 1e-3,
                "target_lags": 24, "use_weather": False, "use_calendar": False}

    def _hp(self):
        up = {**self.DEFAULTS, **self.user_params}
        return int(up["hidden"]), int(up["epochs"]), float(up["lr"])

    def _fit(self, X, y, rng):
        # X rows are standardized [lag1..lag24]; reverse to time order
        width, epochs, lr = self._hp()
        seqs = jnp.asarray(X[:, ::-1], jnp.float32)
        ys = float(np.abs(y).max() * 1.2 + 1e-6)
        key = jax.random.PRNGKey(int(rng.integers(2**31)))
        params = _fit_jax(key, seqs, jnp.asarray(y, jnp.float32), ys,
                          epochs=epochs, width=width, lr=lr)
        return {**{k: np.asarray(v) for k, v in params.items()}, "y_scale": ys}

    def _predict(self, params, X):
        p = {k: jnp.asarray(v) for k, v in params.items() if k != "y_scale"}
        X = np.asarray(X)
        single = X.ndim == 1
        X = np.atleast_2d(X)
        out = _lstm_out(p, jnp.asarray(X[:, ::-1], jnp.float32),
                        params["y_scale"])
        out = np.asarray(out)
        return out[0] if single else out

    @classmethod
    def _fleet_fit(cls, X, y, rng, up, mesh=None):
        # bin-shared user_params, NOT redeclared defaults (fleet == local)
        width = int(up["hidden"])
        epochs, lr = int(up["epochs"]), float(up["lr"])
        N = X.shape[0]
        # keys at the TRUE bin size, then bucket-padded (see ann.py)
        keys = jax.random.split(jax.random.PRNGKey(int(rng.integers(2**31))), N)
        ys = np.abs(np.asarray(y)).max(axis=1) * 1.2 + 1e-6
        pad = bucket_n(N) - N
        fit = jax.vmap(lambda k, s, yy, sc: _fit_jax(
            k, s, yy, sc, epochs=epochs, width=width, lr=lr))
        if mesh is None:
            fit = jax.jit(fit)
        else:
            from ..distributed.sharding import fleet_sharded
            fit = fleet_sharded(fit, mesh,
                                key=("lstm_fit", epochs, width, lr))
        params = fit(edge_pad(keys, pad),
                     edge_pad(jnp.asarray(X, jnp.float32)[:, :, ::-1], pad),
                     edge_pad(jnp.asarray(y, jnp.float32), pad),
                     edge_pad(jnp.asarray(ys, jnp.float32), pad))
        return {**{k: v[:N] for k, v in params.items()}, "y_scale": ys}

    @classmethod
    def _fleet_predict(cls, stacked, X):
        out = cls._fleet_predict_traced(
            stacked, jnp.asarray(np.asarray(X), jnp.float32))
        return np.asarray(out)

    @classmethod
    def _fleet_window_predict(cls, model_objects, X):
        # whole training window per instance in one vmapped forward pass:
        # rows become the LSTM batch axis, lags reversed to time order
        p = {k: jnp.asarray(np.stack([m["params"][k] for m in model_objects]),
                            jnp.float32)
             for k in model_objects[0]["params"] if k != "y_scale"}
        ys = jnp.asarray([m["params"]["y_scale"] for m in model_objects],
                         jnp.float32)
        seqs = jnp.asarray(np.asarray(X)[:, :, ::-1], jnp.float32)
        out = jax.vmap(_lstm_out)(p, seqs, ys)
        return np.asarray(out, np.float64)

    @classmethod
    def _fleet_predict_traced(cls, stacked, x):
        p = {k: jnp.asarray(v, jnp.float32) for k, v in stacked.items()
             if k != "y_scale"}
        seqs = x[:, ::-1]                    # lag order -> time order
        return jax.vmap(lambda pp, xx, sc: _lstm_out(pp, xx[None], sc)[0])(
            p, seqs, jnp.asarray(stacked["y_scale"], jnp.float32))

    @classmethod
    def _device_predict_factory(cls, spec, statics):
        return cls._fleet_predict_traced
