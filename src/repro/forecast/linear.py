"""LR forecaster (paper Table 1): ridge regression on weather + lag +
calendar features. Closed-form fit; fleet path is a vmapped solve."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .base import ForecastModelBase
from .features import bucket_n, edge_pad, note_trace


def _ridge_fit(X, y, lam=1e-2):
    Xb = jnp.concatenate([X, jnp.ones(X.shape[:-1] + (1,))], -1)
    A = Xb.T @ Xb + lam * jnp.eye(Xb.shape[-1])
    b = Xb.T @ y
    return jnp.linalg.solve(A, b)


def _ridge_fit_counted(X, y, lam=1e-2):
    # shared by LR and GAM (single + fleet), hence the neutral name
    note_trace("ridge_fit")          # Python body runs only while tracing
    return _ridge_fit(X, y, lam)


_ridge_fit_j = jax.jit(_ridge_fit_counted)
_ridge_fit_fleet = jax.jit(jax.vmap(_ridge_fit_counted, in_axes=(0, 0, None)),
                           static_argnums=())


def _ridge_fleet(X, y, lam=1e-2, mesh=None):
    """Vmapped per-instance ridge solve; with ``mesh`` the instance axis is
    shard_map-partitioned (one sharded dispatch, no collectives). Shared by
    the LR and GAM fleet fits.

    The instance axis is padded up to its power-of-two bucket (edge
    replication, pad lanes sliced off the solution) so nearby bin sizes
    share ONE compilation — the vmapped solve is per-lane independent, so
    real lanes are unaffected."""
    X, y = jnp.asarray(X), jnp.asarray(y)
    n = X.shape[0]
    pad = bucket_n(n) - n
    X, y = edge_pad(X, pad), edge_pad(y, pad)
    if mesh is None:
        return _ridge_fit_fleet(X, y, lam)[:n]
    from ..distributed.sharding import fleet_sharded
    fit = fleet_sharded(lambda xx, yy: jax.vmap(_ridge_fit_counted,
                                                (0, 0, None))(xx, yy, lam),
                        mesh, key=("ridge_fleet", lam))
    return fit(X, y)[:n]


class LinearForecaster(ForecastModelBase):
    KIND = "LR"
    SUPPORTS_FLEET = True

    def _fit(self, X, y, rng):
        theta = np.asarray(_ridge_fit_j(jnp.asarray(X), jnp.asarray(y)))
        return {"theta": theta}

    def _predict(self, params, X):
        th = params["theta"]
        return np.asarray(X) @ th[:-1] + th[-1]

    @classmethod
    def _fleet_fit(cls, X, y, rng, up, mesh=None):
        # stays device-resident: base.fleet_train converts ONCE for
        # persistence and hands the device copy to the runtime for scoring
        return {"theta": _ridge_fleet(jnp.asarray(X), jnp.asarray(y),
                                      1e-2, mesh=mesh)}

    @classmethod
    def _fleet_predict(cls, stacked, X):
        th = stacked["theta"]                        # (N, F+1)
        return np.einsum("nf,nf->n", np.asarray(X), th[:, :-1]) + th[:, -1]

    @classmethod
    def _fleet_window_predict(cls, model_objects, X):
        # (N, T, F) design against per-instance theta in one einsum
        th = np.stack([m["params"]["theta"] for m in model_objects])
        return (np.einsum("ntf,nf->nt", np.asarray(X), th[:, :-1])
                + th[:, -1][:, None])

    @classmethod
    def _fleet_predict_traced(cls, stacked, x):
        th = jnp.asarray(stacked["theta"], jnp.float32)
        return jnp.einsum("nf,nf->n", x, th[:, :-1]) + th[:, -1]

    @classmethod
    def _device_predict_factory(cls, spec, statics):
        return cls._fleet_predict_traced
