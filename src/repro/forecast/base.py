"""Shared load/transform/score plumbing for the paper's four forecasters.

Each concrete model supplies:
    _fit(X, y, rng) -> params-dict          (train on standardized features)
    _predict(params, X) -> yhat             (one-step prediction)
and optionally the fleet hooks (stacked across instances).

user_params (Listing 2): train_window_days, horizon, frequency, target_lags,
weather_lags, plus model-specific extras (hidden, epochs, lr, ...).
"""
from __future__ import annotations

from typing import Any, List, Tuple

import numpy as np

from ..core.registry import ModelInterface
from ..timeseries.transforms import DAY, HOUR
from .features import (FeatureSpec, design_matrix, hourly_series,
                       recursive_forecast)


class ForecastModelBase(ModelInterface):
    DEFAULTS = {"train_window_days": 28, "horizon": 24}

    # ------------- paper 4-function workflow -------------
    def load(self):
        up = {**self.DEFAULTS, **self.user_params}
        spec = FeatureSpec.from_params(up)
        now = float(up.get("now", self.user_params.get("now", 0.0)))
        t1 = now
        t0 = t1 - float(up["train_window_days"]) * DAY
        ctx = self.context
        times, target = hourly_series(self.system, ctx, t0, t1, spec.step)
        ent = ctx.entity
        temps = self.system.weather.forecast(ent.lat, ent.lon, t0, times) \
            if spec.use_weather else np.zeros_like(times)
        self._loaded = (spec, times, target, temps, now)
        return self._loaded

    def transform(self):
        spec, times, target, temps, now = self._loaded
        X, y = design_matrix(spec, times, target, temps)
        mu, sd = X.mean(0), X.std(0) + 1e-8
        self._xy = ((X - mu) / sd, y, mu, sd)
        return self._xy

    def train(self) -> dict:
        self.load()
        X, y, mu, sd = self.transform()
        import zlib                      # stable across processes (hash() is salted)
        rng = np.random.default_rng(zlib.crc32(self.model_id.encode()))
        params = self._fit(X, y, rng)
        return {"kind": self.KIND, "params": params, "mu": mu, "sd": sd,
                "y_scale": float(np.abs(y).max() + 1e-6)}

    def score(self, model_object) -> Tuple[np.ndarray, np.ndarray]:
        self.load()
        spec, times, target, temps, now = self._loaded
        up = {**self.DEFAULTS, **self.user_params}
        H = int(up["horizon"])
        warm = max(spec.target_lags, spec.weather_lags) + 1
        ent = self.context.entity
        # history grid ends at now-step; the first unknown interval is AT now
        fut_t = now + spec.step * np.arange(0, H)
        temps_future = self.system.weather.forecast(ent.lat, ent.lon, now, fut_t)
        mu, sd = model_object["mu"], model_object["sd"]

        def predict(x):
            return self._predict(model_object["params"], (x - mu) / sd)

        vals = recursive_forecast(predict, spec, target[-warm:], temps[-warm:],
                                  temps_future, now, H)
        return fut_t, vals

    # ------------- fleet plumbing (stacked across instances) -------------
    @classmethod
    def _fleet_xy(cls, instances) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        Xs, ys, mus, sds = [], [], [], []
        for inst in instances:
            inst.load()
            X, y, mu, sd = inst.transform()
            Xs.append(X), ys.append(y), mus.append(mu), sds.append(sd)
        return (np.stack(Xs), np.stack(ys), np.stack(mus), np.stack(sds))

    @classmethod
    def fleet_train(cls, instances: List[ModelInterface]):
        X, y, mu, sd = cls._fleet_xy(instances)
        rng = np.random.default_rng(12345)
        params = cls._fleet_fit(X, y, rng)              # stacked params
        out = []
        for i, inst in enumerate(instances):
            pi = {k: np.asarray(v[i]) for k, v in params.items()}
            out.append({"kind": cls.KIND, "params": pi, "mu": mu[i],
                        "sd": sd[i], "y_scale": float(np.abs(y[i]).max() + 1e-6)})
        return out

    @classmethod
    def fleet_score(cls, instances: List[ModelInterface], model_objects):
        spec = None
        y_hists, temp_hists, temps_futs, fut_ts = [], [], [], []
        H = None
        for inst in instances:
            inst.load()
            spec, times, target, temps, now = inst._loaded
            up = {**cls.DEFAULTS, **inst.user_params}
            H = int(up["horizon"])
            warm = max(spec.target_lags, spec.weather_lags) + 1
            ent = inst.context.entity
            fut_t = now + spec.step * np.arange(0, H)
            temps_futs.append(inst.system.weather.forecast(ent.lat, ent.lon, now, fut_t))
            y_hists.append(target[-warm:])
            temp_hists.append(temps[-warm:])
            fut_ts.append(fut_t)
        mu = np.stack([m["mu"] for m in model_objects])
        sd = np.stack([m["sd"] for m in model_objects])
        stacked = {k: np.stack([m["params"][k] for m in model_objects])
                   for k in model_objects[0]["params"]}

        def predict(x):                                  # x: (N, F)
            return cls._fleet_predict(stacked, (x - mu) / sd)

        t_start = fut_ts[0][0]
        vals = recursive_forecast(predict, spec, np.stack(y_hists),
                                  np.stack(temp_hists), np.stack(temps_futs),
                                  t_start, H)
        return [(fut_ts[i], vals[i]) for i in range(len(instances))]
