"""Shared load/transform/score plumbing for the paper's four forecasters.

Each concrete model supplies:
    _fit(X, y, rng) -> params-dict          (train on standardized features)
    _predict(params, X) -> yhat             (one-step prediction)
and optionally the fleet hooks (stacked across instances).

user_params (Listing 2): train_window_days, horizon, frequency, target_lags,
weather_lags, plus model-specific extras (hidden, epochs, lr, ...).
"""
from __future__ import annotations

from typing import Any, List, Tuple

import numpy as np

from ..core.registry import ModelInterface
from ..timeseries.transforms import DAY, HOUR
from .features import (FeatureSpec, design_matrix, fleet_hourly_series,
                       recursive_forecast)


class ForecastModelBase(ModelInterface):
    DEFAULTS = {"train_window_days": 28, "horizon": 24}

    # ------------- paper 4-function workflow -------------
    def load(self):
        """Single-instance case of ``fleet_load``: one shared pipeline is
        what makes LocalPool and Fleet execution structurally equivalent."""
        self.fleet_load([self])
        return self._loaded

    def transform(self):
        spec, times, target, temps, now = self._loaded
        X, y = design_matrix(spec, times, target, temps)
        mu, sd = X.mean(0), X.std(0) + 1e-8
        self._xy = ((X - mu) / sd, y, mu, sd)
        return self._xy

    def train(self) -> dict:
        self.load()
        X, y, mu, sd = self.transform()
        import zlib                      # stable across processes (hash() is salted)
        rng = np.random.default_rng(zlib.crc32(self.model_id.encode()))
        params = self._fit(X, y, rng)
        return {"kind": self.KIND, "params": params, "mu": mu, "sd": sd,
                "y_scale": float(np.abs(y).max() + 1e-6)}

    def score(self, model_object) -> Tuple[np.ndarray, np.ndarray]:
        self.load()
        spec, times, target, temps, now = self._loaded
        up = {**self.DEFAULTS, **self.user_params}
        H = int(up["horizon"])
        warm = max(spec.target_lags, spec.weather_lags) + 1
        ent = self.context.entity
        # history grid ends at now-step; the first unknown interval is AT now
        fut_t = now + spec.step * np.arange(0, H)
        temps_future = self.system.weather.forecast(ent.lat, ent.lon, now, fut_t)
        mu, sd = model_object["mu"], model_object["sd"]

        def predict(x):
            return self._predict(model_object["params"], (x - mu) / sd)

        vals = recursive_forecast(predict, spec, target[-warm:], temps[-warm:],
                                  temps_future, now, H)
        return fut_t, vals

    # ------------- fleet plumbing (stacked across instances) -------------
    @classmethod
    def fleet_load(cls, instances: List[ModelInterface]) -> None:
        """Batched ``load()`` for a fleet bin: ONE ``store.read_many`` per
        shared (window, step) group instead of one ``read()`` per instance.

        Jobs in a bin share user_params and ``now``, so normally this is a
        single group — the whole bin's history arrives in one store call.
        Sets each instance's ``_loaded`` to exactly what ``load()`` would,
        keeping LocalPool and Fleet observationally equivalent.
        """
        groups: dict = {}
        for inst in instances:
            up = {**cls.DEFAULTS, **inst.user_params}
            spec = FeatureSpec.from_params(up)
            now = float(up.get("now", 0.0))
            t0 = now - float(up["train_window_days"]) * DAY
            groups.setdefault((t0, now, spec.step), []).append(
                (inst, spec, now))
        for (t0, t1, step), members in groups.items():
            ctxs = [m[0].context for m in members]
            grid, targets = fleet_hourly_series(
                members[0][0].system, ctxs, t0, t1, step)
            for (inst, spec, now), target in zip(members, targets):
                ent = inst.context.entity
                temps = inst.system.weather.forecast(
                    ent.lat, ent.lon, t0, grid) if spec.use_weather \
                    else np.zeros_like(grid)
                inst._loaded = (spec, grid, target, temps, now)

    @classmethod
    def _require_one_window(cls, instances) -> None:
        """Batched *scoring* rolls one recursive forecast with a single
        shared time axis, so a bin mixing execution times ('now') would
        silently compute wrong calendar features for all but the first
        instance — fail loudly instead. Training is per-instance after
        stacking and tolerates mixed windows, so only fleet_score guards.
        (Scheduler polls stamp every job in a cycle with the same time, so
        this only trips when jobs from different polls are mixed into one
        run.)"""
        nows = {inst._loaded[4] for inst in instances}
        if len(nows) > 1:
            raise RuntimeError(
                f"fleet bin mixes execution times {sorted(nows)[:3]}...; "
                "run each poll's jobs separately")

    @classmethod
    def _fleet_xy(cls, instances) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        cls.fleet_load(instances)
        Xs, ys, mus, sds = [], [], [], []
        for inst in instances:
            X, y, mu, sd = inst.transform()
            Xs.append(X), ys.append(y), mus.append(mu), sds.append(sd)
        return (np.stack(Xs), np.stack(ys), np.stack(mus), np.stack(sds))

    @classmethod
    def fleet_train(cls, instances: List[ModelInterface]):
        X, y, mu, sd = cls._fleet_xy(instances)
        rng = np.random.default_rng(12345)
        params = cls._fleet_fit(X, y, rng)              # stacked params
        out = []
        for i, inst in enumerate(instances):
            pi = {k: np.asarray(v[i]) for k, v in params.items()}
            out.append({"kind": cls.KIND, "params": pi, "mu": mu[i],
                        "sd": sd[i], "y_scale": float(np.abs(y[i]).max() + 1e-6)})
        return out

    @classmethod
    def fleet_score(cls, instances: List[ModelInterface], model_objects):
        cls.fleet_load(instances)
        cls._require_one_window(instances)
        spec = None
        y_hists, temp_hists, temps_futs, fut_ts = [], [], [], []
        H = None
        for inst in instances:
            spec, times, target, temps, now = inst._loaded
            up = {**cls.DEFAULTS, **inst.user_params}
            H = int(up["horizon"])
            warm = max(spec.target_lags, spec.weather_lags) + 1
            ent = inst.context.entity
            fut_t = now + spec.step * np.arange(0, H)
            temps_futs.append(inst.system.weather.forecast(ent.lat, ent.lon, now, fut_t))
            y_hists.append(target[-warm:])
            temp_hists.append(temps[-warm:])
            fut_ts.append(fut_t)
        mu = np.stack([m["mu"] for m in model_objects])
        sd = np.stack([m["sd"] for m in model_objects])
        stacked = {k: np.stack([m["params"][k] for m in model_objects])
                   for k in model_objects[0]["params"]}

        def predict(x):                                  # x: (N, F)
            return cls._fleet_predict(stacked, (x - mu) / sd)

        t_start = fut_ts[0][0]
        vals = recursive_forecast(predict, spec, np.stack(y_hists),
                                  np.stack(temp_hists), np.stack(temps_futs),
                                  t_start, H)
        return [(fut_ts[i], vals[i]) for i in range(len(instances))]
