"""Shared load/transform/score plumbing for the paper's four forecasters.

Each concrete model supplies:
    _fit(X, y, rng) -> params-dict          (train on standardized features)
    _predict(params, X) -> yhat             (one-step prediction)
and optionally the fleet hooks (stacked across instances).

user_params (Listing 2): train_window_days, horizon, frequency, target_lags,
weather_lags, plus model-specific extras (hidden, epochs, lr, ...).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.registry import ModelInterface
from ..timeseries.transforms import DAY, HOUR, calendar_phases
from .features import (FeatureSpec, bucket_n, design_matrix, edge_pad,
                       fleet_hourly_series, make_device_rollout,
                       recursive_forecast)


class _LRUCache:
    """Bounded LRU for compiled program caches, with hit/miss counters.

    The rollout cache used to grow without limit across (class, spec,
    horizon, statics, mesh) configurations — a long-lived server cycling
    through many deployment configs would pin every compilation forever.
    Eviction drops our reference; jax's own executable cache is keyed by
    the function object, so the next use of an evicted config recompiles.
    """

    def __init__(self, cap: int = 32):
        self.cap = int(cap)
        self._d: "OrderedDict[tuple, Callable]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        fn = self._d.get(key)
        if fn is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return fn

    def put(self, key, fn):
        self._d[key] = fn
        self._d.move_to_end(key)
        while len(self._d) > self.cap:
            self._d.popitem(last=False)
        return fn

    def __len__(self):
        return len(self._d)

    def stats(self) -> dict:
        return {"size": len(self._d), "cap": self.cap,
                "hits": self.hits, "misses": self.misses}


#: compiled whole-horizon rollouts, keyed by
#: (model class, FeatureSpec, horizon, class-specific statics, mesh) — one
#: trace per configuration, reused across every score bin of that shape
#: bucket. mesh=None is the single-device jit; a fleet mesh gets its own
#: sharded compilation (jax Mesh objects hash by devices+axes). LRU-bounded
#: (see _LRUCache); hit/miss counters surface per bin via
#: ``FleetExecutor.last_bin_stats``.
_ROLLOUT_CACHE = _LRUCache(cap=32)


def rollout_cache_stats() -> dict:
    return _ROLLOUT_CACHE.stats()


#: prediction-interval quantiles (lower, upper) for every forecaster's
#: residual band — q10..q90, the band the detection flow compares against
BAND_QUANTILES = (0.1, 0.9)


def prediction_bands(model_object, values):
    """(lower, upper) quantile bands around a rolled-out point forecast.

    Bands come from the TRAINING residual quantiles persisted in the model
    object (``resid_q``, one-step-ahead errors), widened by sqrt(h+1) per
    horizon step — the standard recursive-forecast error growth heuristic:
    step 0 is the raw one-step band, later steps widen as accumulated
    prediction error compounds. Works per instance (``resid_q`` shape
    ``(2,)``, values ``(H,)``) and per fleet bin (``(N, 2)`` / ``(N, H)``).
    Returns ``(None, None)`` for model objects without residual quantiles
    (third-party implementations, versions trained before bands existed) —
    callers persist band-less forecasts rather than failing.
    """
    rq = model_object.get("resid_q") if isinstance(model_object, dict) \
        else None
    if rq is None:
        return None, None
    values = np.asarray(values, np.float64)
    rq = np.asarray(rq, np.float64)
    widen = np.sqrt(1.0 + np.arange(values.shape[-1], dtype=np.float64))
    return (values + rq[..., 0, None] * widen,
            values + rq[..., 1, None] * widen)


class ForecastModelBase(ModelInterface):
    DEFAULTS = {"train_window_days": 28, "horizon": 24}
    #: the fleet hooks accept a ``runtime=`` kwarg (FleetRuntime): the
    #: executor only threads its runtime through classes advertising this,
    #: so third-party SUPPORTS_FLEET implementations with the old
    #: signature keep working
    SUPPORTS_RUNTIME = True

    # ------------- paper 4-function workflow -------------
    def load(self):
        """Single-instance case of ``fleet_load``: one shared pipeline is
        what makes LocalPool and Fleet execution structurally equivalent."""
        self.fleet_load([self])
        return self._loaded

    def transform(self):
        spec, times, target, temps, now = self._loaded
        X, y = design_matrix(spec, times, target, temps)
        mu, sd = X.mean(0), X.std(0) + 1e-8
        self._xy = ((X - mu) / sd, y, mu, sd)
        return self._xy

    def train(self) -> dict:
        self.load()
        X, y, mu, sd = self.transform()
        import zlib                      # stable across processes (hash() is salted)
        rng = np.random.default_rng(zlib.crc32(self.model_id.encode()))
        params = self._fit(X, y, rng)
        # one-step residuals over the training window feed the q10/q90
        # prediction band persisted with every forecast (X is standardized)
        resid = y - np.asarray(self._predict(params, X), np.float64)
        return {"kind": self.KIND, "params": params, "mu": mu, "sd": sd,
                "y_scale": float(np.abs(y).max() + 1e-6),
                "resid_q": np.quantile(resid, BAND_QUANTILES)}

    def score(self, model_object):
        self.load()
        spec, times, target, temps, now = self._loaded
        up = {**self.DEFAULTS, **self.user_params}
        H = int(up["horizon"])
        warm = max(spec.target_lags, spec.weather_lags) + 1
        ent = self.context.entity
        # history grid ends at now-step; the first unknown interval is AT now
        fut_t = now + spec.step * np.arange(0, H)
        temps_future = self.system.weather.forecast(ent.lat, ent.lon, now, fut_t)
        mu, sd = model_object["mu"], model_object["sd"]

        def predict(x):
            return self._predict(model_object["params"], (x - mu) / sd)

        vals = recursive_forecast(predict, spec, target[-warm:], temps[-warm:],
                                  temps_future, now, H)
        lower, upper = prediction_bands(model_object, vals)
        return fut_t, vals, lower, upper

    # ------------- fleet plumbing (stacked across instances) -------------
    @classmethod
    def fleet_load(cls, instances: List[ModelInterface]) -> None:
        """Batched ``load()`` for a fleet bin: ONE ``store.read_many`` per
        shared (window, step) group instead of one ``read()`` per instance.

        Jobs in a bin share user_params and ``now``, so normally this is a
        single group — the whole bin's history arrives in one store call.
        Sets each instance's ``_loaded`` to exactly what ``load()`` would,
        keeping LocalPool and Fleet observationally equivalent.
        """
        groups: dict = {}
        for inst in instances:
            up = {**cls.DEFAULTS, **inst.user_params}
            spec = FeatureSpec.from_params(up)
            now = float(up.get("now", 0.0))
            t0 = now - float(up["train_window_days"]) * DAY
            groups.setdefault((t0, now, spec.step), []).append(
                (inst, spec, now))
        for (t0, t1, step), members in groups.items():
            ctxs = [m[0].context for m in members]
            system = members[0][0].system
            grid, targets = fleet_hourly_series(system, ctxs, t0, t1, step)
            # ONE vectorized weather call per bin group, not O(N) python
            # calls on the hot path (temperature_many rows are bitwise the
            # per-instance calls, so nothing downstream can tell). History
            # weather is the OBSERVED temperature (paper §4.2 trains on
            # observed weather); only the scoring horizon uses forecasts,
            # issued at scoring time. Observed history is also what makes
            # the steady-state runtime O(delta): a forecast issued at the
            # sliding window start would change EVERY value each poll.
            widx = [i for i, m in enumerate(members) if m[1].use_weather]
            if widx:
                ents = [members[i][0].context.entity for i in widx]
                wtemps = system.weather.temperature_many(
                    [e.lat for e in ents], [e.lon for e in ents], grid)
            temps_rows: Dict[int, np.ndarray] = {
                i: wtemps[j] for j, i in enumerate(widx)}
            for i, ((inst, spec, now), target) in enumerate(
                    zip(members, targets)):
                temps = temps_rows.get(i)
                if temps is None:
                    temps = np.zeros_like(grid)
                inst._loaded = (spec, grid, target, temps, now)

    @classmethod
    def _require_one_window(cls, instances) -> None:
        """Batched *scoring* rolls one recursive forecast with a single
        shared time axis, so a bin mixing execution times ('now') would
        silently compute wrong calendar features for all but the first
        instance — fail loudly instead. Training is per-instance after
        stacking and tolerates mixed windows, so only fleet_score guards.
        (Scheduler polls stamp every job in a cycle with the same time, so
        this only trips when jobs from different polls are mixed into one
        run.)"""
        nows = {inst._loaded[4] for inst in instances}
        if len(nows) > 1:
            raise RuntimeError(
                f"fleet bin mixes execution times {sorted(nows)[:3]}...; "
                "run each poll's jobs separately")

    @classmethod
    def _fleet_xy(cls, instances) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        cls.fleet_load(instances)
        Xs, ys, mus, sds = [], [], [], []
        for inst in instances:
            X, y, mu, sd = inst.transform()
            Xs.append(X), ys.append(y), mus.append(mu), sds.append(sd)
        return (np.stack(Xs), np.stack(ys), np.stack(mus), np.stack(sds))

    @classmethod
    def fleet_train(cls, instances: List[ModelInterface], *, mesh=None,
                    runtime=None):
        state = loaded = None
        if runtime is not None:
            loaded = runtime.fleet_xy(cls, instances)
        if loaded is None:               # cold / runtime opted out
            X, y, mu, sd = cls._fleet_xy(instances)
        else:                            # device-resident incremental path
            X, y, mu, sd, state = loaded
        rng = np.random.default_rng(12345)
        # jobs in a bin share user_params_key, so the first instance's
        # merged params speak for the whole bin (hardcoding defaults here
        # is the fleet/local divergence bug this signature prevents)
        up = {**cls.DEFAULTS, **instances[0].user_params}
        params = cls._fleet_fit(X, y, rng, up, mesh=mesh)   # stacked params
        # ONE host transfer per parameter (persistence needs numpy); the
        # train->score handoff below keeps the stacked DEVICE params so a
        # same-poll score bin never re-uploads what training just computed
        host = {k: np.asarray(v) for k, v in params.items()}
        mu_h, sd_h = np.asarray(mu), np.asarray(sd)
        ymax = np.asarray(np.abs(np.asarray(y)).max(axis=1))
        out = []
        yhat = cls._fleet_window_predict(
            [{"params": {k: v[i] for k, v in host.items()}}
             for i in range(len(instances))], np.asarray(X, np.float64))
        resid = np.asarray(y, np.float64) - np.asarray(yhat, np.float64)
        rq = np.quantile(resid, BAND_QUANTILES, axis=1).T      # (N, 2)
        for i, inst in enumerate(instances):
            pi = {k: v[i] for k, v in host.items()}
            out.append({"kind": cls.KIND, "params": pi, "mu": mu_h[i],
                        "sd": sd_h[i], "y_scale": float(ymax[i] + 1e-6),
                        "resid_q": rq[i]})
        if state is not None:
            runtime.note_trained(state, params, mu, sd, out)
        return out

    @classmethod
    def _fleet_window_predict(cls, model_objects, X: np.ndarray) -> np.ndarray:
        """One-step predictions over each instance's full standardized
        training design: ``X (N, T, F) -> (N, T)``. Feeds the per-instance
        training-residual quantiles behind prediction bands. The default
        loops instances through ``_predict`` (none of the built-in
        predictors touch ``self``); each forecaster overrides with a
        batched path."""
        return np.stack([
            np.asarray(cls._predict(cls, m["params"], X[i]), np.float64)
            for i, m in enumerate(model_objects)])

    @classmethod
    def _attach_bands(cls, model_objects, results):
        """Zip per-instance quantile bands onto ``(times, values)`` fleet
        results — shared by the device-runtime and cold scoring paths so
        both return the same 4-tuple shape."""
        return [(t, v, *prediction_bands(m, v))
                for m, (t, v) in zip(model_objects, results)]

    @classmethod
    def fleet_score(cls, instances: List[ModelInterface], model_objects, *,
                    mesh=None, runtime=None):
        if runtime is not None:
            res = runtime.fleet_score(cls, instances, model_objects,
                                      mesh=mesh)
            if res is not None:
                return cls._attach_bands(model_objects, res)
        cls.fleet_load(instances)
        cls._require_one_window(instances)
        # jobs in a bin share user_params_key: one merge speaks for all
        up = {**cls.DEFAULTS, **instances[0].user_params}
        H = int(up["horizon"])
        spec = None
        y_hists, temp_hists, fut_ts = [], [], []
        for inst in instances:
            spec, times, target, temps, now = inst._loaded
            warm = max(spec.target_lags, spec.weather_lags) + 1
            fut_t = now + spec.step * np.arange(0, H)
            y_hists.append(target[-warm:])
            temp_hists.append(temps[-warm:])
            fut_ts.append(fut_t)
        # one vectorized weather call per bin (bitwise == per-instance)
        ents = [inst.context.entity for inst in instances]
        temps_futs = instances[0].system.weather.forecast_many(
            [e.lat for e in ents], [e.lon for e in ents],
            instances[0]._loaded[4], fut_ts[0])
        mu = np.stack([m["mu"] for m in model_objects])
        sd = np.stack([m["sd"] for m in model_objects])
        stacked = {k: np.stack([m["params"][k] for m in model_objects])
                   for k in model_objects[0]["params"]}
        t_start = fut_ts[0][0]
        y_hist = np.stack(y_hists)
        temp_hist = np.stack(temp_hists)
        temps_fut = np.stack(temps_futs)

        vals = None
        if up.get("rollout", "device") != "host":
            vals = cls._device_rollout(spec, up, stacked, mu, sd, y_hist,
                                       temp_hist, temps_fut, t_start, H,
                                       mesh=mesh)
        if vals is None:                 # reference path / no device hook
            def predict(x):                              # x: (N, F)
                return cls._fleet_predict(stacked, (x - mu) / sd)

            vals = recursive_forecast(predict, spec, y_hist, temp_hist,
                                      temps_fut, t_start, H)
        return cls._attach_bands(
            model_objects, [(fut_ts[i], vals[i]) for i in range(len(instances))])

    # ------------- device-resident scoring rollout -------------
    @classmethod
    def _rollout_statics(cls, up: dict, stacked: dict) -> tuple:
        """Hashable per-class trace statics derived from the bin's shared
        user_params / stacked model params (e.g. GAM's spline column
        indices). Part of the compiled-rollout cache key."""
        return ()

    @classmethod
    def _device_predict_factory(cls, spec: FeatureSpec,
                                statics: tuple) -> Optional[Callable]:
        """Return a traceable ``(stacked_params, x) -> (N,)`` one-step
        predictor, or None to keep scoring on the numpy reference path
        (``recursive_forecast``)."""
        return None

    @classmethod
    def _device_rollout(cls, spec: FeatureSpec, up: dict, stacked, mu, sd,
                        y_hist, temp_hist, temps_future, t_start: float,
                        H: int, mesh=None) -> Optional[np.ndarray]:
        """Score a whole bin with ONE device program (jitted lax.scan over
        the horizon) instead of H host-loop steps; with ``mesh`` the bin's
        instance axis is shard_map-partitioned across the mesh's devices
        (still one dispatch). Returns None when the model has no traceable
        predictor — callers then fall back to the numpy reference path,
        preserving the executor equivalence contract for models that
        cannot run device-resident."""
        import jax.numpy as jnp
        statics = cls._rollout_statics(up, stacked)
        key = (cls, spec, H, statics, mesh)
        fn = _ROLLOUT_CACHE.get(key)
        if fn is None:
            predict = cls._device_predict_factory(spec, statics)
            if predict is None:
                return None
            fn = _ROLLOUT_CACHE.put(
                key, make_device_rollout(predict, spec, H, mesh=mesh))
        tl, wl = spec.target_lags, spec.weather_lags
        f32 = jnp.float32
        y0 = jnp.asarray(y_hist, f32)[..., -tl:]
        if spec.use_weather:
            tw0 = jnp.asarray(temp_hist, f32)[..., -(wl + 1):]
        else:                            # unused carry, keep it minimal
            tw0 = jnp.zeros(y0.shape[:-1] + (1,), f32)
        hod, dow = calendar_phases(t_start + spec.step * np.arange(H))
        # shape-bucketed dispatch: pad the instance axis to its bucket so
        # nearby bin sizes hit ONE compilation (per-instance recursion =>
        # padded lanes cannot perturb real ones); slice the pad back off
        n = y0.shape[0] if y0.ndim > 1 else 0
        pad = bucket_n(n) - n if n else 0
        stacked = {k: edge_pad(jnp.asarray(v), pad) for k, v in stacked.items()}
        args = [edge_pad(jnp.asarray(a, f32), pad)
                for a in (mu, sd, y0, tw0, temps_future)]
        out = fn(stacked, *args, jnp.asarray(hod, f32), jnp.asarray(dow, f32))
        out = np.asarray(out, np.float64)
        return out[:n] if n else out
