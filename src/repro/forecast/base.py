"""Shared load/transform/score plumbing for the paper's four forecasters.

Each concrete model supplies:
    _fit(X, y, rng) -> params-dict          (train on standardized features)
    _predict(params, X) -> yhat             (one-step prediction)
and optionally the fleet hooks (stacked across instances).

user_params (Listing 2): train_window_days, horizon, frequency, target_lags,
weather_lags, plus model-specific extras (hidden, epochs, lr, ...).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.registry import ModelInterface
from ..timeseries.transforms import DAY, HOUR, calendar_phases
from .features import (FeatureSpec, design_matrix, fleet_hourly_series,
                       make_device_rollout, recursive_forecast)

#: compiled whole-horizon rollouts, keyed by
#: (model class, FeatureSpec, horizon, class-specific statics, mesh) — one
#: trace per configuration, reused across every score bin of that shape.
#: mesh=None is the single-device jit; a fleet mesh gets its own sharded
#: compilation (jax Mesh objects hash by devices+axes).
_ROLLOUT_CACHE: Dict[tuple, Callable] = {}


class ForecastModelBase(ModelInterface):
    DEFAULTS = {"train_window_days": 28, "horizon": 24}

    # ------------- paper 4-function workflow -------------
    def load(self):
        """Single-instance case of ``fleet_load``: one shared pipeline is
        what makes LocalPool and Fleet execution structurally equivalent."""
        self.fleet_load([self])
        return self._loaded

    def transform(self):
        spec, times, target, temps, now = self._loaded
        X, y = design_matrix(spec, times, target, temps)
        mu, sd = X.mean(0), X.std(0) + 1e-8
        self._xy = ((X - mu) / sd, y, mu, sd)
        return self._xy

    def train(self) -> dict:
        self.load()
        X, y, mu, sd = self.transform()
        import zlib                      # stable across processes (hash() is salted)
        rng = np.random.default_rng(zlib.crc32(self.model_id.encode()))
        params = self._fit(X, y, rng)
        return {"kind": self.KIND, "params": params, "mu": mu, "sd": sd,
                "y_scale": float(np.abs(y).max() + 1e-6)}

    def score(self, model_object) -> Tuple[np.ndarray, np.ndarray]:
        self.load()
        spec, times, target, temps, now = self._loaded
        up = {**self.DEFAULTS, **self.user_params}
        H = int(up["horizon"])
        warm = max(spec.target_lags, spec.weather_lags) + 1
        ent = self.context.entity
        # history grid ends at now-step; the first unknown interval is AT now
        fut_t = now + spec.step * np.arange(0, H)
        temps_future = self.system.weather.forecast(ent.lat, ent.lon, now, fut_t)
        mu, sd = model_object["mu"], model_object["sd"]

        def predict(x):
            return self._predict(model_object["params"], (x - mu) / sd)

        vals = recursive_forecast(predict, spec, target[-warm:], temps[-warm:],
                                  temps_future, now, H)
        return fut_t, vals

    # ------------- fleet plumbing (stacked across instances) -------------
    @classmethod
    def fleet_load(cls, instances: List[ModelInterface]) -> None:
        """Batched ``load()`` for a fleet bin: ONE ``store.read_many`` per
        shared (window, step) group instead of one ``read()`` per instance.

        Jobs in a bin share user_params and ``now``, so normally this is a
        single group — the whole bin's history arrives in one store call.
        Sets each instance's ``_loaded`` to exactly what ``load()`` would,
        keeping LocalPool and Fleet observationally equivalent.
        """
        groups: dict = {}
        for inst in instances:
            up = {**cls.DEFAULTS, **inst.user_params}
            spec = FeatureSpec.from_params(up)
            now = float(up.get("now", 0.0))
            t0 = now - float(up["train_window_days"]) * DAY
            groups.setdefault((t0, now, spec.step), []).append(
                (inst, spec, now))
        for (t0, t1, step), members in groups.items():
            ctxs = [m[0].context for m in members]
            grid, targets = fleet_hourly_series(
                members[0][0].system, ctxs, t0, t1, step)
            for (inst, spec, now), target in zip(members, targets):
                ent = inst.context.entity
                temps = inst.system.weather.forecast(
                    ent.lat, ent.lon, t0, grid) if spec.use_weather \
                    else np.zeros_like(grid)
                inst._loaded = (spec, grid, target, temps, now)

    @classmethod
    def _require_one_window(cls, instances) -> None:
        """Batched *scoring* rolls one recursive forecast with a single
        shared time axis, so a bin mixing execution times ('now') would
        silently compute wrong calendar features for all but the first
        instance — fail loudly instead. Training is per-instance after
        stacking and tolerates mixed windows, so only fleet_score guards.
        (Scheduler polls stamp every job in a cycle with the same time, so
        this only trips when jobs from different polls are mixed into one
        run.)"""
        nows = {inst._loaded[4] for inst in instances}
        if len(nows) > 1:
            raise RuntimeError(
                f"fleet bin mixes execution times {sorted(nows)[:3]}...; "
                "run each poll's jobs separately")

    @classmethod
    def _fleet_xy(cls, instances) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        cls.fleet_load(instances)
        Xs, ys, mus, sds = [], [], [], []
        for inst in instances:
            X, y, mu, sd = inst.transform()
            Xs.append(X), ys.append(y), mus.append(mu), sds.append(sd)
        return (np.stack(Xs), np.stack(ys), np.stack(mus), np.stack(sds))

    @classmethod
    def fleet_train(cls, instances: List[ModelInterface], *, mesh=None):
        X, y, mu, sd = cls._fleet_xy(instances)
        rng = np.random.default_rng(12345)
        # jobs in a bin share user_params_key, so the first instance's
        # merged params speak for the whole bin (hardcoding defaults here
        # is the fleet/local divergence bug this signature prevents)
        up = {**cls.DEFAULTS, **instances[0].user_params}
        params = cls._fleet_fit(X, y, rng, up, mesh=mesh)   # stacked params
        out = []
        for i, inst in enumerate(instances):
            pi = {k: np.asarray(v[i]) for k, v in params.items()}
            out.append({"kind": cls.KIND, "params": pi, "mu": mu[i],
                        "sd": sd[i], "y_scale": float(np.abs(y[i]).max() + 1e-6)})
        return out

    @classmethod
    def fleet_score(cls, instances: List[ModelInterface], model_objects, *,
                    mesh=None):
        cls.fleet_load(instances)
        cls._require_one_window(instances)
        # jobs in a bin share user_params_key: one merge speaks for all
        up = {**cls.DEFAULTS, **instances[0].user_params}
        H = int(up["horizon"])
        spec = None
        y_hists, temp_hists, temps_futs, fut_ts = [], [], [], []
        for inst in instances:
            spec, times, target, temps, now = inst._loaded
            warm = max(spec.target_lags, spec.weather_lags) + 1
            ent = inst.context.entity
            fut_t = now + spec.step * np.arange(0, H)
            temps_futs.append(inst.system.weather.forecast(ent.lat, ent.lon, now, fut_t))
            y_hists.append(target[-warm:])
            temp_hists.append(temps[-warm:])
            fut_ts.append(fut_t)
        mu = np.stack([m["mu"] for m in model_objects])
        sd = np.stack([m["sd"] for m in model_objects])
        stacked = {k: np.stack([m["params"][k] for m in model_objects])
                   for k in model_objects[0]["params"]}
        t_start = fut_ts[0][0]
        y_hist = np.stack(y_hists)
        temp_hist = np.stack(temp_hists)
        temps_fut = np.stack(temps_futs)

        vals = None
        if up.get("rollout", "device") != "host":
            vals = cls._device_rollout(spec, up, stacked, mu, sd, y_hist,
                                       temp_hist, temps_fut, t_start, H,
                                       mesh=mesh)
        if vals is None:                 # reference path / no device hook
            def predict(x):                              # x: (N, F)
                return cls._fleet_predict(stacked, (x - mu) / sd)

            vals = recursive_forecast(predict, spec, y_hist, temp_hist,
                                      temps_fut, t_start, H)
        return [(fut_ts[i], vals[i]) for i in range(len(instances))]

    # ------------- device-resident scoring rollout -------------
    @classmethod
    def _rollout_statics(cls, up: dict, stacked: dict) -> tuple:
        """Hashable per-class trace statics derived from the bin's shared
        user_params / stacked model params (e.g. GAM's spline column
        indices). Part of the compiled-rollout cache key."""
        return ()

    @classmethod
    def _device_predict_factory(cls, spec: FeatureSpec,
                                statics: tuple) -> Optional[Callable]:
        """Return a traceable ``(stacked_params, x) -> (N,)`` one-step
        predictor, or None to keep scoring on the numpy reference path
        (``recursive_forecast``)."""
        return None

    @classmethod
    def _device_rollout(cls, spec: FeatureSpec, up: dict, stacked, mu, sd,
                        y_hist, temp_hist, temps_future, t_start: float,
                        H: int, mesh=None) -> Optional[np.ndarray]:
        """Score a whole bin with ONE device program (jitted lax.scan over
        the horizon) instead of H host-loop steps; with ``mesh`` the bin's
        instance axis is shard_map-partitioned across the mesh's devices
        (still one dispatch). Returns None when the model has no traceable
        predictor — callers then fall back to the numpy reference path,
        preserving the executor equivalence contract for models that
        cannot run device-resident."""
        statics = cls._rollout_statics(up, stacked)
        key = (cls, spec, H, statics, mesh)
        fn = _ROLLOUT_CACHE.get(key)
        if fn is None:
            predict = cls._device_predict_factory(spec, statics)
            if predict is None:
                return None
            fn = _ROLLOUT_CACHE.setdefault(
                key, make_device_rollout(predict, spec, H, mesh=mesh))
        tl, wl = spec.target_lags, spec.weather_lags
        f32 = np.float32
        y0 = np.asarray(y_hist, f32)[..., -tl:]
        if spec.use_weather:
            tw0 = np.asarray(temp_hist, f32)[..., -(wl + 1):]
        else:                            # unused carry, keep it minimal
            tw0 = np.zeros(y0.shape[:-1] + (1,), f32)
        hod, dow = calendar_phases(t_start + spec.step * np.arange(H))
        out = fn(stacked, np.asarray(mu, f32), np.asarray(sd, f32), y0, tw0,
                 np.asarray(temps_future, f32),
                 np.asarray(hod, f32), np.asarray(dow, f32))
        return np.asarray(out, np.float64)
