"""Band-compare anomaly scorer — the minutely detection flow's model.

A ``DetectionDeployment`` (repro.flows.detection) schedules ``detect``
occurrences at minutely cadence. Each occurrence reads the live values
of the monitored context over a short lookback window and compares them
against the q10/q90 prediction band of the forecast a live poller would
have had at that boundary (the band is resolved by the executor with
``predictions.latest(signal, entity, at=scheduled_at)`` — the same
replay-faithful ``at=`` semantics model versions use).

The occurrence's anomaly score is the worst normalized band exceedance
over the window::

    exceed(v) = max(lower(t) - v, v - upper(t), 0) / max(upper - lower, eps)

0.0 means every reading sat inside the band; 1.0 means a reading escaped
the band by one full band-width. Readings whose timestamps fall outside
the band's horizon count as *band misses* (telemetry, not anomalies).

Fleet execution is the point: ``fleet_detect`` scores a whole bin with
ONE ``store.read_many`` and one vectorized compare over the flattened
(sensor, reading) axis — no per-sensor Python loop. The per-sensor
``detect`` path computes bitwise-identical scores (same float64
elementwise operations), which ``benchmarks/bench_detection.py`` and
``tests/test_flows.py`` pin.
"""
from __future__ import annotations

from typing import List

import numpy as np

from ..core.registry import ModelInterface
from ..flows.detection import DetectionRecord
from ..obs.metrics import note_retrace

#: floor on band width when normalizing exceedance (degenerate bands)
EPS = 1e-9


def _band_grid(fc) -> tuple:
    """(t0, step, H) of a banded forecast's horizon grid."""
    t0 = float(fc.times[0])
    step = float(fc.times[1] - fc.times[0]) if len(fc.times) > 1 else 1.0
    return t0, step, len(fc.times)


#: band-pack memo: stacked (t0s, steps, Hs, L, U) per bin's band list.
#: Forecasts are frozen and a minutely bin re-resolves the SAME bands
#: until the next scoring boundary, so the stacks are rebuilt only when
#: the band set actually changes. Keyed by the forecasts' ids; the value
#: holds the bands tuple itself, which pins those ids live. Tiny cap —
#: one entry per concurrently-detecting bin is all steady state needs.
_BAND_PACKS: dict = {}
_BAND_PACKS_MAX = 8


def _band_pack(bands):
    """(t0s, steps, Hs, L, U, mvs) stacks for a bin's bands; L/U are None
    for ragged horizons (the caller gathers per sensor instead)."""
    key = tuple(map(id, bands))
    hit = _BAND_PACKS.get(key)
    if hit is not None:
        return hit[1]
    # the detection path's retrace analogue: a rebuild means the bin's
    # band set changed (new scoring boundary), counted like a jit retrace
    note_retrace("band_pack")
    grids = [_band_grid(fc) for fc in bands]
    t0s = np.asarray([g[0] for g in grids])
    steps = np.asarray([g[1] for g in grids])
    Hs = np.asarray([g[2] for g in grids], np.int64)
    if len(set(Hs.tolist())) == 1:
        L = np.stack([np.asarray(fc.lower, np.float64) for fc in bands])
        U = np.stack([np.asarray(fc.upper, np.float64) for fc in bands])
    else:
        L = U = None
    pack = (t0s, steps, Hs, L, U, [fc.model_version for fc in bands])
    if len(_BAND_PACKS) >= _BAND_PACKS_MAX:
        _BAND_PACKS.pop(next(iter(_BAND_PACKS)))
    _BAND_PACKS[key] = (tuple(bands), pack)
    return pack


def _exceedances(rv, lo, hi):
    """Normalized band exceedance per reading (float64, elementwise —
    the single-sensor and fleet paths share these exact operations)."""
    width = np.maximum(hi - lo, EPS)
    return np.maximum(np.maximum(lo - rv, rv - hi), 0.0) / width


class BandAnomalyDetector(ModelInterface):
    """Model-free detection: the "model" is the banded forecast itself."""

    KIND = "ANOM"
    SUPPORTS_FLEET = True
    SUPPORTS_RUNTIME = False
    DEFAULTS = {"lookback": 60.0}

    # ------------- 4-function interface (detect flow) -------------
    def load(self):
        up = {**self.DEFAULTS, **self.user_params}
        now = float(up.get("now", 0.0))
        # half-open [now - lookback, now): exactly the readings that
        # arrived since the previous minutely occurrence
        self._raw = self.system.store.read(
            self.context.ts_id, now - float(up["lookback"]), now)
        self._now = now
        return self._raw

    def transform(self):
        return self._raw

    def train(self):
        # nothing to fit — banded forecasts come from the forecast flow
        return {"kind": self.KIND}

    def score(self, model_object):
        raise RuntimeError(
            "detection deployments schedule 'detect', not 'score'")

    # ------------- detection -------------
    def _derived_signal(self) -> str:
        up = {**self.DEFAULTS, **self.user_params}
        return str(up.get("derived_signal",
                          f"{self.context.signal.name}.anomaly"))

    def detect(self, fc) -> DetectionRecord:
        """Per-sensor reference path (LocalPoolExecutor): one ``read()``
        and one compare for this sensor's window."""
        self.load()
        rt, rv = (np.asarray(self._raw[0], np.float64),
                  np.asarray(self._raw[1], np.float64))
        t0, step, H = _band_grid(fc)
        idx = np.floor((rt - t0) / step + 0.5).astype(np.int64)
        ok = (idx >= 0) & (idx < H)
        ex = _exceedances(rv[ok], np.asarray(fc.lower, np.float64)[idx[ok]],
                          np.asarray(fc.upper, np.float64)[idx[ok]])
        score = float(ex.max()) if ex.size else 0.0
        return DetectionRecord(
            deployment_name=self.model_id,
            signal=self.context.signal.name,
            entity=self.context.entity.name,
            scheduled_at=self._now, score=score,
            n_readings=int(rt.size),
            n_anomalies=int(np.count_nonzero(ex > 0.0)),
            band_misses=int(np.count_nonzero(~ok)),
            model_version=fc.model_version,
            derived_signal=self._derived_signal())

    @classmethod
    def fleet_detect(cls, instances: List["BandAnomalyDetector"],
                     bands, now=None, ts_ids=None,
                     names=None) -> List[DetectionRecord]:
        """Whole-bin detection: ONE ``store.read_many`` for every sensor's
        window, then one vectorized compare over the flattened (sensor,
        reading) axis. Scores are bitwise-identical to the per-sensor
        ``detect`` path (same float64 elementwise ops; the segment max is
        order-independent). ``now`` defaults to the bin's
        ``user_params["now"]``, ``ts_ids`` to the instances' context
        series and ``names`` to per-instance ``(model_ids, signals,
        entities)`` columns (kept as fallbacks so direct callers need no
        executor); the fleet executor passes all three explicitly because
        its cached bin instances outlive any single boundary and the
        name columns hold until the deployment set changes."""
        n = len(instances)
        up = {**cls.DEFAULTS, **instances[0].user_params}
        if now is None:
            now = float(up.get("now", 0.0))
        t0w = now - float(up["lookback"])
        system = instances[0].system
        # since= window read: the steady-state delta fast path (two binary
        # searches per consolidated series), flattened in the store — the
        # vectorized compare wants one concatenated axis anyway
        if ts_ids is None:
            ts_ids = [inst.context.ts_id for inst in instances]
        sizes, rt, rv = system.store.read_many_flat(ts_ids, end=now,
                                                    since=t0w)
        sidx = np.repeat(np.arange(n, dtype=np.int64), sizes)
        t0s, steps, Hs, L, U, mvs = _band_pack(bands)
        idx = np.floor((rt - t0s[sidx]) / steps[sidx] + 0.5).astype(np.int64)
        ok = (idx >= 0) & (idx < Hs[sidx])
        if L is not None:
            lo, hi = L[sidx[ok], idx[ok]], U[sidx[ok], idx[ok]]
        else:                  # ragged horizons: gather per sensor (rare)
            lo = np.asarray([bands[s].lower[i]
                             for s, i in zip(sidx[ok], idx[ok])], np.float64)
            hi = np.asarray([bands[s].upper[i]
                             for s, i in zip(sidx[ok], idx[ok])], np.float64)
        ex = _exceedances(rv[ok], lo, hi)
        scores = np.zeros(n, np.float64)
        np.maximum.at(scores, sidx[ok], ex)
        anom = np.bincount(sidx[ok][ex > 0.0], minlength=n)
        miss = np.bincount(sidx[~ok], minlength=n)
        # one C-loop materialization per column, then pure-python record
        # assembly — per-element float()/int() coercions were measurable
        # at fleet width
        scores_l, sizes_l = scores.tolist(), sizes.tolist()
        anom_l, miss_l = anom.tolist(), miss.tolist()
        if names is None:
            mids = [inst.model_id for inst in instances]
            sigs = [inst.context.signal.name for inst in instances]
            ents = [inst.context.entity.name for inst in instances]
        else:
            mids, sigs, ents = names
        derived = up.get("derived_signal")
        derived_l = [str(derived)] * n if derived is not None \
            else [s + ".anomaly" for s in sigs]
        # frozen-dataclass __init__ routes every field through
        # object.__setattr__; at fleet width that alone was ~7% of a
        # minutely bin, so records are built by installing the field dict
        # directly (__eq__/asdict/attribute reads are unaffected)
        new = DetectionRecord.__new__
        out = []
        for i in range(n):
            rec = new(DetectionRecord)
            rec.__dict__.update({
                "deployment_name": mids[i], "signal": sigs[i],
                "entity": ents[i],
                "scheduled_at": now, "score": scores_l[i],
                "n_readings": sizes_l[i], "n_anomalies": anom_l[i],
                "band_misses": miss_l[i], "model_version": mvs[i],
                "derived_signal": derived_l[i]})
            out.append(rec)
        return out
