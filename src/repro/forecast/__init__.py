from .base import ForecastModelBase  # noqa: F401
from .linear import LinearForecaster  # noqa: F401
from .gam import GAMForecaster  # noqa: F401
from .ann import ANNForecaster  # noqa: F401
from .lstm import LSTMForecaster  # noqa: F401
from .transform_models import EnergyFromCurrentModel  # noqa: F401

PAPER_MODELS = {"LR": LinearForecaster, "GAM": GAMForecaster,
                "ANN": ANNForecaster, "LSTM": LSTMForecaster}
