"""ANN forecaster (paper §4.2): MLP with 4 hidden ReLU layers and a sigmoid
output, Adam(1e-3). Paper width 512; default here is user-configurable
(``hidden``) so CPU tests stay fast. Fleet training = one jitted program with
vmapped per-instance Adam; fleet scoring = the fleet_mlp kernel (per-instance
weights megabatch — the paper's serving hot-spot)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.fleet_mlp.ops import fleet_mlp
from .base import ForecastModelBase
from .features import bucket_n, edge_pad, note_trace

N_HIDDEN_LAYERS = 4


def _init(key, f_in, width):
    sizes = [f_in] + [width] * N_HIDDEN_LAYERS + [1]
    ws, bs = [], []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        ws.append(jax.random.normal(k, (sizes[i], sizes[i + 1]), jnp.float32)
                  * jnp.sqrt(2.0 / sizes[i]))
        bs.append(jnp.zeros((sizes[i + 1],), jnp.float32))
    return {"w": ws, "b": bs}


def _mlp_raw(params, X):
    h = X
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        h = h @ w + b
        if i < n - 1:
            h = jax.nn.relu(h)
    return h[..., 0]


def _mlp_out(params, X, y_scale):
    return jax.nn.sigmoid(_mlp_raw(params, X)) * y_scale


def _loss(params, X, y, y_scale):
    return jnp.mean(jnp.square(_mlp_out(params, X, y_scale) - y))


@partial(jax.jit, static_argnames=("epochs", "width", "lr"))
def _fit_jax(key, X, y, y_scale, *, epochs: int, width: int, lr: float):
    note_trace("ann_fit")            # Python body runs only while tracing
    params = _init(key, X.shape[-1], width)
    opt = jax.tree_util.tree_map(lambda p: (jnp.zeros_like(p),) * 2, params)

    def step(carry, i):
        params, mu, nu = carry
        g = jax.grad(_loss)(params, X, y, y_scale)
        t = i + 1
        mu = jax.tree_util.tree_map(lambda m, gg: 0.9 * m + 0.1 * gg, mu, g)
        nu = jax.tree_util.tree_map(lambda v, gg: 0.999 * v + 0.001 * gg * gg, nu, g)
        def upd(p, m, v):
            mh = m / (1 - 0.9 ** t)
            vh = v / (1 - 0.999 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + 1e-8)
        params = jax.tree_util.tree_map(upd, params, mu, nu)
        return (params, mu, nu), None

    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    (params, _, _), _ = jax.lax.scan(step, (params, z, z),
                                     jnp.arange(epochs, dtype=jnp.float32))
    return params


def _fit_fleet_vmapped(keys, X, y, ys, epochs, width, lr):
    """Per-instance Adam, vmapped over the bin. Kept un-jitted so the mesh
    path can shard_map it; the single-device path jits it below."""
    return jax.vmap(lambda k, xx, yy, sc: _fit_jax(
        k, xx, yy, sc, epochs=epochs, width=width, lr=lr))(keys, X, y, ys)


_fit_fleet = jax.jit(_fit_fleet_vmapped,
                     static_argnames=("epochs", "width", "lr"))


class ANNForecaster(ForecastModelBase):
    KIND = "ANN"
    SUPPORTS_FLEET = True
    DEFAULTS = {**ForecastModelBase.DEFAULTS,
                "hidden": 64, "epochs": 300, "lr": 1e-3,
                "target_lags": 48, "weather_lags": 0}

    def _hp(self):
        up = {**self.DEFAULTS, **self.user_params}
        return int(up["hidden"]), int(up["epochs"]), float(up["lr"])

    def _fit(self, X, y, rng):
        width, epochs, lr = self._hp()
        key = jax.random.PRNGKey(int(rng.integers(2**31)))
        ys = float(np.abs(y).max() * 1.2 + 1e-6)
        params = _fit_jax(key, jnp.asarray(X, jnp.float32),
                          jnp.asarray(y, jnp.float32), ys,
                          epochs=epochs, width=width, lr=lr)
        # flat w0../b0.. layout, SAME as _fleet_fit: a version trained by
        # either executor must be scorable by either scoring path
        out = {f"w{i}": np.asarray(w) for i, w in enumerate(params["w"])}
        out.update({f"b{i}": np.asarray(b)
                    for i, b in enumerate(params["b"])})
        out["y_scale"] = ys
        return out

    def _predict(self, params, X):
        nl = N_HIDDEN_LAYERS + 1
        p = {"w": [jnp.asarray(params[f"w{i}"]) for i in range(nl)],
             "b": [jnp.asarray(params[f"b{i}"]) for i in range(nl)]}
        return np.asarray(_mlp_out(p, jnp.asarray(X, jnp.float32),
                                   params["y_scale"]))

    # ------------- fleet hooks -------------
    @classmethod
    def _fleet_fit(cls, X, y, rng, up, mesh=None):
        # bin-shared user_params, NOT redeclared defaults: a deployment with
        # hidden=128 must fleet-train the same width LocalPool would
        width = int(up["hidden"])
        epochs, lr = int(up["epochs"]), float(up["lr"])
        N = X.shape[0]
        # per-instance keys drawn at the TRUE bin size (bucket padding must
        # never shift which key a real instance trains with), then padded
        # to the size bucket so nearby bin sizes share one compilation
        keys = jax.random.split(jax.random.PRNGKey(int(rng.integers(2**31))), N)
        ys = np.abs(np.asarray(y)).max(axis=1) * 1.2 + 1e-6
        pad = bucket_n(N) - N
        if mesh is None:
            fit = partial(_fit_fleet, epochs=epochs, width=width, lr=lr)
        else:
            from ..distributed.sharding import fleet_sharded
            fit = fleet_sharded(
                partial(_fit_fleet_vmapped, epochs=epochs, width=width, lr=lr),
                mesh, key=("ann_fit", epochs, width, lr))
        params = fit(edge_pad(keys, pad),
                     edge_pad(jnp.asarray(X, jnp.float32), pad),
                     edge_pad(jnp.asarray(y, jnp.float32), pad),
                     edge_pad(jnp.asarray(ys, jnp.float32), pad))
        out = {}
        for i, w in enumerate(params["w"]):
            out[f"w{i}"] = w[:N]
            out[f"b{i}"] = params["b"][i][:N]
        out["y_scale"] = ys
        return out

    @classmethod
    def _fleet_predict(cls, stacked, X):
        y = cls._fleet_predict_traced(stacked, jnp.asarray(X, jnp.float32))
        return np.asarray(y)

    @classmethod
    def _fleet_window_predict(cls, model_objects, X):
        # full-window forward pass, vmapped over instances: (N, T, F) -> (N, T)
        nl = N_HIDDEN_LAYERS + 1
        p = {"w": [jnp.asarray(np.stack([m["params"][f"w{i}"]
                                         for m in model_objects]), jnp.float32)
                   for i in range(nl)],
             "b": [jnp.asarray(np.stack([m["params"][f"b{i}"]
                                         for m in model_objects]), jnp.float32)
                   for i in range(nl)]}
        ys = jnp.asarray([m["params"]["y_scale"] for m in model_objects],
                         jnp.float32)
        out = jax.vmap(_mlp_out)(p, jnp.asarray(X, jnp.float32), ys)
        return np.asarray(out, np.float64)

    @classmethod
    def _fleet_predict_traced(cls, stacked, x):
        """One megabatched fleet_mlp launch: per-instance weight stacks with
        a real leading batch dimension (the Pallas kernel's grid axis)."""
        nl = N_HIDDEN_LAYERS + 1
        ws = [jnp.asarray(stacked[f"w{i}"], jnp.float32) for i in range(nl)]
        bs = [jnp.asarray(stacked[f"b{i}"], jnp.float32) for i in range(nl)]
        raw = fleet_mlp(x[:, None, :], ws, bs)
        return jax.nn.sigmoid(raw[:, 0, 0]) \
            * jnp.asarray(stacked["y_scale"], jnp.float32)

    @classmethod
    def _device_predict_factory(cls, spec, statics):
        return cls._fleet_predict_traced
