"""Feature engineering per paper Table 1, expressed against semantic concepts:
the model code asks for (context.signal, context.entity) history and weather
at (entity.lat, entity.lon) — never for raw sensor ids.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from ..timeseries.transforms import (HOUR, align_resample, calendar_features,
                                     lagged_features, regular_grid)


@dataclass(frozen=True)
class FeatureSpec:
    target_lags: int = 24        # 1..L hourly lags of the target
    weather_lags: int = 24       # 1..Lw hourly lags of temperature
    use_weather: bool = True
    use_calendar: bool = True
    step: float = HOUR

    @property
    def n_features(self) -> int:
        n = self.target_lags
        if self.use_weather:
            n += 1 + self.weather_lags
        if self.use_calendar:
            n += 5
        return n

    @classmethod
    def from_params(cls, up: dict) -> "FeatureSpec":
        return cls(target_lags=int(up.get("target_lags", 24)),
                   weather_lags=int(up.get("weather_lags", 24)),
                   use_weather=bool(up.get("use_weather", True)),
                   use_calendar=bool(up.get("use_calendar", True)),
                   step=float(up.get("frequency", HOUR)))


def fleet_hourly_series(system, ctxs, t0: float, t1: float,
                        step: float) -> Tuple[np.ndarray, np.ndarray]:
    """Batched series loading: ONE ``store.read_many`` for a whole fleet
    bin, then per-series alignment onto the shared ``[t0, t1)`` grid.

    Returns ``(grid (T,), targets (N, T))``; rows align 1:1 with ``ctxs``.

    Missing-data policy (deliberate, see docs/ARCHITECTURE.md): a window
    with NO points yields an all-zero row, so the job succeeds with flat
    forecasts in both executors instead of crashing — one dead sensor
    must not poison a megabatched bin, and LocalPool must agree with
    Fleet. ``hourly_series`` is the single-context case of this function,
    so the solo and fleet paths cannot drift apart.
    """
    raw = system.store.read_many([c.ts_id for c in ctxs],
                                 t0 - step, t1 + step)
    grid = regular_grid(t0, t1, step)   # same binning rule as align_resample
    rows = []
    for t, v in raw:
        if t.size == 0:
            rows.append(np.zeros_like(grid))
            continue
        _, r = align_resample(t, v, step=step, start=t0, end=t1)
        rows.append(r)
    return grid, np.stack(rows) if rows else np.zeros((0, grid.size))


def hourly_series(system, ctx, t0: float, t1: float, step: float) -> Tuple[np.ndarray, np.ndarray]:
    grid, targets = fleet_hourly_series(system, [ctx], t0, t1, step)
    return grid, targets[0]


def design_matrix(spec: FeatureSpec, times, target, temps) -> Tuple[np.ndarray, np.ndarray]:
    """Rows t -> predict target[t] from lags/calendar/weather. Drops warmup."""
    cols = [lagged_features(target, range(1, spec.target_lags + 1))]
    if spec.use_weather:
        cols.append(temps[:, None])
        cols.append(lagged_features(temps, range(1, spec.weather_lags + 1)))
    if spec.use_calendar:
        cols.append(calendar_features(times))
    X = np.concatenate(cols, axis=1)
    warm = max(spec.target_lags, spec.weather_lags if spec.use_weather else 0)
    return X[warm:], np.asarray(target, np.float64)[warm:]


def step_features(spec: FeatureSpec, y_hist: np.ndarray, temp_hist: np.ndarray,
                  t_next: float) -> np.ndarray:
    """Feature row(s) for ONE next step given trailing history.
    y_hist/temp_hist: (..., >=lags) trailing windows (last element = t-1)."""
    tl, wl = spec.target_lags, spec.weather_lags
    cols = [y_hist[..., -1: -tl - 1: -1]]              # lag1..lagL
    if spec.use_weather:
        cols.append(temp_hist[..., -1:])               # temp at ~t (forecast)
        cols.append(temp_hist[..., -2: -wl - 2: -1])
    if spec.use_calendar:
        cal = calendar_features(np.asarray([t_next]))[0]
        cal = np.broadcast_to(cal, y_hist.shape[:-1] + (5,))
        cols.append(cal)
    return np.concatenate(cols, axis=-1)


def recursive_forecast(predict_fn, spec: FeatureSpec, y_hist, temp_hist,
                       temps_future, t_start: float, horizon: int):
    """Roll a one-step model forward ``horizon`` steps (recursive strategy).
    Vectorised over leading dims: y_hist (..., L), temps_future (..., H).
    predict_fn maps (..., F) -> (...,). Returns (..., H)."""
    y_hist = np.array(y_hist, np.float64)
    temp_hist = np.array(temp_hist, np.float64)
    preds = []
    for h in range(horizon):
        t_next = t_start + h * spec.step
        temp_hist = np.concatenate(
            [temp_hist, temps_future[..., h: h + 1]], axis=-1)
        x = step_features(spec, y_hist, temp_hist, t_next)
        yh = np.asarray(predict_fn(x), np.float64)
        preds.append(yh)
        y_hist = np.concatenate([y_hist, yh[..., None]], axis=-1)
    return np.stack(preds, axis=-1)
