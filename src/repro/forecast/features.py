"""Feature engineering per paper Table 1, expressed against semantic concepts:
the model code asks for (context.signal, context.entity) history and weather
at (entity.lat, entity.lon) — never for raw sensor ids.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from ..timeseries.transforms import (HOUR, align_resample, calendar_features,
                                     calendar_features_jnp, calendar_phases,
                                     lagged_features, regular_grid)


@dataclass(frozen=True)
class FeatureSpec:
    target_lags: int = 24        # 1..L hourly lags of the target
    weather_lags: int = 24       # 1..Lw hourly lags of temperature
    use_weather: bool = True
    use_calendar: bool = True
    step: float = HOUR

    @property
    def n_features(self) -> int:
        n = self.target_lags
        if self.use_weather:
            n += 1 + self.weather_lags
        if self.use_calendar:
            n += 5
        return n

    @classmethod
    def from_params(cls, up: dict) -> "FeatureSpec":
        return cls(target_lags=int(up.get("target_lags", 24)),
                   weather_lags=int(up.get("weather_lags", 24)),
                   use_weather=bool(up.get("use_weather", True)),
                   use_calendar=bool(up.get("use_calendar", True)),
                   step=float(up.get("frequency", HOUR)))


def fleet_hourly_series(system, ctxs, t0: float, t1: float,
                        step: float) -> Tuple[np.ndarray, np.ndarray]:
    """Batched series loading: ONE ``store.read_many`` for a whole fleet
    bin, then per-series alignment onto the shared ``[t0, t1)`` grid.

    Returns ``(grid (T,), targets (N, T))``; rows align 1:1 with ``ctxs``.

    Missing-data policy (deliberate, see docs/ARCHITECTURE.md): a window
    with NO points yields an all-zero row, so the job succeeds with flat
    forecasts in both executors instead of crashing — one dead sensor
    must not poison a megabatched bin, and LocalPool must agree with
    Fleet. ``hourly_series`` is the single-context case of this function,
    so the solo and fleet paths cannot drift apart.
    """
    raw = system.store.read_many([c.ts_id for c in ctxs],
                                 t0 - step, t1 + step)
    grid = regular_grid(t0, t1, step)   # same binning rule as align_resample
    rows = []
    for t, v in raw:
        if t.size == 0:
            rows.append(np.zeros_like(grid))
            continue
        _, r = align_resample(t, v, step=step, start=t0, end=t1)
        rows.append(r)
    return grid, np.stack(rows) if rows else np.zeros((0, grid.size))


def hourly_series(system, ctx, t0: float, t1: float, step: float) -> Tuple[np.ndarray, np.ndarray]:
    grid, targets = fleet_hourly_series(system, [ctx], t0, t1, step)
    return grid, targets[0]


def design_matrix(spec: FeatureSpec, times, target, temps) -> Tuple[np.ndarray, np.ndarray]:
    """Rows t -> predict target[t] from lags/calendar/weather. Drops warmup."""
    cols = [lagged_features(target, range(1, spec.target_lags + 1))]
    if spec.use_weather:
        cols.append(temps[:, None])
        cols.append(lagged_features(temps, range(1, spec.weather_lags + 1)))
    if spec.use_calendar:
        cols.append(calendar_features(times))
    X = np.concatenate(cols, axis=1)
    warm = max(spec.target_lags, spec.weather_lags if spec.use_weather else 0)
    return X[warm:], np.asarray(target, np.float64)[warm:]


def step_features(spec: FeatureSpec, y_hist: np.ndarray, temp_hist: np.ndarray,
                  t_next: float) -> np.ndarray:
    """Feature row(s) for ONE next step given trailing history.
    y_hist/temp_hist: (..., >=lags) trailing windows (last element = t-1)."""
    tl, wl = spec.target_lags, spec.weather_lags
    cols = [y_hist[..., -1: -tl - 1: -1]]              # lag1..lagL
    if spec.use_weather:
        cols.append(temp_hist[..., -1:])               # temp at ~t (forecast)
        cols.append(temp_hist[..., -2: -wl - 2: -1])
    if spec.use_calendar:
        cal = calendar_features(np.asarray([t_next]))[0]
        cal = np.broadcast_to(cal, y_hist.shape[:-1] + (5,))
        cols.append(cal)
    return np.concatenate(cols, axis=-1)


def recursive_forecast(predict_fn, spec: FeatureSpec, y_hist, temp_hist,
                       temps_future, t_start: float, horizon: int):
    """Roll a one-step model forward ``horizon`` steps (recursive strategy).
    Vectorised over leading dims: y_hist (..., L), temps_future (..., H).
    predict_fn maps (..., F) -> (...,). Returns (..., H).

    This is the host-side REFERENCE path: one predict_fn round-trip per
    step. The serving hot path is ``make_device_rollout``, which runs the
    identical recursion as a single jitted ``lax.scan`` on device;
    ``tests/test_fleet_rollout.py`` pins their agreement.
    """
    y_hist = np.array(y_hist, np.float64)
    temp_hist = np.array(temp_hist, np.float64)
    preds = []
    for h in range(horizon):
        t_next = t_start + h * spec.step
        temp_hist = np.concatenate(
            [temp_hist, temps_future[..., h: h + 1]], axis=-1)
        x = step_features(spec, y_hist, temp_hist, t_next)
        yh = np.asarray(predict_fn(x), np.float64)
        preds.append(yh)
        y_hist = np.concatenate([y_hist, yh[..., None]], axis=-1)
    return np.stack(preds, axis=-1)


def step_features_jnp(spec: FeatureSpec, y_win, t_win, cal_row):
    """jnp twin of ``step_features`` over FIXED-SIZE trailing windows (the
    scan carry): y_win (..., target_lags) with the most recent value last,
    t_win (..., weather_lags+1) already including the step's forecast temp
    at its end, cal_row (5,) precomputed calendar features for the step."""
    import jax.numpy as jnp
    wl = spec.weather_lags
    cols = [y_win[..., ::-1]]                          # lag1..lagL
    if spec.use_weather:
        cols.append(t_win[..., -1:])                   # temp at ~t (forecast)
        if wl:
            cols.append(t_win[..., -2: -wl - 2: -1])
    if spec.use_calendar:
        cols.append(jnp.broadcast_to(cal_row, y_win.shape[:-1] + (5,)))
    return jnp.concatenate(cols, axis=-1)


def make_device_rollout(predict_fn, spec: FeatureSpec, horizon: int,
                        mesh=None):
    """Device-resident whole-horizon rollout: ONE jitted program that runs
    the recursive-forecast recursion as a ``lax.scan`` over the horizon —
    lag-window update, calendar/weather feature assembly, per-instance
    standardization and prediction all stay on device. The host loop in
    ``recursive_forecast`` crosses host<->device 2x per step; this crosses
    once per score bin.

    With ``mesh`` (a 1-D fleet mesh from ``launch.mesh.make_fleet_mesh``)
    the instance axis N of every input/output is shard_map-partitioned
    across the mesh's devices — the recursion is per-instance independent,
    so the sharded program needs no collectives and still runs as one
    dispatch; hod/dow stay replicated. Uneven N is edge-padded to a shard
    multiple and the pad rows are sliced back off.

    predict_fn: traceable (stacked_params, x (N, F)) -> (N,) predictions
    (standardized features in, physical-unit predictions out).

    Returns jitted ``run(stacked, mu, sd, y0, tw0, temps_future, hod, dow)``
      stacked       pytree of per-instance model params, leading dim N
      mu, sd        (N, F) per-instance feature standardization
      y0            (N, target_lags) trailing target window, newest last
      tw0           (N, weather_lags+1) trailing temperature window
      temps_future  (N, H) weather forecasts for the horizon
      hod, dow      (H,) calendar phases (``calendar_phases`` of the
                    horizon timestamps — reduced on host, f32-safe)
    -> (N, H) predictions.
    """
    import jax
    import jax.numpy as jnp

    def run(stacked, mu, sd, y0, tw0, temps_future, hod, dow):
        cal = calendar_features_jnp(hod, dow)                    # (H, 5)
        xs = (jnp.moveaxis(temps_future, -1, 0), cal)

        def body(carry, inp):
            y_win, t_win = carry
            temp_next, cal_row = inp
            if spec.use_weather:
                t_win = jnp.concatenate(
                    [t_win[..., 1:], temp_next[..., None]], axis=-1)
            x = step_features_jnp(spec, y_win, t_win, cal_row)
            yh = predict_fn(stacked, (x - mu) / sd)
            y_win = jnp.concatenate([y_win[..., 1:], yh[..., None]], axis=-1)
            return (y_win, t_win), yh

        (_, _), preds = jax.lax.scan(body, (y0, tw0), xs, length=horizon)
        return jnp.moveaxis(preds, 0, -1)

    if mesh is None:
        return jax.jit(run)
    from ..distributed.sharding import fleet_sharded
    # hod/dow (args 6, 7) are the shared horizon calendar: replicated
    return fleet_sharded(run, mesh, replicated_argnums=(6, 7),
                         key=("rollout", predict_fn, spec, horizon))
