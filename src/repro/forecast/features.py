"""Feature engineering per paper Table 1, expressed against semantic concepts:
the model code asks for (context.signal, context.entity) history and weather
at (entity.lat, entity.lon) — never for raw sensor ids.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from ..obs.metrics import note_retrace
from ..timeseries.transforms import (HOUR, align_resample, calendar_features,
                                     calendar_features_jnp, calendar_phases,
                                     lagged_features, regular_grid)

# ---------------------------------------------------------------------------
# Trace accounting: every jitted hot-path program increments the counter in
# its PYTHON body, which only executes while jax traces (a compiled cache hit
# never re-enters Python). ``trace_count()`` deltas therefore equal the
# number of retraces/compilations — the steady-state regression tests and
# ``FleetExecutor.last_bin_stats["retraces"]`` are built on this. The
# ``name`` breaks the same events down per program family in the metrics
# registry (``jit.retrace.<name>`` counters) without perturbing the
# legacy global's delta semantics.
# ---------------------------------------------------------------------------
_TRACE_COUNT = 0


def note_trace(name: str = "features") -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1
    note_retrace(name)


def trace_count() -> int:
    return _TRACE_COUNT


# ---------------------------------------------------------------------------
# Shape bucketing: fleet bins of nearby sizes share one compiled program.
# ---------------------------------------------------------------------------

def bucket_n(n: int) -> int:
    """Power-of-two bucket for a fleet bin's instance axis (and the runtime
    ring's history axis): padding N up to the bucket makes the train and
    rollout jit caches key on the bucket, so a bin that shrinks by one job
    (a failed deployment, a removed sensor) re-uses the warm compilation
    instead of retracing."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def edge_pad(a, pad: int, axis: int = 0):
    """Pad ``axis`` by repeating the trailing slice ``pad`` times. Edge
    replication — never zeros — so padded instances run the same numerics
    as a real one (GAM knot rows must stay strictly increasing); callers
    slice the pad back off every output. Works on numpy and jax arrays."""
    if pad <= 0:
        return a
    import jax.numpy as jnp
    xp = jnp if isinstance(a, jnp.ndarray) else np
    take = [slice(None)] * a.ndim
    take[axis] = slice(a.shape[axis] - 1, a.shape[axis])
    shape = list(a.shape)
    shape[axis] = pad
    return xp.concatenate(
        [a, xp.broadcast_to(a[tuple(take)], shape)], axis=axis)


@dataclass(frozen=True)
class FeatureSpec:
    target_lags: int = 24        # 1..L hourly lags of the target
    weather_lags: int = 24       # 1..Lw hourly lags of temperature
    use_weather: bool = True
    use_calendar: bool = True
    step: float = HOUR

    @property
    def n_features(self) -> int:
        n = self.target_lags
        if self.use_weather:
            n += 1 + self.weather_lags
        if self.use_calendar:
            n += 5
        return n

    @classmethod
    def from_params(cls, up: dict) -> "FeatureSpec":
        return cls(target_lags=int(up.get("target_lags", 24)),
                   weather_lags=int(up.get("weather_lags", 24)),
                   use_weather=bool(up.get("use_weather", True)),
                   use_calendar=bool(up.get("use_calendar", True)),
                   step=float(up.get("frequency", HOUR)))


def fleet_hourly_series(system, ctxs, t0: float, t1: float,
                        step: float) -> Tuple[np.ndarray, np.ndarray]:
    """Batched series loading: ONE ``store.read_many`` for a whole fleet
    bin, then per-series alignment onto the shared ``[t0, t1)`` grid.

    Returns ``(grid (T,), targets (N, T))``; rows align 1:1 with ``ctxs``.

    Missing-data policy (deliberate, see docs/ARCHITECTURE.md): a window
    with NO points yields an all-zero row, so the job succeeds with flat
    forecasts in both executors instead of crashing — one dead sensor
    must not poison a megabatched bin, and LocalPool must agree with
    Fleet. ``hourly_series`` is the single-context case of this function,
    so the solo and fleet paths cannot drift apart.
    """
    raw = system.store.read_many([c.ts_id for c in ctxs],
                                 t0 - step, t1 + step)
    grid = regular_grid(t0, t1, step)   # same binning rule as align_resample
    rows = []
    for t, v in raw:
        if t.size == 0:
            rows.append(np.zeros_like(grid))
            continue
        _, r = align_resample(t, v, step=step, start=t0, end=t1)
        rows.append(r)
    return grid, np.stack(rows) if rows else np.zeros((0, grid.size))


def hourly_series(system, ctx, t0: float, t1: float, step: float) -> Tuple[np.ndarray, np.ndarray]:
    grid, targets = fleet_hourly_series(system, [ctx], t0, t1, step)
    return grid, targets[0]


def fleet_window(system, ctxs, t0: float, t1: float, step: float):
    """``fleet_hourly_series`` plus the two extras the incremental runtime
    needs to keep a bin's history device-resident across polls:

    * ``mask (N, T)`` — which grid bins held real points (the others carry
      window-relative forward-fill / leading-zero values);
    * ``prior (N,)`` — per-series count of stored points strictly before
      the read window, taken under the SAME store lock as the read, so a
      later ``read_many(since=watermark)`` can prove no out-of-order
      append landed behind the watermark.

    Returns ``(grid, targets, mask, prior)``; rows computed by the exact
    ``align_resample`` rule, so ``targets`` equals what the cold path
    loads.
    """
    raw, prior = system.store.read_many([c.ts_id for c in ctxs],
                                        t0 - step, t1 + step,
                                        prior_counts=True)
    grid = regular_grid(t0, t1, step)
    rows, masks = [], []
    in_window = np.zeros(len(raw), np.int64)   # points < t1 (next watermark)
    for i, (t, v) in enumerate(raw):
        if t.size == 0:
            rows.append(np.zeros_like(grid))
            masks.append(np.zeros(grid.size, bool))
            continue
        in_window[i] = int(np.searchsorted(t, t1)) \
            - int(np.searchsorted(t, t0 - step))
        _, r, m = align_resample(t, v, step=step, start=t0, end=t1,
                                 with_mask=True)
        rows.append(r)
        masks.append(m)
    # prior counts from the store are "< t0 - step"; the runtime watermark
    # is t1, so fold in the returned points below it (same lock => exact)
    prior = prior + in_window
    if not rows:
        z = np.zeros((0, grid.size))
        return grid, z, z.astype(bool), prior
    return grid, np.stack(rows), np.stack(masks), prior


def align_delta(raw, t_hi: float, t1: float, step: float
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Align a watermark-delta read onto the ``d`` new grid bins covering
    ``[t_hi, t1)``: returns ``(vals (N, d), mask (N, d))`` where ``vals``
    holds each filled bin's mean (same bincount rule as
    ``align_resample``) and ``mask`` marks filled bins. Empty bins are
    left 0 here — the device ring update forward-fills them from the
    previous ring column, which by induction carries the value
    ``align_resample`` would have propagated."""
    d = max(int(round((t1 - t_hi) / step)), 0)
    n = len(raw)
    sizes = np.asarray([t.size for t, _ in raw], np.int64)
    if sizes.sum() == 0:
        return np.zeros((n, d)), np.zeros((n, d), bool)
    # one flattened bincount over (series, bin) — per-(series,bin) sums
    # accumulate in the same store order as align_resample's, so filled
    # bins land bitwise-identical to the cold aligner
    tcat = np.concatenate([t for t, _ in raw if t.size])
    vcat = np.concatenate([v for _, v in raw if v.size])
    sidx = np.repeat(np.arange(n), sizes)
    idx = np.floor((tcat - t_hi) / step).astype(np.int64)
    ok = (idx >= 0) & (idx < d)
    flat = sidx[ok] * d + idx[ok]
    sums = np.bincount(flat, weights=vcat[ok], minlength=n * d).reshape(n, d)
    cnts = np.bincount(flat, minlength=n * d).reshape(n, d)
    mask = cnts > 0
    vals = np.where(mask, sums / np.maximum(cnts, 1), 0.0)
    return vals, mask


def design_matrix(spec: FeatureSpec, times, target, temps) -> Tuple[np.ndarray, np.ndarray]:
    """Rows t -> predict target[t] from lags/calendar/weather. Drops warmup."""
    cols = [lagged_features(target, range(1, spec.target_lags + 1))]
    if spec.use_weather:
        cols.append(temps[:, None])
        cols.append(lagged_features(temps, range(1, spec.weather_lags + 1)))
    if spec.use_calendar:
        cols.append(calendar_features(times))
    X = np.concatenate(cols, axis=1)
    warm = max(spec.target_lags, spec.weather_lags if spec.use_weather else 0)
    return X[warm:], np.asarray(target, np.float64)[warm:]


def step_features(spec: FeatureSpec, y_hist: np.ndarray, temp_hist: np.ndarray,
                  t_next: float) -> np.ndarray:
    """Feature row(s) for ONE next step given trailing history.
    y_hist/temp_hist: (..., >=lags) trailing windows (last element = t-1)."""
    tl, wl = spec.target_lags, spec.weather_lags
    cols = [y_hist[..., -1: -tl - 1: -1]]              # lag1..lagL
    if spec.use_weather:
        cols.append(temp_hist[..., -1:])               # temp at ~t (forecast)
        cols.append(temp_hist[..., -2: -wl - 2: -1])
    if spec.use_calendar:
        cal = calendar_features(np.asarray([t_next]))[0]
        cal = np.broadcast_to(cal, y_hist.shape[:-1] + (5,))
        cols.append(cal)
    return np.concatenate(cols, axis=-1)


def recursive_forecast(predict_fn, spec: FeatureSpec, y_hist, temp_hist,
                       temps_future, t_start: float, horizon: int):
    """Roll a one-step model forward ``horizon`` steps (recursive strategy).
    Vectorised over leading dims: y_hist (..., L), temps_future (..., H).
    predict_fn maps (..., F) -> (...,). Returns (..., H).

    This is the host-side REFERENCE path: one predict_fn round-trip per
    step. The serving hot path is ``make_device_rollout``, which runs the
    identical recursion as a single jitted ``lax.scan`` on device;
    ``tests/test_fleet_rollout.py`` pins their agreement.
    """
    y_hist = np.array(y_hist, np.float64)
    temp_hist = np.array(temp_hist, np.float64)
    preds = []
    for h in range(horizon):
        t_next = t_start + h * spec.step
        temp_hist = np.concatenate(
            [temp_hist, temps_future[..., h: h + 1]], axis=-1)
        x = step_features(spec, y_hist, temp_hist, t_next)
        yh = np.asarray(predict_fn(x), np.float64)
        preds.append(yh)
        y_hist = np.concatenate([y_hist, yh[..., None]], axis=-1)
    return np.stack(preds, axis=-1)


def step_features_jnp(spec: FeatureSpec, y_win, t_win, cal_row):
    """jnp twin of ``step_features`` over FIXED-SIZE trailing windows (the
    scan carry): y_win (..., target_lags) with the most recent value last,
    t_win (..., weather_lags+1) already including the step's forecast temp
    at its end, cal_row (5,) precomputed calendar features for the step."""
    import jax.numpy as jnp
    wl = spec.weather_lags
    cols = [y_win[..., ::-1]]                          # lag1..lagL
    if spec.use_weather:
        cols.append(t_win[..., -1:])                   # temp at ~t (forecast)
        if wl:
            cols.append(t_win[..., -2: -wl - 2: -1])
    if spec.use_calendar:
        cols.append(jnp.broadcast_to(cal_row, y_win.shape[:-1] + (5,)))
    return jnp.concatenate(cols, axis=-1)


def make_device_rollout(predict_fn, spec: FeatureSpec, horizon: int,
                        mesh=None):
    """Device-resident whole-horizon rollout: ONE jitted program that runs
    the recursive-forecast recursion as a ``lax.scan`` over the horizon —
    lag-window update, calendar/weather feature assembly, per-instance
    standardization and prediction all stay on device. The host loop in
    ``recursive_forecast`` crosses host<->device 2x per step; this crosses
    once per score bin.

    With ``mesh`` (a 1-D fleet mesh from ``launch.mesh.make_fleet_mesh``)
    the instance axis N of every input/output is shard_map-partitioned
    across the mesh's devices — the recursion is per-instance independent,
    so the sharded program needs no collectives and still runs as one
    dispatch; hod/dow stay replicated. Uneven N is edge-padded to a shard
    multiple and the pad rows are sliced back off.

    predict_fn: traceable (stacked_params, x (N, F)) -> (N,) predictions
    (standardized features in, physical-unit predictions out).

    Returns jitted ``run(stacked, mu, sd, y0, tw0, temps_future, hod, dow)``
      stacked       pytree of per-instance model params, leading dim N
      mu, sd        (N, F) per-instance feature standardization
      y0            (N, target_lags) trailing target window, newest last
      tw0           (N, weather_lags+1) trailing temperature window
      temps_future  (N, H) weather forecasts for the horizon
      hod, dow      (H,) calendar phases (``calendar_phases`` of the
                    horizon timestamps — reduced on host, f32-safe)
    -> (N, H) predictions.
    """
    import jax
    import jax.numpy as jnp

    def run(stacked, mu, sd, y0, tw0, temps_future, hod, dow):
        note_trace("rollout")        # Python body runs only while tracing
        cal = calendar_features_jnp(hod, dow)                    # (H, 5)
        xs = (jnp.moveaxis(temps_future, -1, 0), cal)

        def body(carry, inp):
            y_win, t_win = carry
            temp_next, cal_row = inp
            if spec.use_weather:
                t_win = jnp.concatenate(
                    [t_win[..., 1:], temp_next[..., None]], axis=-1)
            x = step_features_jnp(spec, y_win, t_win, cal_row)
            yh = predict_fn(stacked, (x - mu) / sd)
            y_win = jnp.concatenate([y_win[..., 1:], yh[..., None]], axis=-1)
            return (y_win, t_win), yh

        (_, _), preds = jax.lax.scan(body, (y0, tw0), xs, length=horizon)
        return jnp.moveaxis(preds, 0, -1)

    if mesh is None:
        return jax.jit(run)
    from ..distributed.sharding import fleet_sharded
    # hod/dow (args 6, 7) are the shared horizon calendar: replicated
    return fleet_sharded(run, mesh, replicated_argnums=(6, 7),
                         key=("rollout", predict_fn, spec, horizon))
