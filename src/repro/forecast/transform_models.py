"""Data-transformation models (paper §3.1 'Data Transformation Models',
§4.1 Fig. 4): the SAME 4-function interface used for pure data processing —
here, integrating an irregular instantaneous current feed into a regular
15-minute energy series. To consumers the output is just another semantic
time-series."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..core.registry import ModelInterface
from ..timeseries.transforms import DAY, integrate_to_energy


class EnergyFromCurrentModel(ModelInterface):
    """score() reads CURRENT_MAG at the context entity, integrates to kWh on
    a regular grid, and the executor persists it as a forecast-series on the
    target context (signal ENERGY_LOAD_DERIVED)."""
    KIND = "XFORM"
    DEFAULTS = {"voltage": 230.0, "out_step": 900.0, "window_days": 7}

    def load(self):
        up = {**self.DEFAULTS, **self.user_params}
        now = float(up.get("now", 0.0))
        src_sig = up.get("source_signal", "CURRENT_MAG")
        ctx = self.system.graph.context(src_sig, self.context.entity.name)
        t0 = now - float(up["window_days"]) * DAY
        self._raw = self.system.store.read(ctx.ts_id, t0, now)
        self._up = up
        return self._raw

    def transform(self):
        t, i = self._raw
        grid, energy = integrate_to_energy(
            t, i, voltage=self._up["voltage"], step=self._up["out_step"])
        self._out = (grid, energy)
        return self._out

    def train(self):
        # transformation models are stateless; "training" records config only
        self.load()
        return {"kind": self.KIND, "config": dict(self._up)}

    def score(self, model_object) -> Tuple[np.ndarray, np.ndarray]:
        self.load()
        return self.transform()
