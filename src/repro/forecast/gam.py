"""GAM forecaster (paper Table 1): additive smooth terms via cubic B-spline
basis expansion on the continuous drivers (temperature, recent lags) +
linear terms, fitted by ridge — the classic penalised-basis GAM
approximation. Fleet path: vmapped solve over the expanded design."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .base import ForecastModelBase
from .linear import _ridge_fit, _ridge_fleet

N_KNOTS = 8


def _spline_cols(up: dict) -> list:
    """Columns to spline-expand: the smooth drivers — concurrent temperature
    (sits right after the target lags in the design matrix) and the top
    target lag. Remaining features stay linear."""
    tl = int(up.get("target_lags", 24))
    cols = [0]                               # lag-1 (smooth autoregression)
    if up.get("use_weather", True):
        cols.append(tl)                      # concurrent temp
    return cols


def _bspline_basis(x, knots):
    """Cubic B-spline basis (numpy, де Boor via cox-de-boor on fixed grid).
    x: (..., ), knots: (K,) augmented internally. Returns (..., K+2)."""
    t = np.concatenate([[knots[0]] * 3, knots, [knots[-1]] * 3])
    n_basis = len(t) - 4
    x = np.clip(x, knots[0], knots[-1])
    B = np.zeros(x.shape + (len(t) - 1,))
    for i in range(len(t) - 1):
        B[..., i] = np.where((x >= t[i]) & (x < t[i + 1]), 1.0, 0.0)
    B[..., np.searchsorted(t, knots[-1]) - 1] = np.where(x >= knots[-1], 1.0,
                                                         B[..., np.searchsorted(t, knots[-1]) - 1])
    for k in range(1, 4):
        Bn = np.zeros(x.shape + (len(t) - 1 - k,))
        for i in range(len(t) - 1 - k):
            d1 = t[i + k] - t[i]
            d2 = t[i + k + 1] - t[i + 1]
            a = (x - t[i]) / d1 * B[..., i] if d1 > 0 else 0.0
            b = (t[i + k + 1] - x) / d2 * B[..., i + 1] if d2 > 0 else 0.0
            Bn[..., i] = a + b
        B = Bn
    return B[..., :n_basis]


def _expand(X, knot_sets, cols):
    """Spline-expand the given columns; keep every column linear as well
    (spline terms are additive corrections on top of the linear model)."""
    parts = [X]
    for knots, j in zip(knot_sets, cols):
        parts.append(_bspline_basis(X[..., j], knots))
    return np.concatenate(parts, axis=-1)


def _bspline_basis_jnp(x, knots):
    """jnp twin of ``_bspline_basis``, batched per instance and traceable
    inside the device scoring rollout. x: (N,), knots: (N, K) each row
    strictly increasing. Returns (N, K+2)."""
    K = knots.shape[-1]
    t = jnp.concatenate([jnp.repeat(knots[..., :1], 3, axis=-1), knots,
                         jnp.repeat(knots[..., -1:], 3, axis=-1)], axis=-1)
    x = jnp.clip(x, knots[..., 0], knots[..., -1])
    B = ((x[..., None] >= t[..., :-1])
         & (x[..., None] < t[..., 1:])).astype(jnp.float32)
    # right-closed last interval (x == last knot falls in the top basis)
    B = B.at[..., K + 1].set(jnp.where(x >= knots[..., -1], 1.0,
                                       B[..., K + 1]))
    for k in range(1, 4):
        d1 = t[..., k:-1] - t[..., :-1 - k]
        d2 = t[..., k + 1:] - t[..., 1:-k]
        a = jnp.where(d1 > 0, (x[..., None] - t[..., :-1 - k])
                      / jnp.where(d1 > 0, d1, 1.0) * B[..., :-1], 0.0)
        b = jnp.where(d2 > 0, (t[..., k + 1:] - x[..., None])
                      / jnp.where(d2 > 0, d2, 1.0) * B[..., 1:], 0.0)
        B = a + b
    return B[..., :K + 2]


class GAMForecaster(ForecastModelBase):
    KIND = "GAM"
    SUPPORTS_FLEET = True

    def _cols(self):
        return _spline_cols({**self.DEFAULTS, **self.user_params})

    def _fit(self, X, y, rng):
        cols = self._cols()
        knot_sets = [np.linspace(X[:, j].min() - 1e-3, X[:, j].max() + 1e-3,
                                 N_KNOTS) for j in cols]
        Xe = _expand(X, knot_sets, cols)
        theta = np.asarray(_ridge_fit(jnp.asarray(Xe), jnp.asarray(y), 1e-2))
        return {"theta": theta, "knots": np.stack(knot_sets),
                "cols": np.asarray(cols)}

    def _predict(self, params, X):
        Xe = _expand(np.asarray(X), list(params["knots"]),
                     list(params["cols"]))
        th = params["theta"]
        return Xe @ th[:-1] + th[-1]

    @classmethod
    def _fleet_fit(cls, X, y, rng, up, mesh=None):
        # spline columns from the bin's SHARED user_params — a non-default
        # target_lags shifts the concurrent-temp column, so defaults here
        # would spline the wrong feature and diverge from LocalPool
        cols = _spline_cols(up)
        X = np.asarray(X)                # spline expansion is host-side
        knots, Xes = [], []
        for i in range(X.shape[0]):
            ks = [np.linspace(X[i, :, j].min() - 1e-3, X[i, :, j].max() + 1e-3,
                              N_KNOTS) for j in cols]
            knots.append(np.stack(ks))
            Xes.append(_expand(X[i], ks, cols))
        Xe = jnp.asarray(np.stack(Xes))
        th = _ridge_fleet(Xe, jnp.asarray(y), 1e-2, mesh=mesh)
        return {"theta": th, "knots": np.stack(knots),
                "cols": np.tile(np.asarray(cols), (X.shape[0], 1))}

    @classmethod
    def _fleet_predict(cls, stacked, X):
        X = np.asarray(X)
        out = np.zeros(X.shape[0])
        # knots differ per instance -> loop the expansion (cheap); the
        # matmul stays vectorised per instance
        for i in range(X.shape[0]):
            Xe = _expand(X[i], list(stacked["knots"][i]),
                         list(stacked["cols"][i]))
            th = stacked["theta"][i]
            out[i] = Xe @ th[:-1] + th[-1]
        return out

    @classmethod
    def _fleet_window_predict(cls, model_objects, X):
        # knots differ per instance -> loop the expansion; each row is the
        # full (T, Fe) expanded design so the matmul stays batched per
        # instance
        X = np.asarray(X)
        out = []
        for i, m in enumerate(model_objects):
            p = m["params"]
            Xe = _expand(X[i], list(p["knots"]), list(p["cols"]))
            th = p["theta"]
            out.append(Xe @ th[:-1] + th[-1])
        return np.stack(out)

    @classmethod
    def _rollout_statics(cls, up, stacked):
        # the columns the model was FITTED with (shared across the bin) —
        # static python ints, part of the compiled-rollout cache key
        return tuple(int(c) for c in stacked["cols"][0])

    @classmethod
    def _device_predict_factory(cls, spec, statics):
        cols = statics

        def predict(stacked, x):
            th = jnp.asarray(stacked["theta"], jnp.float32)
            knots = jnp.asarray(stacked["knots"], jnp.float32)
            parts = [x]
            for i, j in enumerate(cols):
                parts.append(_bspline_basis_jnp(x[..., j], knots[:, i]))
            Xe = jnp.concatenate(parts, axis=-1)
            return jnp.einsum("nf,nf->n", Xe, th[:, :-1]) + th[:, -1]

        return predict
