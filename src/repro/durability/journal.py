"""Group-commit write-ahead journal + snapshot/compaction + recovery.

The journal rides the ``serverless.storage.StorageBackend`` protocol
(``InMemoryStorage`` for tests and crash sweeps, ``FilesystemStorage``
with atomic fsync'd puts for real durability). Records buffer in memory
and flush as ONE segment object per commit — ``Castor.tick`` commits once
per scheduler cycle, so the fsync cost is batched per bin, never paid per
record (that is throughput gate (b) in ``bench_durability.py``).

Object layout (both key families sort chronologically)::

    wal/<seq>.log    one segment per commit, seq strictly increasing
    snap/<seq>.snap  full-state snapshot covering every segment < seq

Record stream invariants that make any-prefix recovery safe:

* effects (model versions, forecasts, detections, series appends) are
  journaled by the stores at mutation time, IN mutation order;
* the scheduler's watermark/retry delta for a tick is ONE atomic
  ``sched`` record appended AFTER the tick's effects — so a torn tail
  can only ever produce "effects persisted, watermark behind", never the
  reverse. Recovery then re-fires the whole boundary: the full-fleet bin
  re-executes with its original batch composition (bitwise-identical f32
  numerics), and the idempotent stores drop the already-journaled prefix;
* a detection bin's record subsumes its derived-signal write-back (the
  inner ``append_points`` is journal-suppressed), so detection state and
  derived series can never come apart across a torn tail.

What is deliberately NOT journaled: the ``ModelRegistry`` (implementation
classes are code artifacts — re-``publish`` after ``Castor.open``, like
re-deploying code), executor/runtime caches (device state is rebuilt cold,
bitwise-equal by the PR-4 warm==cold contract), serverless worker pools,
and the deterministic ``WeatherService`` (reconstructed from its journaled
seed).

``snapshot()`` requires a quiescent control plane (no async serverless
run streaming absorbs concurrently): it reads full store state outside
any global mutation barrier. ``Castor.tick`` triggers it only between
cycles; call sites that stream (``run_async``) should snapshot after
``wait()``.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .wal import decode_records, encode_record

WAL_PREFIX = "wal/"
SNAP_PREFIX = "snap/"


def wal_key(seq: int) -> str:
    return f"{WAL_PREFIX}{int(seq):012d}.log"


def snap_key(seq: int) -> str:
    return f"{SNAP_PREFIX}{int(seq):012d}.snap"


def _seq_of(key: str) -> int:
    return int(key.split("/", 1)[1].split(".", 1)[0])


class Journal:
    """Buffered, group-committed WAL over a ``StorageBackend``.

    ``append`` is what the stores call at mutation time; it buffers a
    framed record and auto-flushes past ``max_buffer_bytes`` (a bulk
    ingest must not accumulate unbounded memory). ``commit`` flushes the
    buffer as one segment — the durability point. ``suppressed()`` is a
    thread-local escape hatch for mutations that are subsumed by a
    coarser atomic record (the detection flow's derived write-back).
    """

    def __init__(self, storage, *, castor=None, snapshot_every: int = 0,
                 max_buffer_bytes: int = 4 << 20,
                 retain_segments: bool = False, pipelined: bool = False):
        self.storage = storage
        self.castor = castor
        self.snapshot_every = int(snapshot_every)
        self.max_buffer_bytes = int(max_buffer_bytes)
        #: keep compacted-away segments (chaos sweeps reconstruct every
        #: chronological crash state from the retained history)
        self.retain_segments = retain_segments
        #: hand each segment put to a writer thread so the fsync of tick
        #: k overlaps the compute of tick k+1 (at most ONE write in
        #: flight; the next flush waits for it first, so segments land
        #: strictly in seq order and a crash still loses only a suffix)
        self.pipelined = pipelined
        self._lock = threading.Lock()
        self._local = threading.local()
        self._buf: List[bytes] = []
        self._buf_bytes = 0
        self._seq = 0                      # next segment seq to write
        self._commits_since_snap = 0
        self._closed = False
        self._inflight: Optional[threading.Thread] = None
        self._write_err: Optional[BaseException] = None
        # telemetry (Castor.stats()["durability"])
        self.records = 0
        self.segments = 0
        self.bytes_written = 0
        self.snapshots = 0
        self.auto_flushes = 0

    # ------------------------------------------------------------ writes
    def start_at(self, seq: int) -> None:
        """First segment seq to write (recovery continues after the
        highest existing object so a torn tail is never overwritten)."""
        self._seq = int(seq)

    @contextmanager
    def suppressed(self):
        """Thread-locally drop ``append`` calls (re-entrant)."""
        prev = getattr(self._local, "off", 0)
        self._local.off = prev + 1
        try:
            yield
        finally:
            self._local.off = prev

    def append(self, op: str, obj: Any) -> None:
        if self._closed or getattr(self._local, "off", 0):
            return
        rec = encode_record(op, obj)
        with self._lock:
            if self._closed:
                return
            self._buf.append(rec)
            self._buf_bytes += len(rec)
            self.records += 1
            if self._buf_bytes >= self.max_buffer_bytes:
                self._flush_locked()
                self.auto_flushes += 1

    def commit(self) -> bool:
        """Flush buffered records as one segment (the group-commit /
        batched-fsync point); may trigger the periodic snapshot."""
        with self._lock:
            flushed = self._flush_locked()
        if self.snapshot_every and self.castor is not None \
                and self._commits_since_snap >= self.snapshot_every:
            self.snapshot()
        return flushed

    def _wait_inflight_locked(self) -> None:
        t = self._inflight
        if t is not None:
            t.join()
            self._inflight = None
        err, self._write_err = self._write_err, None
        if err is not None:
            raise err                      # surface at the NEXT commit

    def barrier(self) -> None:
        """Block until any in-flight pipelined segment write has landed
        (re-raising its error). A no-op for synchronous journals; crash
        tests call this before cloning the storage so the clone reflects
        the last commit deterministically."""
        with self._lock:
            self._wait_inflight_locked()

    def _write_async(self, key: str, data: bytes) -> None:
        from ..obs.trace import get_tracer
        try:
            # pipelined fsync: its span lives on the writer thread (a
            # root span there — the committing tick has already moved on)
            with get_tracer().span("journal.fsync", bytes=len(data)):
                self.storage.put(key, data)
        except BaseException as e:         # noqa: BLE001 — incl. chaos
            self._write_err = e

    def _flush_locked(self) -> bool:
        self._wait_inflight_locked()       # at most one write in flight
        if not self._buf:
            return False
        from ..obs.metrics import get_metrics
        from ..obs.trace import get_tracer
        tracer = get_tracer()
        with tracer.span("journal.flush", records=len(self._buf)) as sp:
            data = b"".join(self._buf)
            sp.set(bytes=len(data))
            key = wal_key(self._seq)
            self._seq += 1
            self.segments += 1
            self.bytes_written += len(data)
            self._buf = []
            self._buf_bytes = 0
            self._commits_since_snap += 1
            m = get_metrics()
            m.counter("wal.flushes").inc()
            m.counter("wal.flushed_bytes").inc(len(data))
            m.histogram("wal.segment_bytes").observe(len(data))
            if self.pipelined:
                # the fsync'd put happens on the writer thread and
                # overlaps the next tick's compute; the span covers only
                # the handoff (the fsync span lands on the writer side)
                t = threading.Thread(target=self._write_async,
                                     args=(key, data), daemon=True)
                self._inflight = t
                t.start()
            else:
                with tracer.span("journal.fsync", bytes=len(data)):
                    self.storage.put(key, data)
        return True

    def snapshot(self) -> str:
        """Write a full-state snapshot covering all current segments,
        then delete them (compaction). Requires quiescence — see module
        docstring."""
        if self.castor is None:
            raise RuntimeError("journal has no castor attached")
        with self._lock:
            self._flush_locked()
            self._wait_inflight_locked()   # snap put is synchronous
            basis = self._seq
        recs = snapshot_records(self.castor)
        data = b"".join(recs)
        key = snap_key(basis)
        self.storage.put(key, data)
        self.snapshots += 1
        self.bytes_written += len(data)
        self._commits_since_snap = 0
        if not self.retain_segments:
            for k in self.storage.list(WAL_PREFIX):
                if _seq_of(k) < basis:
                    self.storage.delete(k)
            for k in self.storage.list(SNAP_PREFIX):
                if k != key:
                    self.storage.delete(k)
        return key

    def close(self) -> None:
        """Flush any open segment, then refuse further appends.
        Idempotent — ``Castor.close`` may run more than once."""
        with self._lock:
            if self._closed:
                return
            self._flush_locked()
            self._wait_inflight_locked()
            self._closed = True

    @property
    def closed(self) -> bool:
        return self._closed

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"records": self.records, "segments": self.segments,
                    "snapshots": self.snapshots,
                    "bytes_written": self.bytes_written,
                    "auto_flushes": self.auto_flushes,
                    "buffered_records": len(self._buf),
                    "buffered_bytes": self._buf_bytes,
                    "next_seq": self._seq}


# ------------------------------------------------------------- recovery


def load_records(storage) -> Tuple[List[Tuple[str, Any]], Dict[str, Any]]:
    """Read snapshot-then-WAL into one record list + recovery stats.

    The newest fully-valid snapshot is the base (corrupt snapshots fall
    back to older ones — compaction deletes predecessors only after a
    successful snapshot put, so a crash mid-snapshot always leaves a
    replayable history). WAL segments after the snapshot replay in
    sorted-key order; the first torn/corrupt segment ends the trusted
    prefix (its valid records are kept, everything after is dropped —
    never an exception)."""
    all_wal = sorted(storage.list(WAL_PREFIX))
    all_snaps = sorted(storage.list(SNAP_PREFIX))
    records: List[Tuple[str, Any]] = []
    basis = 0
    snapshot_used: Optional[str] = None
    corrupt_snapshots = 0
    for key in reversed(all_snaps):
        recs, _valid, clean = decode_records(storage.get(key))
        if clean and recs:
            records.extend(recs)
            basis = _seq_of(key)
            snapshot_used = key
            break
        corrupt_snapshots += 1
    torn_segments = 0
    dropped_segments = 0
    segments_replayed = 0
    hit_torn = False
    for key in all_wal:
        if _seq_of(key) < basis:
            continue                       # compacted into the snapshot
        if hit_torn:
            dropped_segments += 1
            continue
        recs, _valid, clean = decode_records(storage.get(key))
        records.extend(recs)
        segments_replayed += 1
        if not clean:
            torn_segments += 1
            hit_torn = True                # trust nothing after a tear
    seqs = [_seq_of(k) for k in all_wal] + [_seq_of(k) for k in all_snaps]
    stats = {"records": len(records), "snapshot": snapshot_used,
             "snapshot_basis": basis if snapshot_used else None,
             "segments_replayed": segments_replayed,
             "torn_segments": torn_segments,
             "dropped_segments": dropped_segments,
             "corrupt_snapshots": corrupt_snapshots,
             "next_seq": (max(seqs) + 1) if seqs else 0}
    return records, stats


def replay_records(castor, records: List[Tuple[str, Any]]) -> int:
    """Apply a record stream to a fresh (journal-less) castor. Replay is
    idempotent where live saves are idempotent, and record order is
    mutation order, so per-model version numbering comes out identical.
    Unknown ops are skipped (forward compatibility), counted in the
    return value alongside applied records."""
    from ..core.deployment import deployment_from_record
    from ..core.lineage import forecasts_from_batch
    from ..core.semantics import Entity, Signal
    from ..flows.detection import DetectionRecord
    n = 0
    for op, d in records:
        n += 1
        if op == "ts":
            castor.store.append(d["id"], d["t"], d["v"])
        elif op == "tsp":
            castor.store.append_points(d["ids"], d["t"], d["v"])
        elif op == "mv":
            castor.versions.save(d["model_id"], d["params"],
                                 trained_at=d["trained_at"],
                                 metadata=d.get("metadata"))
        elif op == "fc":
            castor.predictions.save_many(forecasts_from_batch(d))
        elif op == "det":
            castor.detections.save_many(
                [DetectionRecord(**r) for r in d["records"]],
                write_back=bool(d.get("wb", True)))
        elif op == "sig":
            castor.graph.add_signal(Signal(d["name"], d.get("unit", ""),
                                           d.get("description", "")))
        elif op == "ent":
            castor.graph.add_entity(
                Entity(d["name"], d.get("kind", "ENTITY"),
                       d.get("lat", 0.0), d.get("lon", 0.0)),
                d.get("parent"))
        elif op == "lnk":
            castor.graph.link_timeseries(d["ts_id"], d["signal"],
                                         d["entity"])
        elif op == "dep":
            castor.deployments.register(deployment_from_record(d))
        elif op == "rmdep":
            castor.deployments.remove(d["name"])
        elif op == "sched":
            castor.scheduler.restore_state(d)
        elif op == "meta":
            pass
    return n


def meta_of(records: List[Tuple[str, Any]]) -> Optional[Dict[str, Any]]:
    for op, d in records:
        if op == "meta":
            return d
    return None


# ------------------------------------------------------------- snapshot


def snapshot_records(castor) -> List[bytes]:
    """The full system-of-record state as one framed record sequence — a
    snapshot is literally a compacted WAL, replayed by the exact same
    machinery. Detection records are emitted with ``wb=False``: the
    snapshotted series already contain every derived write-back."""
    from dataclasses import asdict

    from ..core.deployment import deployment_record
    from ..core.lineage import forecast_batch_record
    recs: List[bytes] = [encode_record("meta", {
        "format": 1, "weather_seed": castor.weather_seed})]
    g = castor.graph
    for sig in g.signals.values():
        recs.append(encode_record("sig", {
            "name": sig.name, "unit": sig.unit,
            "description": sig.description}))
    for name, ent in g.entities.items():    # insertion order: parents first
        p = g.parent(name)
        recs.append(encode_record("ent", {
            "name": ent.name, "kind": ent.kind, "lat": ent.lat,
            "lon": ent.lon, "parent": p.name if p is not None else None}))
    for (signal, entity), ts_id in list(g._ts.items()):
        recs.append(encode_record("lnk", {
            "ts_id": ts_id, "signal": signal, "entity": entity}))
    for ts_id in castor.store.ids():
        t, v = castor.store.read(ts_id)
        recs.append(encode_record("ts", {
            "id": ts_id, "t": np.asarray(t), "v": np.asarray(v)}))
    for dep in castor.deployments.all():
        recs.append(encode_record("dep", deployment_record(dep)))
    for model_id in castor.versions.model_ids():
        for mv in castor.versions.history(model_id):   # save order: the
            recs.append(encode_record("mv", {           # numbering replays
                "model_id": mv.model_id, "trained_at": mv.trained_at,
                "params": mv.params, "metadata": mv.metadata}))
    for name in castor.predictions.deployment_names():
        recs.append(encode_record(
            "fc", forecast_batch_record(castor.predictions.history(name))))
    for name in castor.detections.deployment_names():
        recs.append(encode_record("det", {
            "records": [asdict(r) for r in castor.detections.history(name)],
            "wb": False}))
    recs.append(encode_record("sched", castor.scheduler.dump_state()))
    return recs
