"""Control-plane crash points: enumerate every post-crash storage state.

PR 6's chaos layer injects *worker* faults (duplicated/lost invocations);
this module injects *control-plane* deaths. Two mechanisms:

* ``CrashingStorage`` — a ``StorageBackend`` wrapper that kills the
  process (raises ``ProcessCrash``) on the Nth put, optionally writing a
  torn byte-prefix of the segment first — a live kill -9 mid-append.
* ``crash_states`` — offline enumeration: given the retained WAL+snapshot
  history of a COMPLETED run (``Journal(retain_segments=True)``), yield a
  fresh ``InMemoryStorage`` for every chronological record prefix the
  log ever passed through, plus torn-tail variants (next frame truncated
  mid-way; a byte of the last frame flipped). Recovery from each state +
  boundary-stamped catch-up must land bitwise-equal to the fault-free
  run — that sweep is gate (a) in ``bench_durability.py``.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Tuple

from ..serverless.storage import InMemoryStorage, StorageBackend
from .journal import SNAP_PREFIX, WAL_PREFIX, _seq_of
from .wal import split_frames


class ProcessCrash(RuntimeError):
    """Simulated control-plane death (kill -9 mid-write)."""


class CrashingStorage(StorageBackend):
    """Delegate to ``inner``, but die on put number ``puts_before_crash``
    (0-based): the fatal put persists only the first ``torn_fraction`` of
    its bytes — the non-atomic append a real crash leaves behind — then
    raises ``ProcessCrash``. With ``corrupt=True`` the torn prefix also
    gets one byte flipped (simulated media error); recovery must drop it
    via checksum either way. Reads/deletes pass through untouched so the
    wrapped storage IS the post-crash disk."""

    def __init__(self, inner: StorageBackend, puts_before_crash: int,
                 *, torn_fraction: float = 0.5, corrupt: bool = False):
        self.inner = inner
        self.puts_before_crash = int(puts_before_crash)
        self.torn_fraction = float(torn_fraction)
        self.corrupt = corrupt
        self.puts = 0
        self.crashed = False

    def put(self, key: str, data: bytes) -> None:
        if self.crashed:
            raise ProcessCrash("storage used after simulated crash")
        if self.puts < self.puts_before_crash:
            self.puts += 1
            self.inner.put(key, data)
            return
        self.crashed = True
        cut = int(len(data) * self.torn_fraction)
        if cut > 0:
            torn = bytearray(data[:cut])
            if self.corrupt and torn:
                torn[len(torn) // 2] ^= 0xFF
            self.inner.put(key, bytes(torn))
        raise ProcessCrash(f"simulated crash on put #{self.puts} ({key})")

    def get(self, key: str) -> bytes:
        return self.inner.get(key)

    def list(self, prefix: str = "") -> List[str]:
        return self.inner.list(prefix)

    def delete(self, key: str) -> bool:
        if self.crashed:
            raise ProcessCrash("storage used after simulated crash")
        return self.inner.delete(key)

    def clear(self) -> None:
        self.inner.clear()

    def stats(self):
        return self.inner.stats()

    def close(self) -> None:
        self.inner.close()


def clone_to_memory(storage: StorageBackend) -> InMemoryStorage:
    """Copy any backend's objects into a fresh ``InMemoryStorage``."""
    mem = InMemoryStorage()
    for key in storage.list():
        mem.put(key, storage.get(key))
    return mem


def _chronological(storage: StorageBackend) -> List[str]:
    """WAL segments and snapshots interleaved in creation order: the
    snapshot with basis N was written after segment N-1 and before
    segment N, so it sorts as (N, 0) against a segment's (seq, 1)."""
    keys = []
    for k in storage.list(WAL_PREFIX):
        keys.append((_seq_of(k), 1, k))
    for k in storage.list(SNAP_PREFIX):
        keys.append((_seq_of(k), 0, k))
    return [k for _, _, k in sorted(keys)]


def crash_states(
    storage: StorageBackend, *, torn: bool = True, stride: int = 1,
) -> Iterator[Tuple[str, InMemoryStorage]]:
    """Yield ``(label, state)`` for every post-crash storage state a run
    could have died in, chronologically: before any write, after every
    prefix of records within every segment (``stride`` subsamples the
    interior but segment boundaries are always included), and — with
    ``torn=True`` — the same prefixes with the NEXT frame half-written
    or byte-flipped. Each state is an independent ``InMemoryStorage``."""
    stride = max(1, int(stride))
    base: List[Tuple[str, bytes]] = []

    def state(extra: Optional[Tuple[str, bytes]] = None) -> InMemoryStorage:
        mem = InMemoryStorage()
        for k, d in base:
            mem.put(k, d)
        if extra is not None:
            mem.put(*extra)
        return mem

    yield "empty", state()
    for key in _chronological(storage):
        data = storage.get(key)
        if key.startswith(SNAP_PREFIX):
            # snapshots are single atomic puts (mkstemp+replace); the
            # mid-write states are covered by the torn variants below
            if torn and len(data) > 1:
                yield (f"{key}@torn", state((key, data[: len(data) // 2])))
                flipped = bytearray(data)
                flipped[-1] ^= 0xFF
                yield (f"{key}@corrupt", state((key, bytes(flipped))))
            base.append((key, data))
            yield f"{key}@full", state()
            continue
        frames = split_frames(data)
        cuts = list(range(stride, len(frames), stride))
        if not cuts or cuts[-1] != len(frames):
            cuts.append(len(frames))
        for r in cuts:
            if torn:
                # crash mid-write of frame r-1: its prefix survives, or
                # survives with a flipped byte — checksum must drop it
                head = b"".join(frames[: r - 1])
                last = frames[r - 1]
                yield (f"{key}@{r - 1}+torn",
                       state((key, head + last[: max(1, len(last) // 2)])))
                flipped = bytearray(last)
                flipped[len(flipped) // 2] ^= 0xFF
                yield (f"{key}@{r - 1}+corrupt",
                       state((key, head + bytes(flipped))))
            yield f"{key}@{r}", state((key, b"".join(frames[:r])))
        base.append((key, data))
