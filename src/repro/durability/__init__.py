"""Durability subsystem: write-ahead-logged stores + crash-restart
recovery (the paper's traceability claim made crash-proof).

* ``wal``     — checksummed, length-prefixed append-only record codec.
* ``journal`` — group-commit segment log + periodic snapshot/compaction
  over the ``serverless.storage.StorageBackend`` protocol, plus the
  recovery replay that rebuilds a ``Castor`` bitwise from
  snapshot-then-WAL.
* ``chaos``   — control-plane crash points: enumerate every
  record-prefix state of a finished run's log (including torn /
  truncated / corrupted tails) and a crashing storage wrapper for live
  kill -9 simulation.

Entry point: ``Castor.open(path)`` / ``Castor.open(storage=...)``.
"""
from .journal import Journal, load_records, replay_records, snapshot_records
from .wal import decode_records, encode_record, frame_records

__all__ = ["Journal", "load_records", "replay_records", "snapshot_records",
           "decode_records", "encode_record", "frame_records"]
