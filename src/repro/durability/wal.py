"""Write-ahead-log record codec: checksummed, length-prefixed frames.

A WAL *segment* is a byte string of back-to-back frames::

    | magic u32 | length u32 | crc32 u32 |  payload (length bytes)  |

(little-endian header). ``payload`` is the UTF-8 JSON encoding of one
``[op, obj]`` record, with numpy arrays encoded bitwise via the same
``(dtype, shape, base64)`` scheme the serverless payloads use
(``serverless.payload._enc``/``_dec``) — so params pytrees, forecast
bands and raw series round-trip byte-exact.

Decoding is *prefix-tolerant*: a segment whose tail was torn by a crash
(truncated mid-frame, or with flipped bytes in the last frame) decodes to
exactly the longest valid prefix of records — the frame whose magic,
bounds or checksum fails is dropped along with everything after it, and
decoding NEVER raises on malformed bytes. That is the whole recovery
contract: a kill -9 after any prefix of the record stream leaves a log
that replays to a consistent (possibly older) state, and the
boundary-stamped catch-up machinery regenerates the rest.
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Any, List, Tuple

from ..serverless.payload import _dec, _enc

#: per-frame magic: a corrupted length in frame k would otherwise let a
#: stale frame boundary masquerade as frame k+1; requiring the magic at
#: every boundary makes resynchronizing on garbage vanishingly unlikely
MAGIC = 0x57414C31  # "WAL1"

_HEADER = struct.Struct("<III")
HEADER_SIZE = _HEADER.size


def encode_record(op: str, obj: Any) -> bytes:
    """One framed record: header + JSON payload (arrays bitwise)."""
    payload = json.dumps([op, _enc(obj)],
                         separators=(",", ":")).encode("utf-8")
    return _HEADER.pack(MAGIC, len(payload),
                        zlib.crc32(payload)) + payload


def decode_payload(payload: bytes) -> Tuple[str, Any]:
    op, obj = json.loads(payload.decode("utf-8"))
    return op, _dec(obj)


def frame_records(payloads: List[bytes]) -> bytes:
    """Concatenate already-framed records into one segment blob."""
    return b"".join(payloads)


def decode_records(data: bytes) -> Tuple[List[Tuple[str, Any]], int, bool]:
    """Decode a segment into ``(records, valid_bytes, clean)``.

    ``records`` is the longest valid prefix of ``[op, obj]`` records;
    ``valid_bytes`` is how far into ``data`` that prefix extends;
    ``clean`` is True iff every byte decoded (no torn/corrupt tail).
    Malformed input is DATA, not an error — this never raises."""
    records: List[Tuple[str, Any]] = []
    pos = 0
    n = len(data)
    while pos + HEADER_SIZE <= n:
        magic, length, crc = _HEADER.unpack_from(data, pos)
        if magic != MAGIC:
            break                          # corrupted header
        end = pos + HEADER_SIZE + length
        if end > n:
            break                          # truncated mid-frame
        payload = data[pos + HEADER_SIZE:end]
        if zlib.crc32(payload) != crc:
            break                          # flipped payload bytes
        try:
            records.append(decode_payload(payload))
        except Exception:                  # crc collision on garbage JSON
            break
        pos = end
    return records, pos, pos == n


def split_frames(data: bytes) -> List[bytes]:
    """The valid prefix of a segment as individual framed records — what
    the chaos crash-point enumerator slices prefixes from."""
    frames: List[bytes] = []
    pos = 0
    records, valid, _clean = decode_records(data)
    del records
    while pos < valid:
        _magic, length, _crc = _HEADER.unpack_from(data, pos)
        end = pos + HEADER_SIZE + length
        frames.append(data[pos:end])
        pos = end
    return frames
