"""Parameter-spec machinery.

Every model declares a pytree of :class:`ParamSpec` leaves. From that single
declaration we derive:
  * ``shape_structs``  — ShapeDtypeStruct pytree (dry-run, no allocation)
  * ``init_tree``      — materialised parameters (smoke tests / examples)
  * ``partition_tree`` — jax.sharding.PartitionSpec pytree via logical-axis rules
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis name per dim
    init: str = "normal"                     # see _INITS
    scale: Optional[float] = None            # stddev / fill override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map(f, tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=is_spec)


def stack_specs(tree, n: int, axis_name: str = "layers"):
    """Prepend a leading stacked dim (for scan-over-periods)."""
    return _tree_map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        tree)


def shape_structs(tree, dtype):
    return _tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree)


def partition_tree(tree, rules: dict, mesh_axes: Tuple[str, ...]):
    """Logical axes -> PartitionSpec. ``rules[name]`` is a mesh axis (or tuple
    of mesh axes) or None. Unknown logical names replicate."""
    def one(s: ParamSpec):
        out = []
        used: set = set()
        for ax in s.axes:
            m = rules.get(ax) if ax is not None else None
            if m is None:
                out.append(None)
                continue
            ms = tuple(m) if isinstance(m, (tuple, list)) else (m,)
            ms = tuple(a for a in ms if a in mesh_axes and a not in used)
            used.update(ms)
            out.append(ms if len(ms) > 1 else (ms[0] if ms else None))
        return P(*out)
    return _tree_map(one, tree)


def _init_leaf(spec: ParamSpec, key, dtype):
    s = spec.shape
    fan_in = s[-2] if len(s) >= 2 else max(s[-1], 1)
    if spec.init == "normal":
        std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, s, jnp.float32) * std).astype(dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, s, jnp.float32) * std).astype(dtype)
    if spec.init == "zeros":
        return jnp.zeros(s, dtype)
    if spec.init == "ones":
        return jnp.ones(s, dtype)
    if spec.init == "const":
        return jnp.full(s, spec.scale or 0.0, dtype)
    if spec.init == "ssm_A":     # A_log: log Uniform[1, 16]
        u = jax.random.uniform(key, s, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "ssm_dt":    # softplus^-1 of Uniform[1e-3, 1e-1]
        u = jax.random.uniform(key, s, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    if spec.init == "rwkv_decay":  # w0 so that exp(-exp(w0)) ~ 0.85..0.99
        u = jax.random.uniform(key, s, jnp.float32, -3.0, -0.5)
        return u.astype(dtype)
    if spec.init == "uniform_small":
        return (jax.random.uniform(key, s, jnp.float32, -0.5, 0.5)
                * (spec.scale or 1.0)).astype(dtype)
    raise ValueError(f"unknown init {spec.init}")


def init_tree(tree, key, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def param_count(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def cast_tree(params, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
