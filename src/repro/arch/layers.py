"""Shared transformer layers: norms, RoPE / M-RoPE, GQA attention, MLPs.

All functions are pure; parameters come in as pytrees built by the matching
``*_specs`` builders. Compute dtype follows the inputs (bf16), accumulation
and softmax in f32 inside the attention kernels.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.flash_attention.ops import flash_attention
from ..kernels.decode_attention.ops import decode_attention
from .params import ParamSpec

# ---------------------------------------------------------------- norms

def norm_specs(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), ("embed",), "ones"),
                "bias": ParamSpec((d,), ("embed",), "zeros")}
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm (Qwen3): x (..., D), scale (D,)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)

# ---------------------------------------------------------------- RoPE

def mrope_sections(head_dim: int):
    """Half-dim split for Qwen2-VL M-RoPE (t/h/w). 128 -> (16, 24, 24)."""
    half = head_dim // 2
    a = half // 4
    b = (half - a) // 2
    return (a, b, half - a - b)


def _rope_angles(positions, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float, *, mrope: bool = False):
    """x: (B, S, H, D); positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the half-dim frequency spectrum is PARTITIONED into
    (temporal, height, width) sections; each section keeps its slice of the
    full spectrum but rotates by its own position stream.
    """
    D = x.shape[-1]
    half = D // 2
    if mrope:
        freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
        secs = mrope_sections(D)
        parts_c, parts_s = [], []
        off = 0
        for i, sec in enumerate(secs):
            ang = positions[i].astype(jnp.float32)[..., None] * freqs[off:off + sec]
            parts_c.append(jnp.cos(ang))
            parts_s.append(jnp.sin(ang))
            off += sec
        cos = jnp.concatenate(parts_c, -1)
        sin = jnp.concatenate(parts_s, -1)
    else:
        cos, sin = _rope_angles(positions, D, theta)
    cos = cos[:, :, None, :]                         # (B,S,1,half)
    sin = sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)

# ---------------------------------------------------------------- attention

def attention_specs(cfg: ModelConfig, d_in: Optional[int] = None):
    """Projections are stored FUSED over (H*hd): the fused dim is always
    divisible by the 16-way model axis even when the head count is not
    (28/36/40-head archs), which jit in_shardings require. The head structure
    is recovered by a reshape inside the layer (GSPMD pads intermediates)."""
    d = d_in or cfg.d_model
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    sp = {
        "wq": ParamSpec((d, H * hd), ("embed", "heads")),
        "wk": ParamSpec((d, KV * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, KV * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((H * hd, cfg.d_model), ("heads", "embed")),
    }
    if cfg.qk_norm:
        sp["q_norm"] = ParamSpec((hd,), (None,), "ones")
        sp["k_norm"] = ParamSpec((hd,), (None,), "ones")
    if cfg.norm == "layernorm":                      # bias-ful archs
        sp["bq"] = ParamSpec((H * hd,), ("heads",), "zeros")
        sp["bk"] = ParamSpec((KV * hd,), ("kv_heads",), "zeros")
        sp["bv"] = ParamSpec((KV * hd,), ("kv_heads",), "zeros")
        sp["bo"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
    return sp


def _project_qkv(cfg, p, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    return q, k, v


def attention_block(cfg: ModelConfig, p, x, positions):
    """Full-sequence attention (train / prefill).

    x: (B, S, d_in) normed input (d_in may exceed d_model for the Zamba2
    shared block, which projects q/k/v from a concat input). Returns
    (out (B,S,d_model), (k, v)) so prefill can populate caches.
    """
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta, mrope=cfg.use_mrope)
    k = apply_rope(k, positions, cfg.rope_theta, mrope=cfg.use_mrope)
    o = flash_attention(q, k, v, causal=cfg.causal)
    B, S = o.shape[:2]
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1),
                     p["wo"].astype(x.dtype))
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out, (k, v)


def attention_decode(cfg: ModelConfig, p, x, kstack, vstack, layer, lengths,
                     dist=None, in_place: bool = True):
    """One-token decode against STACKED caches (periods, B, S, KV, hd).

    Scatter-writes the new k/v at (layer, batch, lengths) — an in-place
    update touching only B rows, never rewriting the cache — then attends
    over lengths+1. Returns (out (B,1,d_model), new kstack, new vstack).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x)                # (B,1,H/KV,hd)
    pos = lengths[:, None]                           # (B,1)
    if cfg.use_mrope:
        pos3 = jnp.broadcast_to(lengths[None, :, None], (3, B, 1))
        q = apply_rope(q, pos3, cfg.rope_theta, mrope=True)
        k = apply_rope(k, pos3, cfg.rope_theta, mrope=True)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)

    b_idx = jnp.arange(B)
    if in_place:
        kstack = kstack.at[layer, b_idx, lengths].set(k[:, 0].astype(kstack.dtype))
        vstack = vstack.at[layer, b_idx, lengths].set(v[:, 0].astype(vstack.dtype))
        ck = jax.lax.dynamic_index_in_dim(kstack, layer, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(vstack, layer, 0, keepdims=False)
    else:   # stacks are actually single-layer slices (legacy path)
        ck = kstack.at[b_idx, lengths].set(k[:, 0].astype(kstack.dtype))
        cv = vstack.at[b_idx, lengths].set(v[:, 0].astype(vstack.dtype))
        kstack, vstack = ck, cv
    if dist is not None:
        from ..kernels.decode_attention.distributed import (
            decode_attention_distributed)
        o = decode_attention_distributed(q[:, 0], ck, cv, lengths + 1,
                                         mesh=dist["mesh"],
                                         seq_axis=dist.get("seq_axis", "model"),
                                         batch_axes=dist.get("batch_axes", ("data",)))
    else:
        o = decode_attention(q[:, 0], ck, cv, lengths + 1)
    out = jnp.einsum("be,ed->bd", o.reshape(B, -1), p["wo"].astype(x.dtype))
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out[:, None], kstack, vstack

# ---------------------------------------------------------------- MLP

def mlp_specs(cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    ff = cfg.d_ff
    if cfg.act == "swiglu":
        return {"w_gate": ParamSpec((d, ff), ("embed", "mlp")),
                "w_up": ParamSpec((d, ff), ("embed", "mlp")),
                "w_down": ParamSpec((ff, cfg.d_model), ("mlp", "embed"))}
    sp = {"w_in": ParamSpec((d, ff), ("embed", "mlp")),
          "w_down": ParamSpec((ff, cfg.d_model), ("mlp", "embed"))}
    if cfg.norm == "layernorm":
        sp["b_in"] = ParamSpec((ff,), ("mlp",), "zeros")
        sp["b_down"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
    return sp


def mlp_block(cfg: ModelConfig, p, x):
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype))
        if "b_in" in p:
            h = h + p["b_in"].astype(x.dtype)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    if "b_down" in p:
        out = out + p["b_down"].astype(x.dtype)
    return out
