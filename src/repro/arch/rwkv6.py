"""RWKV-6 (Finch) block: time-mix (WKV scan with data-dependent decay) +
channel-mix, both with token-shift. LayerNorms are handled by the caller
(model.py) like every other block; this module provides the two mixers.

Decode state per layer: (x_prev_tm (B,d), x_prev_cm (B,d), wkv (B,H,K,K)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.rwkv6_scan.ops import wkv6_scan
from ..kernels.rwkv6_scan.ref import wkv6_decode_step
from .params import ParamSpec

_DDLERP_R = 32      # low-rank dim of the data-dependent token-shift lerp
_DECAY_R = 64       # low-rank dim of the decay projection


def timemix_specs(cfg: ModelConfig):
    d = cfg.d_model
    H, K = cfg.rwkv_heads, cfg.rwkv_head_size
    return {
        "mu_x": ParamSpec((d,), ("embed",), "uniform_small", 1.0),
        "mu_5": ParamSpec((5, d), (None, "embed"), "uniform_small", 1.0),
        "lora_A": ParamSpec((d, 5 * _DDLERP_R), ("embed", None), "normal", 0.01),
        "lora_B": ParamSpec((5, _DDLERP_R, d), (None, None, "embed"), "normal", 0.01),
        "w0": ParamSpec((d,), ("embed",), "rwkv_decay"),
        "w_lora_A": ParamSpec((d, _DECAY_R), ("embed", None), "normal", 0.01),
        "w_lora_B": ParamSpec((_DECAY_R, d), (None, "embed"), "normal", 0.01),
        "u": ParamSpec((H, K), ("rwkv_heads", None), "uniform_small", 1.0),
        "wr": ParamSpec((d, d), ("embed", "rwkv_hidden")),
        "wk": ParamSpec((d, d), ("embed", "rwkv_hidden")),
        "wv": ParamSpec((d, d), ("embed", "rwkv_hidden")),
        "wg": ParamSpec((d, d), ("embed", "rwkv_hidden")),
        "wo": ParamSpec((d, d), ("rwkv_hidden", "embed")),
        "ln_x_scale": ParamSpec((d,), ("embed",), "ones"),
        "ln_x_bias": ParamSpec((d,), ("embed",), "zeros"),
    }


def channelmix_specs(cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), ("embed",), "uniform_small", 1.0),
        "mu_r": ParamSpec((d,), ("embed",), "uniform_small", 1.0),
        "wk": ParamSpec((d, ff), ("embed", "mlp")),
        "wv": ParamSpec((ff, d), ("mlp", "embed")),
        "wr": ParamSpec((d, d), ("embed", "rwkv_hidden")),
    }


def _shift(x, x_prev):
    """Token shift: x[t-1] with x_prev filling t=0. x: (B,S,d), x_prev: (B,d)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _group_norm(scale, bias, x, H, eps=1e-5):
    """Per-head LayerNorm over each head's channels. x: (B,S,d)."""
    B, S, d = x.shape
    xf = x.astype(jnp.float32).reshape(B, S, H, d // H)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(B, S, d)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def _ddlerp(p, x, dx):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    xxx = x + dx * p["mu_x"].astype(x.dtype)
    s = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, p["lora_A"].astype(x.dtype))
                 .astype(jnp.float32)).astype(x.dtype)
    B, S, _ = x.shape
    s = s.reshape(B, S, 5, _DDLERP_R)
    off = jnp.einsum("bsfr,frd->bsfd", s, p["lora_B"].astype(x.dtype))
    mixed = (x[:, :, None] + dx[:, :, None]
             * (p["mu_5"].astype(x.dtype)[None, None] + off))
    return [mixed[:, :, i] for i in range(5)]     # w,k,v,r,g


def _decay(p, xw):
    """Data-dependent per-channel decay w in (0,1)."""
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["w_lora_A"].astype(xw.dtype))
                  .astype(jnp.float32))
    ww = (p["w0"].astype(jnp.float32)
          + jnp.einsum("bsr,rd->bsd", lo, p["w_lora_B"].astype(jnp.float32)))
    return jnp.exp(-jnp.exp(ww))                   # (B,S,d) f32


def timemix_block(cfg: ModelConfig, p, x, x_prev, wkv_state=None, *, chunk: int = 32):
    """x: (B,S,d) normed input. Returns (out, last_x (B,d), new_wkv_state)."""
    B, S, d = x.shape
    H, K = cfg.rwkv_heads, cfg.rwkv_head_size
    dx = _shift(x, x_prev) - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, dx)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype))
                    .astype(jnp.float32)).astype(x.dtype)
    w = _decay(p, xw)

    hshape = (B, S, H, K)
    y, new_state = wkv6_scan(r.reshape(hshape), k.reshape(hshape),
                             v.reshape(hshape), w.reshape(hshape),
                             p["u"].astype(jnp.float32),
                             wkv_state, chunk=chunk)
    y = _group_norm(p["ln_x_scale"], p["ln_x_bias"], y.reshape(B, S, d), H)
    out = jnp.einsum("bsd,de->bse", y * g, p["wo"].astype(x.dtype))
    return out, x[:, -1], new_state


def timemix_decode(cfg: ModelConfig, p, x, x_prev, wkv_state):
    """One token: x (B,1,d). Returns (out (B,1,d), last_x, new_state)."""
    B, _, d = x.shape
    H, K = cfg.rwkv_heads, cfg.rwkv_head_size
    dx = x_prev[:, None] - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, dx)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype))
                    .astype(jnp.float32)).astype(x.dtype)
    w = _decay(p, xw)
    y, new_state = wkv6_decode_step(
        wkv_state, r[:, 0].reshape(B, H, K), k[:, 0].reshape(B, H, K),
        v[:, 0].reshape(B, H, K), w[:, 0].reshape(B, H, K),
        p["u"].astype(jnp.float32))
    y = _group_norm(p["ln_x_scale"], p["ln_x_bias"], y.reshape(B, 1, d), H)
    out = jnp.einsum("bsd,de->bse", y * g, p["wo"].astype(x.dtype))
    return out, x[:, 0], new_state


def channelmix_block(cfg: ModelConfig, p, x, x_prev):
    """x: (B,S,d) normed input. Returns (out, last_x (B,d))."""
    dx = _shift(x, x_prev) - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jnp.maximum(k.astype(jnp.float32), 0.0)).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(x.dtype))
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
                           .astype(jnp.float32)).astype(x.dtype)
    return rgate * kv, x[:, -1]
