"""Mamba2 (SSD) block: in_proj -> causal depthwise conv -> selective SSD scan
-> gated RMSNorm -> out_proj. Train/prefill use the chunked SSD kernel;
decode carries (conv_state, ssd_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.mamba2_scan.ops import ssd_scan
from ..kernels.mamba2_scan.ref import ssd_decode_step
from .params import ParamSpec

_G = 1  # ssm groups (ngroups=1 for all assigned archs)


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_ch = di + 2 * _G * N                 # conv runs over [x, B, C]
    proj = 2 * di + 2 * _G * N + H            # [z, x, B, C, dt]
    return di, H, N, conv_ch, proj


def mamba2_specs(cfg: ModelConfig):
    d = cfg.d_model
    di, H, N, conv_ch, proj = _dims(cfg)
    return {
        "in_proj": ParamSpec((d, proj), ("embed", "mamba_proj")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), (None, "ssm_inner"), "uniform_small", 0.5),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), "zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), "ssm_A"),
        "D": ParamSpec((H,), ("ssm_heads",), "ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), "ssm_dt"),
        "norm_scale": ParamSpec((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg, zxbcdt):
    di, H, N, _, _ = _dims(cfg)
    z, x, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + _G * N, 2 * di + 2 * _G * N], axis=-1)
    return z, x, Bm, Cm, dt


def _gated_rmsnorm(scale, y, z, eps=1e-5):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), -1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def mamba2_block(cfg: ModelConfig, p, x, init_state=None, *, chunk: int = 64):
    """x: (B, S, d). Returns (out (B,S,d), (conv_state, ssd_state))."""
    B, S, _ = x.shape
    di, H, N, conv_ch, _ = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"].astype(x.dtype))
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)

    # causal depthwise conv over [x, B, C]
    xbc = jnp.concatenate([xs, Bm, Cm], -1)                        # (B,S,conv_ch)
    cw = p["conv_w"].astype(x.dtype)                               # (w, conv_ch)
    pad = jnp.pad(xbc, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S] * cw[i][None, None]
               for i in range(cfg.ssm_conv))
    conv = jax.nn.silu((conv + p["conv_b"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(conv, [di, di + _G * N], axis=-1)

    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, ssd_state = ssd_scan(xh, dt, A,
                            Bm.reshape(B, S, _G, N), Cm.reshape(B, S, _G, N),
                            p["D"].astype(jnp.float32),
                            init_state, chunk=chunk)
    y = y.reshape(B, S, di)
    y = _gated_rmsnorm(p["norm_scale"], y, z)
    out = jnp.einsum("bsv,vd->bsd", y, p["out_proj"].astype(x.dtype))
    conv_state = xbc[:, S - (cfg.ssm_conv - 1):]                   # pre-activation tail
    return out, (conv_state, ssd_state)


def mamba2_decode(cfg: ModelConfig, p, x, state):
    """One token. x: (B, 1, d); state = (conv_state (B,w-1,conv_ch),
    ssd_state (B,H,P,N)). Returns (out (B,1,d), new_state)."""
    B = x.shape[0]
    di, H, N, conv_ch, _ = _dims(cfg)
    conv_state, ssd_state = state
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"].astype(x.dtype))
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)

    xbc = jnp.concatenate([xs, Bm, Cm], -1)[:, 0]                  # (B,conv_ch)
    win = jnp.concatenate([conv_state, xbc[:, None]], 1)           # (B,w,conv_ch)
    cw = p["conv_w"].astype(x.dtype)
    conv = jnp.einsum("bwc,wc->bc", win, cw) + p["conv_b"].astype(x.dtype)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs1, Bm1, Cm1 = jnp.split(conv, [di, di + _G * N], axis=-1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_ssd = ssd_decode_step(
        ssd_state, xs1.reshape(B, H, cfg.ssm_head_dim), dt1, A,
        Bm1.reshape(B, _G, N), Cm1.reshape(B, _G, N), p["D"].astype(jnp.float32))
    y = y.reshape(B, 1, di)
    y = _gated_rmsnorm(p["norm_scale"], y, z)
    out = jnp.einsum("bsv,vd->bsd", y, p["out_proj"].astype(x.dtype))
    return out, (win[:, 1:], new_ssd)
