from . import layers, mamba2, model, moe, params, rwkv6  # noqa: F401
