"""Mixture-of-Experts MLP with top-k routing.

Execution paths:
  * ``dispatch`` (default) — capacity-bounded scatter/gather dispatch
    (GShard-style dropping semantics, but built on scatter-add / gather so the
    dispatch tensors are O(E*C*d), never O(T*E*C)). Under EP the expert dim is
    sharded on the ``model`` mesh axis and the capacity dim on ``data``; XLA
    emits the all-to-alls.
  * ``dense`` — every expert computes every token (tiny smoke configs only;
    used as a correctness cross-check for the dispatch path).

Aux losses: Switch-style load-balance + router z-loss, returned as metrics.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import ParamSpec


def moe_specs(cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    sp = {
        "router": ParamSpec((d, E), ("embed", "expert"), "normal", 0.02),
        "w_gate": ParamSpec((E, d, ff), ("expert", "embed", "expert_mlp")),
        "w_up": ParamSpec((E, d, ff), ("expert", "embed", "expert_mlp")),
        "w_down": ParamSpec((E, ff, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        sp["shared"] = {
            "w_gate": ParamSpec((d, ff * cfg.n_shared_experts), ("embed", "mlp")),
            "w_up": ParamSpec((d, ff * cfg.n_shared_experts), ("embed", "mlp")),
            "w_down": ParamSpec((ff * cfg.n_shared_experts, d), ("mlp", "embed")),
        }
    return sp


def _router(cfg, p, x):
    """x (B,S,d) -> (weights (B,S,k), idx (B,S,k), aux dict)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    k = cfg.num_experts_per_tok
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, k)
    w = w / jnp.sum(w, -1, keepdims=True)

    E = cfg.num_experts
    me = jnp.mean(gates, axis=(0, 1))                              # mean gate
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E), axis=(0, 1))     # top-1 freq
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return w, idx, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _expert_ffn(p, x):
    """x (E, C, d) -> (E, C, d), per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))


def _shared_expert(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


def moe_block_dense(cfg: ModelConfig, p, x):
    """All experts on all tokens (smoke-scale only)."""
    w, idx, aux = _router(cfg, p, x)
    E = cfg.num_experts
    comb = jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32)
                   * w[..., None], axis=2)                          # (B,S,E)
    g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("bsef,efd->bsed", h, p["w_down"].astype(x.dtype))
    out = jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), comb).astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + _shared_expert(p["shared"], x)
    return out, aux


def moe_block_dispatch(cfg: ModelConfig, p, x, *,
                       capacity_factor: float = 1.25,
                       shard: Callable = lambda t, names: t,
                       groups: int = 0):
    """GShard-style einsum dispatch with token groups.

    Tokens are flattened to (G, S_g, d) with G sharded over the WHOLE mesh
    (data x model), so each device routes only its local tokens; the dispatch
    einsum against model-sharded experts lowers to all-to-alls. Capacity is
    per (group, expert): C = cf * S_g * k / E; over-capacity choices drop
    (token keeps its residual) — standard dropping semantics.

    Memory: dispatch/combine tensors are (G, S_g, E, C) sharded on G.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    if groups <= 0:
        groups = min(T, 256)
    while T % groups:
        groups -= 1
    Sg = T // groups
    C = max(4, -(-int(capacity_factor * Sg * k / E) // 4) * 4)
    C = min(C, Sg * k)

    w, idx, aux = _router(cfg, p, x)                    # (B,S,k) x2
    xg = shard(x.reshape(groups, Sg, d), ("tokens", None, None))
    wg = w.reshape(groups, Sg, k)
    ig = idx.reshape(groups, Sg, k)

    # slot of each (token, choice) within its (group, expert), FIFO by (s, k)
    mask = jax.nn.one_hot(ig, E, dtype=jnp.int32)       # (G,Sg,k,E)
    flat = mask.reshape(groups, Sg * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat               # exclusive rank
    slot = jnp.sum(pos.reshape(groups, Sg, k, E) * mask, axis=-1)   # (G,Sg,k)
    keep = (slot < C).astype(x.dtype)

    slot_oh = jax.nn.one_hot(slot, C, dtype=x.dtype) * keep[..., None]
    # dispatch (G,Sg,E,C) = sum_k onehot_e x onehot_c
    disp = jnp.einsum("gske,gskc->gsec", mask.astype(x.dtype), slot_oh)
    comb = jnp.einsum("gske,gskc,gsk->gsec", mask.astype(x.dtype), slot_oh,
                      wg.astype(x.dtype))

    expert_in = jnp.einsum("gsec,gsd->egcd", disp, xg)  # all-to-all here
    expert_in = shard(expert_in, ("expert", "tokens", None, None))
    eo = _expert_ffn_grouped(p, expert_in)              # (E,G,C,d)
    eo = shard(eo, ("expert", "tokens", None, None))
    yg = jnp.einsum("egcd,gsec->gsd", eo, comb)         # and back
    out = shard(yg, ("tokens", None, None)).reshape(B, S, d)
    if cfg.n_shared_experts:
        out = out + _shared_expert(p["shared"], x)
    return out, aux


def _expert_ffn_grouped(p, x):
    """x (E, G, C, d) -> (E, G, C, d), per-expert SwiGLU."""
    g = jnp.einsum("egcd,edf->egcf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("egcd,edf->egcf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(x.dtype))


def moe_block(cfg: ModelConfig, p, x, *, path: str = "dispatch",
              shard: Callable = lambda t, names: t, groups: int = 0):
    if path == "dense":
        return moe_block_dense(cfg, p, x)
    return moe_block_dispatch(cfg, p, x, shard=shard, groups=groups)
