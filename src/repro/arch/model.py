"""Unified LM builder: one scan-over-superblocks code path for all 10
assigned architectures (dense / GQA / MoE / Mamba2-hybrid / RWKV6 / encoder).

Public surface:
    build_param_specs(cfg)            ParamSpec pytree (dry-run & init)
    init_params(cfg, key)             materialised f32 params
    forward(cfg, params, batch, ...)  logits (train/prefill)
    train_loss(cfg, params, batch)    scalar CE (+ MoE aux)
    decode_state_specs(cfg, B, S)     ShapeDtypeStruct pytree of decode state
    init_decode_state(cfg, B, S)      zeroed decode state
    decode_step(cfg, params, state, batch)  (logits, new_state)
    param_count(cfg)                  exact parameter count
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers, mamba2, moe, rwkv6
from .params import (ParamSpec, init_tree, param_count as _spec_count,
                     shape_structs, stack_specs)

_IDShard = lambda x, names: x   # noqa: E731  (default no-op shard hook)


# ------------------------------------------------------------------ specs

def _block_specs(cfg: ModelConfig, kind: str):
    if kind == "attn":
        return {"ln1": layers.norm_specs(cfg), "attn": layers.attention_specs(cfg),
                "ln2": layers.norm_specs(cfg), "mlp": layers.mlp_specs(cfg)}
    if kind == "attn_moe":
        return {"ln1": layers.norm_specs(cfg), "attn": layers.attention_specs(cfg),
                "ln2": layers.norm_specs(cfg), "moe": moe.moe_specs(cfg)}
    if kind == "mamba2":
        return {"ln1": layers.norm_specs(cfg), "mixer": mamba2.mamba2_specs(cfg)}
    if kind == "rwkv6":
        return {"ln1": layers.norm_specs(cfg), "tm": rwkv6.timemix_specs(cfg),
                "ln2": layers.norm_specs(cfg), "cm": rwkv6.channelmix_specs(cfg)}
    raise ValueError(kind)


def _shared_block_specs(cfg: ModelConfig):
    d2 = 2 * cfg.d_model
    return {"ln1": layers.norm_specs(cfg, d2),
            "attn": layers.attention_specs(cfg, d_in=d2),
            "ln2": layers.norm_specs(cfg, d2),
            "mlp": layers.mlp_specs(cfg, d_in=d2)}


def build_param_specs(cfg: ModelConfig):
    period = {f"pos{i}": _block_specs(cfg, kind)
              for i, kind in enumerate(cfg.pattern)}
    specs = {"blocks": stack_specs(period, cfg.num_periods),
             "final_norm": layers.norm_specs(cfg)}
    if cfg.frontend != "frames":
        specs["embed"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                   ("vocab", "embed"), "embed")
    if "rwkv6" in cfg.pattern:
        specs["ln0"] = layers.norm_specs(cfg)
    if cfg.shared_attn_every_period:
        specs["shared"] = _shared_block_specs(cfg)
    if not (cfg.tie_embeddings and cfg.frontend != "frames"):
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"), "normal")
    return specs


def param_count(cfg: ModelConfig) -> int:
    return _spec_count(build_param_specs(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k + shared experts only)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    e_specs = moe.moe_specs(cfg)
    per_expert = _spec_count({k: e_specs[k] for k in ("w_gate", "w_up", "w_down")})
    n_moe_layers = cfg.num_periods * sum(k == "attn_moe" for k in cfg.pattern)
    inactive = per_expert * (1 - cfg.num_experts_per_tok / cfg.num_experts)
    return int(total - n_moe_layers * inactive)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    return init_tree(build_param_specs(cfg), key, dtype)


def param_shape_structs(cfg: ModelConfig, dtype=jnp.float32):
    return shape_structs(build_param_specs(cfg), dtype)


# ------------------------------------------------------------------ embed

def _embed(cfg: ModelConfig, params, batch, dtype):
    if cfg.frontend == "frames":
        return batch["frames"].astype(dtype)
    emb = params["embed"].astype(dtype)
    h = jnp.take(emb, batch["tokens"], axis=0)
    if cfg.frontend == "patches" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(dtype)
        h = jnp.concatenate([ve, h[:, ve.shape[1]:]], axis=1)
    return h


def _positions(cfg: ModelConfig, batch, B, S):
    if cfg.use_mrope:
        if "positions" in batch:
            return batch["positions"]
        base = jnp.arange(S)[None].repeat(B, 0)
        return jnp.stack([base] * 3)
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


def _unembed(cfg: ModelConfig, params, h):
    if cfg.tie_embeddings and "embed" in params:
        w = params["embed"].astype(h.dtype).T
    else:
        w = params["lm_head"].astype(h.dtype)
    return jnp.einsum("...d,dv->...v", h, w)


# ------------------------------------------------------------------ blocks

def _apply_block(cfg, kind, p, h, positions, emb0, shard, moe_path,
                 moe_groups=0):
    """Full-sequence application. Returns (h, cache, aux)."""
    aux = {}
    cache = None
    if kind in ("attn", "attn_moe"):
        a, (k, v) = layers.attention_block(cfg, p["attn"],
                                           layers.apply_norm(cfg, p["ln1"], h),
                                           positions)
        h = h + a
        if kind == "attn":
            h = h + layers.mlp_block(cfg, p["mlp"],
                                     layers.apply_norm(cfg, p["ln2"], h))
        else:
            m, aux = moe.moe_block(cfg, p["moe"],
                                   layers.apply_norm(cfg, p["ln2"], h),
                                   path=moe_path, shard=shard,
                                   groups=moe_groups)
            h = h + m
        cache = {"k": k, "v": v}
    elif kind == "mamba2":
        m, (conv_s, ssd_s) = mamba2.mamba2_block(
            cfg, p["mixer"], layers.apply_norm(cfg, p["ln1"], h))
        h = h + m
        cache = {"conv": conv_s, "ssd": ssd_s}
    elif kind == "rwkv6":
        x_prev0 = jnp.zeros((h.shape[0], h.shape[2]), h.dtype)
        t, x_tm, wkv = rwkv6.timemix_block(
            cfg, p["tm"], layers.apply_norm(cfg, p["ln1"], h), x_prev0)
        h = h + t
        c, x_cm = rwkv6.channelmix_block(
            cfg, p["cm"], layers.apply_norm(cfg, p["ln2"], h), x_prev0)
        h = h + c
        cache = {"x_tm": x_tm, "x_cm": x_cm, "wkv": wkv}
    else:
        raise ValueError(kind)
    return h, cache, aux


def _apply_shared(cfg, p, h, emb0, positions):
    """Zamba2 weight-shared attention+MLP block on concat(h, emb0)."""
    cat = jnp.concatenate([h, emb0], axis=-1)
    a, (k, v) = layers.attention_block(cfg, p["attn"],
                                       layers.apply_norm(cfg, p["ln1"], cat),
                                       positions)
    h = h + a
    cat = jnp.concatenate([h, emb0], axis=-1)
    h = h + layers.mlp_block(cfg, p["mlp"], layers.apply_norm(cfg, p["ln2"], cat))
    return h, {"k": k, "v": v}


# ------------------------------------------------------------------ forward

def forward(cfg: ModelConfig, params, batch, *, mode: str = "train",
            shard: Callable = _IDShard, remat: bool = True,
            moe_path: str = "dispatch", scan_unroll: int = 1,
            moe_groups: int = 0):
    """Full-sequence forward. mode: "train" -> logits (B,S,V);
    "prefill" -> (last-token logits (B,V), decode_state)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.frontend == "frames":
        B, S = batch["frames"].shape[:2]
    else:
        B, S = batch["tokens"].shape
    h = shard(_embed(cfg, params, batch, dtype), ("batch", "seq", None))
    positions = _positions(cfg, batch, B, S)
    if "ln0" in params:
        h = layers.apply_norm(cfg, params["ln0"], h)
    emb0 = h

    shared_p = params.get("shared")

    want_cache = mode == "prefill"

    def body(carry, xs):
        h = carry
        h = shard(h, ("batch", "seq", None))
        caches, auxes = {}, []
        for i, kind in enumerate(cfg.pattern):
            h, cache, aux = _apply_block(cfg, kind, xs[f"pos{i}"], h,
                                         positions, emb0, shard, moe_path,
                                         moe_groups)
            if cache is not None and want_cache:
                caches[f"pos{i}"] = cache
            if aux:
                auxes.append(aux)
        if cfg.shared_attn_every_period:
            h, sc = _apply_shared(cfg, shared_p, h, emb0, positions)
            if want_cache:
                caches["shared"] = sc
        aux_sum = ({k: sum(a[k] for a in auxes) for k in auxes[0]}
                   if auxes else {})
        return h, (caches, aux_sum)

    body_fn = (jax.checkpoint(body)
               if (remat and mode in ("train", "hidden")) else body)
    h, (caches, aux) = jax.lax.scan(body_fn, h, params["blocks"],
                                    unroll=scan_unroll)

    h = layers.apply_norm(cfg, params["final_norm"], h)
    if mode == "hidden":          # final hidden states (chunked-CE path)
        aux_mean = jax.tree_util.tree_map(jnp.mean, aux)
        return h, aux_mean
    if mode == "train":
        logits = _unembed(cfg, params, h).astype(jnp.float32)
        aux_mean = jax.tree_util.tree_map(jnp.mean, aux)
        return logits, aux_mean
    # prefill: logits for the last position + populated decode state
    logits = _unembed(cfg, params, h[:, -1]).astype(jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    state = {"caches": caches, "lengths": lengths}
    return logits, state


def _ce_from_logits(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(lse - ll)


def chunked_ce(cfg: ModelConfig, params, h, labels, *, chunks: int,
               shard: Callable = _IDShard):
    """Sequence-chunked cross-entropy: the (B, S, V) f32 logits are never
    materialised — each S/chunks slice computes (and in backward, recomputes
    under remat) its own logits. Chunking slices along S with dynamic_slice so
    the batch sharding of ``h`` survives (reshape/transpose would break GSPMD
    propagation and silently replicate the hidden states)."""
    B, S, d = h.shape
    csz = S // chunks

    @jax.checkpoint
    def one(ci):
        hc = jax.lax.dynamic_slice_in_dim(h, ci * csz, csz, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, ci * csz, csz, axis=1)
        hc = shard(hc, ("batch", None, None))
        logits = _unembed(cfg, params, hc).astype(jnp.float32)
        return _ce_from_logits(logits, lc)

    def body(acc, ci):
        return acc + one(ci), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(chunks))
    return total / (B * S)


def train_loss(cfg: ModelConfig, params, batch, *, shard: Callable = _IDShard,
               remat: bool = True, moe_path: str = "dispatch",
               scan_unroll: int = 1, loss_chunks: int = 0,
               moe_groups: int = 0):
    labels = batch["labels"]
    S = labels.shape[1]
    if loss_chunks == 0:                      # auto: chunk long sequences
        loss_chunks = max(1, min(16, S // 512))
    while S % loss_chunks:
        loss_chunks -= 1
    if loss_chunks > 1:
        h, aux = forward(cfg, params, batch, mode="hidden", shard=shard,
                         remat=remat, moe_path=moe_path,
                         scan_unroll=scan_unroll, moe_groups=moe_groups)
        ce = chunked_ce(cfg, params, h, labels, chunks=loss_chunks, shard=shard)
    else:
        logits, aux = forward(cfg, params, batch, mode="train", shard=shard,
                              remat=remat, moe_path=moe_path,
                              scan_unroll=scan_unroll, moe_groups=moe_groups)
        ce = _ce_from_logits(logits, labels) / labels.size
    loss = ce
    if aux:
        loss = loss + 0.01 * aux.get("moe_lb_loss", 0.0) \
                    + 1e-3 * aux.get("moe_z_loss", 0.0)
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


# ------------------------------------------------------------------ decode

def _cache_entry_spec(cfg: ModelConfig, kind: str, B: int, S: int, dtype):
    sd = jax.ShapeDtypeStruct
    if kind in ("attn", "attn_moe", "shared"):
        return {"k": sd((B, S, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": sd((B, S, cfg.num_kv_heads, cfg.head_dim), dtype)}
    if kind == "mamba2":
        di, H, N, conv_ch, _ = mamba2._dims(cfg)
        return {"conv": sd((B, cfg.ssm_conv - 1, conv_ch), dtype),
                "ssd": sd((B, H, cfg.ssm_head_dim, N), jnp.float32)}
    if kind == "rwkv6":
        H, K = cfg.rwkv_heads, cfg.rwkv_head_size
        return {"x_tm": sd((B, cfg.d_model), dtype),
                "x_cm": sd((B, cfg.d_model), dtype),
                "wkv": sd((B, H, K, K), jnp.float32)}
    raise ValueError(kind)


def decode_state_specs(cfg: ModelConfig, B: int, S: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    per = {f"pos{i}": _cache_entry_spec(cfg, kind, B, S, dtype)
           for i, kind in enumerate(cfg.pattern)}
    if cfg.shared_attn_every_period:
        per["shared"] = _cache_entry_spec(cfg, "shared", B, S, dtype)
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_periods,) + s.shape, s.dtype), per)
    return {"caches": stacked, "lengths": jax.ShapeDtypeStruct((B,), jnp.int32)}


def init_decode_state(cfg: ModelConfig, B: int, S: int, dtype=None):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  decode_state_specs(cfg, B, S, dtype))


def _decode_block(cfg, kind, p, h, caches, key, layer, lengths, emb0, shard,
                  moe_path, moe_groups=0, attn_dist=None):
    """One block against the STACKED cache pytree (in-place updates)."""
    cs = caches[key]
    if kind in ("attn", "attn_moe"):
        a, nk, nv = layers.attention_decode(
            cfg, p["attn"], layers.apply_norm(cfg, p["ln1"], h),
            cs["k"], cs["v"], layer, lengths, dist=attn_dist)
        caches[key] = {"k": nk, "v": nv}
        h = h + a
        if kind == "attn":
            h = h + layers.mlp_block(cfg, p["mlp"],
                                     layers.apply_norm(cfg, p["ln2"], h))
        else:
            m, _ = moe.moe_block(cfg, p["moe"],
                                 layers.apply_norm(cfg, p["ln2"], h),
                                 path=moe_path, shard=shard,
                                 groups=moe_groups)
            h = h + m
        return h, caches
    pick = lambda x: jax.lax.dynamic_index_in_dim(x, layer, 0, keepdims=False)  # noqa: E731
    put = lambda x, v: x.at[layer].set(v.astype(x.dtype))  # noqa: E731
    if kind == "mamba2":
        m, (conv_s, ssd_s) = mamba2.mamba2_decode(
            cfg, p["mixer"], layers.apply_norm(cfg, p["ln1"], h),
            (pick(cs["conv"]), pick(cs["ssd"])))
        caches[key] = {"conv": put(cs["conv"], conv_s),
                       "ssd": put(cs["ssd"], ssd_s)}
        return h + m, caches
    if kind == "rwkv6":
        t, x_tm, wkv = rwkv6.timemix_decode(
            cfg, p["tm"], layers.apply_norm(cfg, p["ln1"], h),
            pick(cs["x_tm"]), pick(cs["wkv"]))
        h = h + t
        xn = layers.apply_norm(cfg, p["ln2"], h)
        # channelmix's shift uses x_prev at t=0 == stored last token
        c, x_cm = rwkv6.channelmix_block(cfg, p["cm"], xn, pick(cs["x_cm"]))
        caches[key] = {"x_tm": put(cs["x_tm"], x_tm),
                       "x_cm": put(cs["x_cm"], x_cm),
                       "wkv": put(cs["wkv"], wkv)}
        return h + c, caches
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, params, state, batch, *,
                shard: Callable = _IDShard, moe_path: str = "dispatch",
                scan_unroll: int = 1, moe_groups: int = 0, attn_dist=None):
    """One-token decode. batch: {"tokens": (B,1)} (or {"frames": (B,1,d)}).

    The stacked caches travel in the scan CARRY and are updated IN PLACE
    (scatter on the touched rows): per-layer traffic is one cache read plus
    a B-row write — the cache is never rewritten. Returns (logits, state).
    """
    assert cfg.is_decoder, f"{cfg.name} is encoder-only"
    dtype = jnp.dtype(cfg.dtype)
    lengths = state["lengths"]
    B = lengths.shape[0]
    h = _embed(cfg, params, batch, dtype)
    if "ln0" in params:
        h = layers.apply_norm(cfg, params["ln0"], h)
    h = shard(h, ("batch", None, None))
    emb0 = h
    shared_p = params.get("shared")

    def body(carry, xs):
        h, caches = carry
        p, layer = xs
        caches = dict(caches)
        for i, kind in enumerate(cfg.pattern):
            h, caches = _decode_block(cfg, kind, p[f"pos{i}"], h, caches,
                                      f"pos{i}", layer, lengths, emb0, shard,
                                      moe_path, moe_groups, attn_dist)
        if cfg.shared_attn_every_period:
            cat = jnp.concatenate([h, emb0], axis=-1)
            a, nk, nv = layers.attention_decode(
                cfg, shared_p["attn"],
                layers.apply_norm(cfg, shared_p["ln1"], cat),
                caches["shared"]["k"], caches["shared"]["v"], layer, lengths,
                dist=attn_dist)
            caches["shared"] = {"k": nk, "v": nv}
            h = h + a
            cat = jnp.concatenate([h, emb0], axis=-1)
            h = h + layers.mlp_block(cfg, shared_p["mlp"],
                                     layers.apply_norm(cfg, shared_p["ln2"], cat))
        return (h, caches), None

    (h, new_caches), _ = jax.lax.scan(
        body, (h, dict(state["caches"])),
        (params["blocks"], jnp.arange(cfg.num_periods)), unroll=scan_unroll)
    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = _unembed(cfg, params, h[:, 0]).astype(jnp.float32)
    return logits, {"caches": new_caches, "lengths": lengths + 1}
