"""Fault tolerance: failure detection, elastic re-meshing, preemption handling.

At 1000+ nodes failures are routine; the framework treats them as schedulable
events, not crashes:

  * ``HealthMonitor`` — heartbeat registry with failure injection (tests/
    benchmarks simulate node loss deterministically).
  * ``elastic_remesh`` — given surviving device count, rebuild the largest
    valid (data, model) mesh and recompute shardings; training resumes from
    the last checkpoint on the SHRUNKEN mesh (checkpoint.restore reshards).
  * ``TrainSupervisor`` — wraps a train loop: on step failure -> restore from
    last checkpoint, optionally shrink the mesh, continue. On SIGTERM ->
    checkpoint-and-exit (preemption).
"""
from __future__ import annotations

import math
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax


class NodeFailure(RuntimeError):
    pass


@dataclass
class HealthMonitor:
    """Heartbeat table + deterministic failure injection."""
    heartbeat_timeout_s: float = 30.0
    _last_beat: Dict[int, float] = field(default_factory=dict)
    _failed: set = field(default_factory=set)

    def beat(self, node_id: int, now: Optional[float] = None):
        if node_id in self._failed:
            raise NodeFailure(f"node {node_id} marked failed")
        self._last_beat[node_id] = time.time() if now is None else now

    def inject_failure(self, node_id: int):
        self._failed.add(node_id)

    def heal(self, node_id: int):
        self._failed.discard(node_id)

    def alive(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return sorted(n for n, t in self._last_beat.items()
                      if n not in self._failed
                      and now - t <= self.heartbeat_timeout_s)

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return sorted(set(self._last_beat) - set(self.alive(now)))


def largest_mesh_shape(n_devices: int, *, model_axis: int = 16):
    """Largest (data, model) grid using <= n_devices, keeping the model axis
    if possible (TP degree is fixed by the model's sharding constraints;
    elasticity shrinks the DATA axis first)."""
    while model_axis > 1 and n_devices < model_axis:
        model_axis //= 2
    data = max(n_devices // model_axis, 1)
    # data axis must stay a power of two for clean batch resharding
    data = 2 ** int(math.log2(data))
    return (data, model_axis)


def elastic_remesh(devices=None, *, model_axis: int = 16):
    """Rebuild the largest valid mesh from surviving devices."""
    devices = list(devices if devices is not None else jax.devices())
    shape = largest_mesh_shape(len(devices), model_axis=model_axis)
    n = shape[0] * shape[1]
    import numpy as np
    arr = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, ("data", "model"))


@dataclass
class SupervisorReport:
    steps_run: int = 0
    failures_handled: int = 0
    restores: int = 0
    remeshes: int = 0
    preempted: bool = False
    final_step: int = 0


class TrainSupervisor:
    """Checkpoint/restart/elastic wrapper around a step function.

    ``step_fn(state, batch) -> state`` runs under supervision; a raising step
    triggers restore-from-checkpoint (and optional mesh shrink via
    ``on_remesh``). SIGTERM triggers checkpoint-and-exit.
    """

    def __init__(self, ckpt_manager, *, checkpoint_every: int = 50,
                 max_restores: int = 8,
                 on_remesh: Optional[Callable[[int], None]] = None,
                 install_sigterm: bool = False):
        self.ckpt = ckpt_manager
        self.every = checkpoint_every
        self.max_restores = max_restores
        self.on_remesh = on_remesh
        self._preempt = threading.Event()
        if install_sigterm:
            signal.signal(signal.SIGTERM, lambda *_: self._preempt.set())

    def request_preemption(self):
        self._preempt.set()

    def run(self, state, batches, step_fn, *, start_step: int = 0,
            num_steps: int = 100, shardings=None) -> tuple:
        rep = SupervisorReport()
        step = start_step
        it = iter(batches)
        while step < num_steps:
            if self._preempt.is_set():
                self.ckpt.save_sync(state, step=step, extra={"preempted": True})
                rep.preempted = True
                break
            batch = next(it)
            try:
                state = step_fn(state, batch)
                step += 1
                rep.steps_run += 1
                if step % self.every == 0:
                    self.ckpt.save_async(state, step=step)
            except (NodeFailure, jax.errors.JaxRuntimeError) as e:
                rep.failures_handled += 1
                if rep.restores >= self.max_restores:
                    raise
                restored, manifest = self.ckpt.restore_latest(
                    state, shardings=shardings)
                if restored is None:
                    raise
                state = restored
                step = manifest["step"]
                rep.restores += 1
                if self.on_remesh is not None:
                    self.on_remesh(rep.failures_handled)
                    rep.remeshes += 1
        self.ckpt.wait()
        rep.final_step = step
        return state, rep
