"""Sharded, asynchronous, elastic checkpointing.

Design (mirrors what production JAX frameworks do, scaled to this runtime):
  * SHARDED — each host writes only the addressable shards of its arrays into
    ``shard-<process>.npz``; a JSON manifest records step/tree-structure/
    mesh shape.
  * ASYNC — ``save_async`` snapshots device arrays to host memory
    synchronously (cheap) and writes to disk on a background thread,
    double-buffered so training never blocks on I/O.
  * ELASTIC — ``restore`` resharids onto WHATEVER mesh/sharding the caller
    passes (the saved mesh shape is metadata, not a constraint), which is
    what makes shrink-and-continue after a node failure work.
  * ATOMIC — writes go to ``<dir>.tmp`` then rename; a crash mid-save never
    corrupts the latest-complete checkpoint.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree, *, step: int, extra: Optional[dict] = None):
    """Synchronous sharded save (single-process: one shard file)."""
    p = Path(path)
    tmp = Path(str(p) + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    arrays = {}
    for i, leaf in enumerate(leaves):
        arrays[f"a{i}"] = np.asarray(leaf)
    np.savez(tmp / f"shard-{jax.process_index()}.npz", **arrays)
    manifest = {
        "step": int(step),
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "process_count": jax.process_count(),
        "written_at": time.time(),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if p.exists():
        shutil.rmtree(p)
    tmp.rename(p)


def restore(path: str, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``; if ``shardings`` is given
    (pytree of NamedSharding), arrays are placed with that sharding — which
    may correspond to a DIFFERENT mesh than the one saved from (elastic)."""
    p = Path(path)
    manifest = json.loads((p / "manifest.json").read_text())
    z = np.load(p / f"shard-{jax.process_index()}.npz")
    leaves, treedef = _flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, model has {len(leaves)}"
    out = []
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(leaves))
    for i, (leaf, sh) in enumerate(zip(leaves, flat_sh)):
        arr = z[f"a{i}"]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def latest_step(root: str) -> Optional[int]:
    r = Path(root)
    if not r.exists():
        return None
    steps = [int(d.name.split("-")[1]) for d in r.iterdir()
             if d.is_dir() and d.name.startswith("step-") and
             (d / "manifest.json").exists()]
    return max(steps) if steps else None


class CheckpointManager:
    """Double-buffered async checkpointing with retention."""

    def __init__(self, root: str, *, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def dir_for(self, step: int) -> Path:
        return self.root / f"step-{step}"

    def save_async(self, tree, *, step: int, extra: Optional[dict] = None):
        self.wait()                          # double-buffer: at most 1 pending
        host_tree = jax.tree_util.tree_map(np.asarray, tree)   # snapshot now

        def work():
            try:
                save(self.dir_for(step), host_tree, step=step, extra=extra)
                self._gc()
            except BaseException as e:      # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, tree, *, step: int, extra: Optional[dict] = None):
        self.wait()
        save(self.dir_for(step), tree, step=step, extra=extra)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore_latest(self, like_tree, *, shardings=None):
        self.wait()
        step = latest_step(self.root)
        if step is None:
            return None, None
        return restore(self.dir_for(step), like_tree, shardings=shardings)

    def _gc(self):
        steps = sorted(int(d.name.split("-")[1]) for d in self.root.iterdir()
                       if d.is_dir() and d.name.startswith("step-"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step-{s}", ignore_errors=True)
