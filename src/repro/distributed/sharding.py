"""Logical-axis sharding rules: DP/FSDP/TP/EP/SP over the (pod, data, model)
production mesh.

Parameters declare LOGICAL axes (see arch/params.py); a ``Rules`` object maps
them to mesh axes. Activations use a parallel set of rules applied through the
``shard(x, names)`` hook threaded into the model.

Divisibility guard: a mapping is dropped (replicated) when the dim size does
not divide the mesh-axis extent (jit in_shardings require exact division).
Attention projections avoid the issue structurally: they are stored fused
over (H*hd) — see arch/layers.attention_specs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..arch.params import is_spec

Axes = Union[None, str, Tuple[str, ...]]

PAD_OK: set = set()         # logical axes where uneven sharding would be allowed


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """Version-portable shard_map: jax >= 0.5 exposes ``jax.shard_map``
    (replication check renamed check_vma); 0.4.x ships it under
    jax.experimental with check_rep."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


# ---------------------------------------------------------------------------
# Fleet-bin sharding: partition a megabatch's INSTANCE axis over devices.
# ---------------------------------------------------------------------------

#: compiled sharded dispatchers, keyed by (caller key, mesh, replicated set,
#: arg count) — one shard_map trace per configuration, like _ROLLOUT_CACHE.
_FLEET_SHARDED_CACHE: Dict[tuple, object] = {}


def _pad_leading(tree, pad: int):
    """Pad every array leaf's leading (instance) axis by repeating its last
    row ``pad`` times. Edge replication — never zeros — so padded instances
    run the same numerics as a real one (e.g. GAM knot rows must stay
    strictly increasing); their outputs are sliced off before anyone reads
    them."""
    import jax.numpy as jnp

    def one(a):
        a = jnp.asarray(a)
        last = jnp.broadcast_to(a[-1:], (pad,) + a.shape[1:])
        return jnp.concatenate([a, last], axis=0)

    return jax.tree_util.tree_map(one, tree)


def fleet_sharded(fn, mesh, *, replicated_argnums: Tuple[int, ...] = (),
                  key=None):
    """Wrap ``fn`` — traceable, vmapped/independent over every sharded
    argument's LEADING instance axis, collective-free — so it executes as
    ONE ``shard_map`` dispatch over ``mesh``'s single fleet axis: each
    device computes its N/ndev slice of the bin.

    The wrapper pads the instance axis up to a multiple of the shard count
    (edge-replicated rows, masked back off the outputs), so uneven bins
    just work. Arguments listed in ``replicated_argnums`` are broadcast to
    every device unsharded. With ``key`` the shard_map trace + jit are
    cached across calls (keyed additionally by mesh and arity), mirroring
    the rollout cache in forecast/base.py.
    """
    axis = mesh.axis_names[0]
    nshard = math.prod(mesh.shape.values())
    repl = frozenset(replicated_argnums)

    def build(nargs: int):
        in_specs = tuple(P() if i in repl else P(axis) for i in range(nargs))
        return jax.jit(shard_map_compat(fn, mesh=mesh, in_specs=in_specs,
                                        out_specs=P(axis)))

    def wrapper(*args):
        cache_k = None if key is None else (key, mesh, repl, len(args))
        inner = _FLEET_SHARDED_CACHE.get(cache_k) if cache_k else None
        if inner is None:
            inner = build(len(args))
            if cache_k is not None:
                _FLEET_SHARDED_CACHE[cache_k] = inner
        first = next(a for i, a in enumerate(args) if i not in repl)
        n = jax.tree_util.tree_leaves(first)[0].shape[0]
        pad = (-n) % nshard
        if pad:
            args = tuple(a if i in repl else _pad_leading(a, pad)
                         for i, a in enumerate(args))
        out = inner(*args)
        if pad:
            out = jax.tree_util.tree_map(lambda x: x[:n], out)
        return out

    return wrapper


@dataclass(frozen=True)
class Rules:
    params: Dict[str, Axes]
    acts: Dict[str, Axes]
    name: str = "baseline"


def baseline_rules(multi_pod: bool = False) -> Rules:
    dp: Axes = ("pod", "data") if multi_pod else ("data",)
    return Rules(
        name="baseline",
        params={
            "embed": dp,            # FSDP (ZeRO-3): shard d_model dim of weights
            "vocab": ("model",),
            "heads": ("model",),    # TP
            "kv_heads": None,       # few KV heads: replicate (baseline)
            "head": None,
            "mlp": ("model",),      # TP
            "expert": ("model",),   # EP
            "expert_mlp": ("model",),   # collapses onto EP axis (dropped)
            "mamba_proj": ("model",),
            "ssm_inner": ("model",),
            "ssm_heads": ("model",),
            "rwkv_heads": ("model",),
            "rwkv_hidden": ("model",),
            "layers": None,
        },
        acts={
            "batch": dp,
            # MoE dispatch groups shard over dp ONLY so the (B,S,d)->(G,Sg,d)
            # reshape is layout-aligned (free); the expert einsum's all-to-all
            # covers the model axis.
            "tokens": dp,
            "expert": ("model",),
            "capacity": ("data",),
            "seq": None,            # "model" under sequence parallelism
            "kv_seq": ("model",),   # decode KV caches: shard S over model
            "kv_heads": None,
            "heads": ("model",),
        })


def serve_rules(multi_pod: bool = False) -> Rules:
    """Weight-STATIONARY serving layout (beyond-paper optimization, §Perf):
    no FSDP at decode — dense weights live TP-sharded (model axis) and are
    never gathered; MoE expert weights are 2D-sharded (expert@model x
    ffn@data) so a 400B MoE fits without per-token weight movement. The KV
    cache stays (B@data, S@model); attention combines S-shards with the
    distributed flash-decode (partial-softmax psum) instead of gathering."""
    base = baseline_rules(multi_pod)
    dp: Axes = ("pod", "data") if multi_pod else ("data",)
    params = dict(base.params)
    params.update({
        "embed": None,               # NO FSDP: weights stationary
        "expert": ("model",),
        "expert_mlp": dp,            # 2D expert sharding
    })
    acts = dict(base.acts)
    return Rules(name="serve_stationary", params=params, acts=acts)


def sp_rules(multi_pod: bool = False) -> Rules:
    """Sequence-parallel training layout: the residual stream (and the remat
    residual stack) shards its SEQUENCE dim over the model axis between
    blocks; GSPMD converts the TP all-reduces into reduce-scatter +
    all-gather pairs and the saved activations shrink 16x."""
    base = baseline_rules(multi_pod)
    acts = dict(base.acts)
    acts["seq"] = ("model",)
    return Rules(name="sp", params=dict(base.params), acts=acts)


def _norm(a: Axes) -> Tuple[str, ...]:
    if a is None:
        return ()
    return (a,) if isinstance(a, str) else tuple(a)


def _mesh_extent(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def spec_for(mesh: Mesh, rules: Dict[str, Axes], logical: Tuple[Optional[str], ...],
             shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one tensor given its logical axes + shape."""
    out, used = [], set()
    for dim, name in zip(shape, logical):
        axes = tuple(a for a in _norm(rules.get(name)) if name is not None
                     and a in mesh.axis_names and a not in used)
        if not axes:
            out.append(None)
            continue
        ext = _mesh_extent(mesh, axes)
        if dim % ext != 0 and name not in PAD_OK:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def param_shardings(mesh: Mesh, rules: Rules, spec_tree):
    """ParamSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, spec_for(mesh, rules.params, s.axes, s.shape)),
        spec_tree, is_leaf=is_spec)


def make_shard_fn(mesh: Mesh, rules: Rules):
    """The ``shard(x, logical_names)`` hook threaded through model code."""
    def shard(x, names):
        spec = spec_for(mesh, rules.acts, names, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return shard


def batch_shardings(mesh: Mesh, rules: Rules, batch_specs):
    """Input-batch shardings: leading dim is batch (or dim 1 for (3,B,S))."""
    def one(s):
        if s.shape and s.shape[0] == 3 and len(s.shape) == 3:   # mrope positions
            logical = (None, "batch", None)
        else:
            logical = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, spec_for(mesh, rules.acts, logical, s.shape))
    return jax.tree_util.tree_map(one, batch_specs)


def decode_state_shardings(mesh: Mesh, rules: Rules, cfg, state_specs):
    """Decode state: caches (periods, B, S, KV, hd) -> B on dp, S on model;
    SSM/RWKV states -> B on dp, heads on model."""
    def one(path, s):
        names = [p.key for p in path if hasattr(p, "key")]
        leaf = names[-1] if names else ""
        nd = len(s.shape)
        if leaf in ("k", "v"):
            logical = (None, "batch", "kv_seq", "kv_heads", None)
        elif leaf == "ssd":                       # (periods,B,H,P,N)
            logical = (None, "batch", "heads", None, None)
        elif leaf == "wkv":                       # (periods,B,H,K,V)
            logical = (None, "batch", "heads", None, None)
        elif leaf == "conv":                      # (periods,B,w-1,ch)
            logical = (None, "batch", None, None)
        elif leaf in ("x_tm", "x_cm"):            # (periods,B,d)
            logical = (None, "batch", None)
        elif leaf == "lengths":
            logical = ("batch",)
        else:
            logical = (None,) * nd
        logical = tuple(logical[:nd]) + (None,) * max(0, nd - len(logical))
        return NamedSharding(mesh, spec_for(mesh, rules.acts, logical, s.shape))
    return jax.tree_util.tree_map_with_path(one, state_specs)
