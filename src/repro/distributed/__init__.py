from .sharding import Rules, baseline_rules, make_shard_fn, param_shardings  # noqa: F401
from .checkpoint import CheckpointManager, save, restore, latest_step  # noqa: F401
from .compression import (compress_with_feedback, init_error_state,  # noqa: F401
                          quantize_int8, dequantize_int8)
from .fault import (HealthMonitor, NodeFailure, TrainSupervisor,  # noqa: F401
                    elastic_remesh, largest_mesh_shape)
