"""Error-feedback int8 gradient compression for the slow cross-pod axis.

At multi-pod scale the inter-pod (DCN) links are ~10x slower than in-pod ICI;
compressing the cross-pod gradient contribution is the standard distributed-
optimization trick. We implement stochastic-free deterministic int8 with
per-tensor scale + error feedback (the quantisation residual is carried to
the next step, preserving convergence — Seide et al. / Karimireddy et al.).

The grad_hook integrates with make_train_step: grads are quantised,
dequantised and the residual returned as state threaded by the caller.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """x (f32) -> (int8 codes, scale). Symmetric per-tensor."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8(codes, scale):
    return codes.astype(jnp.float32) * scale


def compress_with_feedback(grads, error_state):
    """Returns (compressed-dequantised grads, new_error_state).

    new_error = (g + e_prev) - dequant(quant(g + e_prev))
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        codes, scale = quantize_int8(corrected)
        deq = dequantize_int8(codes, scale)
        return deq.astype(g.dtype), corrected - deq

    pairs = jax.tree_util.tree_map(one, grads, error_state)
    newg = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                  is_leaf=lambda t: isinstance(t, tuple))
    newe = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return newg, newe


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes_saved(params) -> int:
    """f32 all-reduce vs int8+scale: bytes saved per cross-pod reduction."""
    total = sum(x.size for x in jax.tree_util.tree_leaves(params))
    return total * 4 - (total * 1 + 4 * len(jax.tree_util.tree_leaves(params)))
