"""RWKV-6 (Finch) 7B [arXiv:2404.05892; hf].

Attention-free: time-mix with data-dependent per-channel decay + channel-mix.
head_size 64 -> 64 WKV heads. Decode uses O(1) recurrent state (no KV cache);
sub-quadratic -> runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892; hf",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=14336,
    vocab_size=65536,
    pattern=("rwkv6",),
    rwkv_head_size=64,
    subquadratic=True,
)
