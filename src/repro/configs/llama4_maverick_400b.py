"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

128 experts top-1 + one always-on shared expert, MoE interleaved on every
second layer (dense/MoE alternation) — this is what reconciles the published
400B-total / 17B-active budget with 48L x d=5120 x d_ff=8192:

  MoE params  = 24 layers x 128 experts x 3 x 5120 x 8192 ~ 386B
  dense rest  ~  14B   ->  ~400B total;  active ~ 17B (top-1 + shared).

Early-fusion multimodality is out of scope for the LM backbone (text path
only, per the assignment the frontend would be a stub anyway).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=("attn", "attn_moe"),     # MoE every 2nd layer
    rope_theta=5.0e5,
    num_experts=128,
    num_experts_per_tok=1,
    n_shared_experts=1,
)
