"""DBRX-132B [hf:databricks/dbrx-base; unverified].

Fine-grained MoE: 16 experts, top-4, every layer. GQA kv=8.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base; unverified",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    pattern=("attn_moe",),
    rope_theta=5.0e5,
    num_experts=16,
    num_experts_per_tok=4,
)
