"""Assigned input-shape set (identical for every LM arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / recurrent state of ``seq_len``), NOT ``train_step``.
"""
from __future__ import annotations

from .base import ModelConfig, ShapeSpec

SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": ShapeSpec("prefill_32k", seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   ShapeSpec("long_500k",   seq_len=524_288, global_batch=1,   kind="decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape.kind == "decode" and not cfg.is_decoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.full_attention_only:
        return False, "long_500k needs sub-quadratic attention; arch is pure full-attention"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention"
    if shape.kind == "prefill" and not cfg.is_decoder:
        # encoder-only archs still run prefill_32k as a plain encoder forward
        return True, ""
    return True, ""
