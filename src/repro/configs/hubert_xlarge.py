"""HuBERT-XLarge [arXiv:2106.07447; unverified].

Encoder-only (same transformer as wav2vec2): bidirectional attention,
LayerNorm + gelu. vocab=504 is the masked-prediction codebook. The
convolutional waveform frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, S, d_model).
No decode step exists (decode_32k / long_500k skipped).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447; unverified",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    pattern=("attn",),
    causal=False,
    is_decoder=False,
    norm="layernorm",
    act="gelu",
    frontend="frames",
)
