"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

One module per assigned architecture; exact published configs. Reduced smoke
variants via :func:`repro.configs.base.reduced`.
"""
from __future__ import annotations

from .base import ModelConfig, ShapeSpec, reduced
from .shapes import SHAPES, shape_applicable

from . import (qwen2_vl_7b, starcoder2_7b, llama3_8b, qwen3_1p7b,
               internlm2_20b, dbrx_132b, llama4_maverick_400b, zamba2_2p7b,
               hubert_xlarge, rwkv6_7b)

_ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    qwen2_vl_7b, starcoder2_7b, llama3_8b, qwen3_1p7b, internlm2_20b,
    dbrx_132b, llama4_maverick_400b, zamba2_2p7b, hubert_xlarge, rwkv6_7b)}


def list_archs() -> list[str]:
    return list(_ARCHS)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(_ARCHS[name[: -len("-smoke")]])
    return _ARCHS[name]


__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "reduced", "shape_applicable",
           "get_config", "list_archs"]
