"""InternLM2-20B [arXiv:2403.17297; hf]. GQA kv=8."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    source="arXiv:2403.17297; hf",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    pattern=("attn",),
    rope_theta=1.0e6,
)
