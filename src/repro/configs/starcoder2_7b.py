"""StarCoder2-7B [arXiv:2402.19173; hf]. GQA kv=4, RoPE, LayerNorm + gelu MLP."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    source="arXiv:2402.19173; hf",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    pattern=("attn",),
    rope_theta=1.0e5,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
)
